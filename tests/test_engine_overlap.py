"""PR 6: overlapped (double-buffered) sync, the measured-staleness trace,
and the distributed-metrics bugfix sweep.

Contracts under test:

* ``scheduled_tau(..., overlap=True)`` adds exactly the quantified
  previous-round payload; ``Schedule(overlap=True)`` validates and routes
  it through ``effective_tau``.
* The overlapped strategy variants (halo_gs / sparse_gs / sparse_rk)
  converge on the forced-4-device mesh, their per-round ``lag`` trace is
  0 on round one and the in-flight payload afterwards, and the measured
  staleness ``max(lag) + scheduled_tau(overlap=False)`` never exceeds the
  scheduled overlap bound.  Strategies without an overlapped variant fall
  back to lockstep EXACTLY (bitwise) with a ``UserWarning``.
* ``solve_distributed(x_star=None)`` works on EVERY strategy row of
  ``_DISTRIBUTED_STRATEGIES`` (NaN err_sq, finite residuals) — the dense
  strategies used to crash (ISSUE 6 satellite).
* ``theory.epoch_len`` / ``chi_consistent`` reject ``lam_max >= n`` with
  an informative ``ValueError`` instead of a math domain error.
* ``_sequential_fused_impl`` keeps ``beta`` static by design: same beta
  hits the jit cache, a new beta adds exactly one entry.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_sparse_spd
from repro.core.engine import Schedule, scheduled_tau
from repro.core import theory

from conftest import run_forced_device_script


# ---------------------------------------------------------------------------
# Staleness accounting (pure host-side rules)
# ---------------------------------------------------------------------------

def test_scheduled_tau_overlap_term():
    # per-worker streams (GS): + (P-1) * L
    assert scheduled_tau(4, 8) == 24
    assert scheduled_tau(4, 8, overlap=True) == 48
    # shared stream (dense/banded RK): + L
    assert scheduled_tau(4, 8, shared_stream=True) == 7
    assert scheduled_tau(4, 8, shared_stream=True, overlap=True) == 15
    # local sampling (sparse RK): + (P-1) * L
    assert scheduled_tau(4, 8, local_sampling=True) == 31
    assert scheduled_tau(4, 8, local_sampling=True, overlap=True) == 55
    # P = 1: nothing is ever in flight
    for kw in ({}, {"shared_stream": True}, {"local_sampling": True}):
        assert scheduled_tau(1, 8, overlap=True, **kw) == \
            scheduled_tau(1, 8, **kw)


def test_schedule_overlap_validation():
    Schedule(rounds=4, local_steps=8, overlap=True).validate()
    with pytest.raises(ValueError, match="overlap"):
        Schedule(num_iters=16, overlap=True).validate()
    sched = Schedule(rounds=4, local_steps=8, overlap=True)
    assert sched.effective_tau(4) == 48
    assert sched.effective_tau(4, local_sampling=True) == 55
    assert Schedule(rounds=4, local_steps=8).effective_tau(4) == 24


# ---------------------------------------------------------------------------
# Theory boundary guards (satellite)
# ---------------------------------------------------------------------------

def test_theory_lam_max_boundary():
    assert theory.epoch_len(2.0, 64) > 0
    assert np.isfinite(theory.chi_consistent(0.1, 4, 2.0, 64))
    for bad in (64.0, 65.0, 0.0, -1.0):
        with pytest.raises(ValueError, match="lam_max"):
            theory.epoch_len(bad, 64)
        with pytest.raises(ValueError, match="lam_max"):
            theory.chi_consistent(0.1, 4, bad, 64)
    # just inside the boundary stays defined (large, but finite)
    assert theory.epoch_len(63.999, 64) >= 1
    assert np.isfinite(theory.chi_consistent(0.1, 2, 63.999, 64))


# ---------------------------------------------------------------------------
# Static-beta contract of the fused sequential path (satellite)
# ---------------------------------------------------------------------------

def test_fused_beta_static_recompiles():
    """``beta`` is deliberately static on the fused path (baked into the
    sweep kernel): repeating a beta must hit the jit cache, a new beta
    must add exactly one cache entry."""
    from repro.core.engine import _sequential_fused_impl, solve_sequential

    prob = random_sparse_spd(32, row_nnz=4, n_rhs=2, seed=21)
    from repro.core.operators import CsrOp
    op = CsrOp.from_dense(prob.A)
    x0 = jnp.zeros_like(prob.x_star)

    def run(beta):
        return solve_sequential(op, prob.b, x0, prob.x_star, action="gs",
                                key=jax.random.key(3), num_iters=8,
                                beta=beta, fused=True)

    run(0.5)
    base = _sequential_fused_impl._cache_size()
    run(0.5)                                        # cache hit
    assert _sequential_fused_impl._cache_size() == base
    run(0.25)                                       # one recompile
    assert _sequential_fused_impl._cache_size() == base + 1


# ---------------------------------------------------------------------------
# Forced-4-device: overlapped variants + x_star=None strategy sweep
# ---------------------------------------------------------------------------

OVERLAP_SCRIPT = textwrap.dedent("""
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import block_banded_spd, random_sparse_spd
    from repro.core.operators import BlockBandedOp, CsrOp, DenseOp
    from repro.core.engine import Schedule, scheduled_tau, solve, \\
        solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)
    P, L, rounds = 4, 8, 30
    prob = random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=2)
    cop = CsrOp.from_dense(prob.A)
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(key=jax.random.key(5), mesh=mesh, rounds=rounds,
              local_steps=L, beta=0.9)

    def check_lag(r, base_tau):
        lag = np.asarray(r.lag)
        assert lag.shape == (rounds,)
        assert lag[0] == 0, lag[:3]                  # nothing in flight yet
        assert (lag[1:] == (P - 1) * L).all(), lag   # steady payload
        # measured staleness respects the scheduled overlap bound
        assert int(lag.max()) + base_tau <= int(r.tau)

    # --- sparse_gs: overlap converges, lag as scheduled, fused bitwise ---
    r_lock = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                               sync="allgather", **kw)
    assert r_lock.lag is None
    r_ov = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                             sync="allgather", overlap=True, **kw)
    assert int(r_ov.tau) == scheduled_tau(P, L, overlap=True) == 48
    check_lag(r_ov, scheduled_tau(P, L))
    assert float(r_ov.err_sq[-1].max()) < 1e-3       # converges
    assert not jnp.array_equal(r_ov.x, r_lock.x)     # genuinely staler reads
    r_ovf = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                              sync="allgather", overlap=True, fused=True,
                              **kw)
    assert jnp.array_equal(r_ov.x, r_ovf.x)          # fused overlap bitwise
    assert jnp.array_equal(r_ov.err_sq, r_ovf.err_sq)
    # a2a overlap reads the same slabs -> identical iterates
    r_a2a = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                              sync="a2a", overlap=True, **kw)
    if not jnp.array_equal(r_a2a.x, r_ov.x):
        # dense neighbor graph fell back to allgather; still identical
        raise AssertionError("a2a overlap diverged from allgather overlap")

    # --- sparse_rk: overlap converges (final delta flushed), lag trace ---
    r_lock = solve_distributed(cop, prob.b, x0, prob.x_star, action="rk",
                               sync="psum", **kw)
    r_ov = solve_distributed(cop, prob.b, x0, prob.x_star, action="rk",
                             sync="psum", overlap=True, **kw)
    assert int(r_ov.tau) == scheduled_tau(P, L, local_sampling=True,
                                          overlap=True) == 55
    check_lag(r_ov, scheduled_tau(P, L, local_sampling=True))
    assert float(r_ov.err_sq[-1].max()) < 2e-2
    r_ovf = solve_distributed(cop, prob.b, x0, prob.x_star, action="rk",
                              sync="psum", overlap=True, fused=True, **kw)
    denom = float(jnp.linalg.norm(r_ov.x)) or 1.0
    assert float(jnp.linalg.norm(r_ov.x - r_ovf.x)) / denom <= 1e-5

    # --- halo_gs: overlapped edge exchange converges ---
    bprob = block_banded_spd(256, block=16, bands=1, n_rhs=2, seed=2)
    bop = BlockBandedOp.from_dense(bprob.A, block=16, bands=1)
    bx0 = jnp.zeros_like(bprob.x_star)
    r_ov = solve_distributed(bop, bprob.b, bx0, bprob.x_star, action="gs",
                             sync="halo", overlap=True, fused=True, **kw)
    check_lag(r_ov, scheduled_tau(P, L))
    assert float(r_ov.err_sq[-1].max()) < 1e-6

    # --- strategies without an overlapped variant: exact fallback ---
    dop = DenseOp(prob.A)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r_fb = solve_distributed(dop, prob.b, x0, prob.x_star, action="gs",
                                 sync="allgather", overlap=True, **kw)
    assert any("no overlapped-sync variant" in str(x.message) for x in w)
    r_ls = solve_distributed(dop, prob.b, x0, prob.x_star, action="gs",
                             sync="allgather", **kw)
    assert r_fb.lag is None and int(r_fb.tau) == int(r_ls.tau)
    assert jnp.array_equal(r_fb.x, r_ls.x)

    # --- front door: Schedule(overlap=True) reaches the variant ---
    r = solve(prob, key=jax.random.key(5), format="csr", mesh=mesh,
              beta=0.9, schedule=Schedule(rounds=rounds, local_steps=L,
                                          overlap=True))
    assert r.lag is not None                         # SPD -> gs -> sparse_gs
    assert int(r.tau) == scheduled_tau(P, L, overlap=True)
    print("OVERLAP_OK")
""")


@pytest.mark.slow
def test_overlap_forced_devices():
    run_forced_device_script(OVERLAP_SCRIPT, marker="OVERLAP_OK")


XSTAR_NONE_SCRIPT = textwrap.dedent("""
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import block_banded_spd
    from repro.core.operators import BlockBandedOp, CsrOp, DenseOp, EllOp
    from repro.core.engine import _DISTRIBUTED_STRATEGIES, solve_distributed
    from repro.launch.mesh import make_host_mesh

    # One square SPD system servable by every format (banded structure).
    prob = block_banded_spd(256, block=16, bands=1, n_rhs=2, seed=3)
    cop = CsrOp.from_dense(prob.A)
    ops = {
        "DenseOp": DenseOp(prob.A),
        "BlockBandedOp": BlockBandedOp.from_dense(prob.A, block=16, bands=1),
        "CsrOp": cop,
        "EllOp": EllOp(*cop.padded_rows()),
    }
    mesh = make_host_mesh(4)
    x0 = jnp.zeros_like(prob.x_star)
    for (action, fmt, sync) in sorted(_DISTRIBUTED_STRATEGIES):
        r = solve_distributed(ops[fmt], prob.b, x0, None, action=action,
                              sync=sync, key=jax.random.key(7), mesh=mesh,
                              rounds=3, local_steps=4)
        row = (action, fmt, sync)
        assert np.isnan(np.asarray(r.err_sq)).all(), row
        assert np.isfinite(np.asarray(r.resid)).all(), row
        assert np.isfinite(np.asarray(r.x)).all(), row
    print("XSTAR_NONE_OK")
""")


@pytest.mark.slow
def test_x_star_none_all_strategies():
    """solve_distributed(x_star=None) must work on every strategy row —
    the dense strategies dereferenced xs_full unconditionally (ISSUE 6
    satellite)."""
    run_forced_device_script(XSTAR_NONE_SCRIPT, marker="XSTAR_NONE_OK")
