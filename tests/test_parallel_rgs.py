"""Distributed asynchronous block-RGS (shard_map) — run in a subprocess with
8 forced host devices so the main test process keeps its single real device."""
import textwrap

import pytest

from conftest import run_script_in_subprocess

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (parallel_rgs_solve, random_sparse_spd, rgs_solve,
                            theory, effective_tau)
    from repro.launch.mesh import make_host_mesh

    prob = random_sparse_spd(512, row_nnz=8, n_rhs=2, seed=0)
    mesh = make_host_mesh(8)
    x0 = jnp.zeros_like(prob.x_star)
    rho = float(theory.rho(prob.A))
    tau = effective_tau(8, 64)
    beta = theory.beta_opt(rho, tau)

    res = parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star,
                             key=jax.random.key(0), mesh=mesh, rounds=14,
                             local_steps=64, block=1, beta=beta)
    e = np.asarray(res.err_sq)
    assert res.tau == tau
    assert e[-1].max() < 1e-2 * e[0].max(), e[:, 0]
    # monotone-ish decrease over rounds (allow small noise)
    assert (np.diff(np.log(e[:, 0])) < 0.5).all()

    # the solution actually solves the system
    resid = float(jnp.linalg.norm(prob.b - prob.A @ res.x) /
                  jnp.linalg.norm(prob.b))
    assert resid < 0.2, resid

    # block variant lowers + converges too
    res_b = parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star,
                               key=jax.random.key(1), mesh=mesh, rounds=12,
                               local_steps=16, block=4, beta=beta)
    eb = np.asarray(res_b.err_sq)
    assert eb[-1].max() < eb[0].max()
    print("PARALLEL_OK")
""")


@pytest.mark.slow
def test_parallel_rgs_8_workers():
    out = run_script_in_subprocess(SCRIPT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PARALLEL_OK" in out.stdout
