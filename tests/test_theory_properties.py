"""Property-based checks of the theory module's algebra (skipped cleanly on
a bare jax+pytest environment without hypothesis):

* beta~ = 1/(1 + 2 rho tau) lies in (0, 1] and satisfies the fixed-point
  identity nu_tau(beta~) = beta~ (Sec. 5);
* nu_tau and omega_tau are monotone non-increasing in the delay bound tau
  (more staleness never improves the guaranteed rate);
* rho and rho_2 are invariant under symmetric permutation of the matrix,
  and their RK analogues are invariant under row permutation (the rate
  cannot depend on how equations are numbered)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.core import random_sparse_spd, theory

rhos = st.floats(1e-4, 10.0, allow_nan=False, allow_infinity=False)
taus = st.integers(0, 256)
betas = st.floats(1e-3, 1.0, allow_nan=False, allow_infinity=False)


@given(rho=rhos, tau=taus)
def test_beta_opt_in_unit_interval_and_fixed_point(rho, tau):
    beta = theory.beta_opt(rho, tau)
    assert 0.0 < beta <= 1.0
    # nu_tau(beta~) = beta~: 2b - b^2(1 + 2 rho tau) = 2b - b = b
    assert theory.nu_tau(rho, tau, beta) == pytest.approx(beta, rel=1e-10)
    # tau = 0 recovers the synchronous step size
    if tau == 0:
        assert beta == 1.0


@given(rho=rhos, tau=taus, beta=betas)
def test_nu_tau_monotone_in_tau(rho, tau, beta):
    assert theory.nu_tau(rho, tau + 1, beta) <= theory.nu_tau(rho, tau, beta)


@given(rho2=rhos, tau=taus, beta=betas)
def test_omega_tau_monotone_in_tau(rho2, tau, beta):
    assert (theory.omega_tau(rho2, tau + 1, beta)
            <= theory.omega_tau(rho2, tau, beta))


@given(rho2=rhos, tau=taus)
def test_beta_opt_inconsistent_maximizes_omega(rho2, tau):
    beta = theory.beta_opt_inconsistent(rho2, tau)
    assert 0.0 < beta <= 0.5
    best = theory.omega_tau(rho2, tau, beta)
    for eps in (-1e-3, 1e-3):
        b = beta + eps
        if 0.0 < b <= 1.0:
            assert theory.omega_tau(rho2, tau, b) <= best + 1e-12


@given(seed=st.integers(0, 31), pseed=st.integers(0, 31))
def test_rho_invariant_under_symmetric_permutation(seed, pseed):
    prob = random_sparse_spd(48, row_nnz=6, seed=seed)
    perm = np.random.default_rng(pseed).permutation(48)
    Ap = prob.A[jnp.ix_(perm, perm)]
    assert float(theory.rho(Ap)) == pytest.approx(float(theory.rho(prob.A)),
                                                  rel=1e-5)
    assert float(theory.rho2(Ap)) == pytest.approx(float(theory.rho2(prob.A)),
                                                   rel=1e-5)


@given(seed=st.integers(0, 31), pseed=st.integers(0, 31))
def test_rk_rho_invariant_under_row_permutation(seed, pseed):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((40, 12)).astype(np.float32))
    perm = np.random.default_rng(pseed).permutation(40)
    Ap = A[perm, :]
    assert float(theory.rk_rho(Ap)) == pytest.approx(float(theory.rk_rho(A)),
                                                     rel=1e-4)
    assert float(theory.rk_rho2(Ap)) == pytest.approx(
        float(theory.rk_rho2(A)), rel=1e-4)
    # sampling probabilities are a distribution and rk_rho is a coherence
    # bound: p sums to 1, and 0 < rk_rho2 <= rk_rho <= 1
    p = theory.rk_row_probs(A)
    assert float(jnp.sum(p)) == pytest.approx(1.0, rel=1e-5)
    r1, r2 = float(theory.rk_rho(A)), float(theory.rk_rho2(A))
    assert 0.0 < r2 <= r1 <= 1.0 + 1e-6
