"""ShardCtx placement rules, spec/shape consistency for every arch, and the
paper's theory module (Lemma 2.1, Lanczos extremes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.core import random_sparse_spd, theory
from repro.models import transformer as T
from repro.sharding import Partitioner, ShardCtx

AXIS_SIZES = {"data": 16, "model": 16, "pod": 2, None: 1}


def test_shardctx_divisibility_rules():
    sc = ShardCtx(tp=16, dp=16)
    assert sc.col(64) == "model"
    assert sc.col(56) is None          # llava's 56 heads
    assert sc.data(48) == "data"
    assert sc.data(7) is None
    assert ShardCtx().col(64) is None  # CPU default replicates


def test_attn_tp_choice():
    sc = ShardCtx(tp=16, dp=16)
    assert sc.attn_tp(48, 1)           # granite -> Megatron TP
    assert not sc.attn_tp(40, 8)       # llama4 -> sequence parallel
    assert not sc.attn_tp(56, 8)       # llava  -> sequence parallel


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_specs_divide_shapes(arch):
    """For the FULL config under the production ShardCtx, every sharded dim
    must be divisible by its mesh axis — the invariant that makes the 16x16
    dry-run lower (checked here without any compilation)."""
    cfg = get_config(arch)
    sc = ShardCtx(tp=16, dp=16)
    cap = {}

    def build(key):
        p, s = T.init_params(cfg, key, sc)
        cap["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.random.key(0))
    specs = cap["specs"]
    flat_s, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = dict(jax.tree_util.tree_flatten_with_path(shapes)[0])
    checked = 0
    for path, spec in flat_s:
        shape = flat_p[tuple(path)].shape
        for dim, axis in zip(shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= AXIS_SIZES[a]
            assert dim % size == 0, (jax.tree_util.keystr(path), shape, spec)
            checked += 1
    assert checked > 0


@pytest.mark.parametrize("arch", ["gemma3-1b", "jamba-v0.1-52b", "whisper-small"])
def test_cache_specs_divide_shapes(arch):
    from repro.configs import SHAPES
    from repro.train import steps as ST
    cfg = get_config(arch)
    sc = ShardCtx(tp=16, dp=16)
    part = Partitioner(mesh=None, dp_axes=("data",), sc=sc)
    shape = SHAPES["decode_32k"]
    cache_shapes, cspecs = ST.abstract_cache(cfg, shape, part)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(
        cspecs, is_leaf=lambda x: isinstance(x, P))
    flat_p = dict(jax.tree_util.tree_flatten_with_path(cache_shapes)[0])
    for path, spec in flat_s:
        shp = flat_p[tuple(path)].shape
        for dim, axis in zip(shp, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= AXIS_SIZES[a]
            assert dim % size == 0, (jax.tree_util.keystr(path), shp, spec)


def test_partitioner_noop_without_mesh():
    part = Partitioner(mesh=None)
    x = jnp.ones((2, 3))
    assert part.tokens(x) is x


# -- theory -------------------------------------------------------------------

def test_lemma21_bounds_empirically():
    """lam_min/n E||e||_A^2 <= E[(e,d)_A^2] <= lam_max/n E||e||_A^2."""
    prob = random_sparse_spd(64, row_nnz=5, seed=2)
    rng = np.random.default_rng(0)
    e = jnp.asarray(rng.standard_normal(64), jnp.float32)
    Ae = prob.A @ e
    sq = np.asarray(Ae) ** 2            # (e, e_i)_A^2 for each direction i
    mean = sq.mean()
    err = float(e @ Ae)
    lo = float(prob.lam_min) / 64 * err
    hi = float(prob.lam_max) / 64 * err
    assert lo - 1e-5 <= mean <= hi + 1e-5


def test_lanczos_matches_dense_eigs():
    prob = random_sparse_spd(128, row_nnz=6, seed=4)
    lo, hi = theory.lanczos_extreme_eigs(prob.A, jax.random.key(0), iters=96)
    np.testing.assert_allclose(float(lo), float(prob.lam_min), rtol=2e-2)
    np.testing.assert_allclose(float(hi), float(prob.lam_max), rtol=2e-2)


@given(st.floats(0.01, 0.4), st.integers(0, 8))
def test_block_rho_reduces_to_rho(off, tau):
    prob = random_sparse_spd(32, row_nnz=4, offdiag=off, seed=1)
    r1 = float(theory.rho(prob.A))
    rb = float(theory.block_rho(prob.A, 1))
    np.testing.assert_allclose(r1, rb, rtol=1e-5)
    # nu_tau decreasing in tau
    assert theory.nu_tau(r1, tau) >= theory.nu_tau(r1, tau + 1)
