"""Meta-suite for repro-lint (src/repro/analysis): every checker must fire
on its known-bad fixture snippet and stay silent on the clean twin, and
the repo itself must pass ``--fail-on-new`` against the checked-in
baseline.  The fixtures are the contract: if a checker is loosened until
it misses its bad snippet, this suite — not a future regression — fails.
"""
import ast
import textwrap

from repro.analysis import (bitwise_pin, dead_modules, dispatch,
                            kernel_precision, lint, pytree_purity,
                            trace_safety)


def _codes(checker, source, path="src/repro/fixture.py"):
    tree = ast.parse(textwrap.dedent(source))
    return [f.code for f in checker.check_file(path, tree, source)]


def _repo_codes(checker, files, root="/nonexistent-fixture-root"):
    parsed = {p: (ast.parse(textwrap.dedent(s)), s) for p, s in files.items()}
    return [(f.code, f.symbol) for f in checker.check_repo(root, parsed)]


# -- kernel accumulation contract (KP) --------------------------------------

BAD_KERNEL = """
    import functools
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _bad_kernel(vals_ref, cols_ref, x_ref, o_ref, *, beta):
        vals = vals_ref[0]                       # bf16 panel, no upcast
        cols = cols_ref[0]                       # int16 panel, no widen
        contrib = vals * x_ref[cols]             # KP1 + KP4
        prod = jnp.dot(vals, x_ref[...])         # KP2 (no pet) [+KP1 arg]
        acc = jnp.zeros((8,), dtype=jnp.bfloat16)
        o_ref[0] = acc + contrib                 # KP3: bf16 accumulator

    def run(vals, cols, x):
        return pl.pallas_call(functools.partial(_bad_kernel, beta=1.0),
                              out_shape=x)(vals, cols, x)
"""

CLEAN_KERNEL = """
    import functools
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _kernel(vals_ref, cols_ref, b_ref, x_ref, o_ref, *, beta):
        vals = vals_ref[0].astype(jnp.float32)   # f32 accumulate
        cols = cols_ref[0].astype(jnp.int32)     # widen compact indices
        acc = vals * x_ref[cols] + b_ref[...]
        prod = jnp.dot(vals, x_ref[...],
                       preferred_element_type=jnp.float32)
        o_ref[0] = (beta * (acc + prod)).astype(o_ref.dtype)

    def run(vals, cols, b, x):
        return pl.pallas_call(_kernel, out_shape=x)(vals, cols, b, x)
"""


def test_kernel_precision_catches_bad_kernel():
    codes = _codes(kernel_precision, BAD_KERNEL)
    assert "KP1" in codes, codes       # raw coefficient reaches arithmetic
    assert "KP2" in codes, codes       # jnp.dot without pet=f32
    assert "KP3" in codes, codes       # explicit bf16 accumulator
    assert "KP4" in codes, codes       # raw int16 gather index


def test_kernel_precision_clean_kernel_is_silent():
    assert _codes(kernel_precision, CLEAN_KERNEL) == []


def test_kernel_precision_allows_symbolic_writeback_cast():
    src = """
        from jax.experimental import pallas as pl
        import jax.numpy as jnp

        def _kernel(a_ref, o_ref):
            acc = a_ref[0].astype(jnp.float32) * 2.0
            o_ref[0] = acc.astype(o_ref.dtype)

        def run(a, o):
            return pl.pallas_call(_kernel, out_shape=o)(a)
    """
    assert _codes(kernel_precision, src) == []


# -- dispatch exhaustiveness (DX) -------------------------------------------

BAD_ENGINE = """
    _DISTRIBUTED_STRATEGIES = {
        ("gs", "DenseOp", "allgather"): "dense_gs",
        ("gs", "EllOp", "allgather"): "sparse_gs",
        ("rk", "DenseOp", "psum"): "dense_rk",
    }
    _FUSED_STRATEGIES = frozenset({"sparse_gs", "banded_gs"})

    def solve_distributed(op, action, sync, fused):
        kind = _DISTRIBUTED_STRATEGIES.get((action, type(op).__name__, sync))
        if kind is None:
            raise NotImplementedError("gs/rk on dense or ell")
        return kind
"""


def test_dispatch_catches_hole_stale_member_missing_guard():
    found = _repo_codes(dispatch, {"src/repro/core/engine.py": BAD_ENGINE})
    codes = [c for c, _ in found]
    # ("rk", "EllOp") has no row although both appear -> the PR-3 hole shape
    assert ("DX2", "_DISTRIBUTED_STRATEGIES[rk,EllOp]") in found, found
    # "banded_gs" is not a kind the table produces
    assert "DX1" in codes, found
    # no `fused and kind not in _FUSED_STRATEGIES` warn-guard
    assert "DX3" in codes, found
    # the miss path never enumerates sorted(_DISTRIBUTED_STRATEGIES)
    assert "DX5" in codes, found


def test_dispatch_catches_duplicated_capability_literal():
    engine = """
        _DISTRIBUTED_STRATEGIES = {
            ("gs", "DenseOp", "allgather"): "dense_gs",
        }
        COMPRESS_MODES = ("none", "bf16", "int8_ef")
    """
    cli = """
        def main(ap):
            ap.add_argument("--compress", choices=("none", "bf16", "int8_ef"))
    """
    found = _repo_codes(dispatch, {"src/repro/core/engine.py": engine,
                                   "src/repro/launch/solve.py": cli})
    assert ("DX4", "literal==COMPRESS_MODES") in found, found
    # exactly one site: the defining assignment itself must not be flagged
    assert [f for f in found if f[0] == "DX4"] == [
        ("DX4", "literal==COMPRESS_MODES")], found


def test_dispatch_real_engine_is_single_sourced():
    """The shipped engine passes the dispatch checker except for the one
    baselined bypass: ``matvec_segsum`` is the forced legacy contrast
    case and intentionally never consults the tuning table — every
    capability set is live, guarded, and single-sourced."""
    root = lint.repo_root()
    parsed = {p: ts for p, ts in lint.parse_tree(root)["src"].items()
              if ts[0] is not None}
    found = [(f.code, f.symbol) for f in dispatch.check_repo(root, parsed)]
    assert found == [("DX6", "matvec_segsum")], found


BAD_SEAM = """
    from repro.kernels import ops

    def matvec(self, x):
        if self.skip:
            return ops.spmv_csr_sliced_prefetch(x)
        return ops.spmv_csr_sliced(x)
"""

CLEAN_SEAM = """
    from repro.kernels import ops
    from repro.tune import runtime as tune_runtime

    def matvec(self, x):
        if tune_runtime.matvec_variant(self) == "sliced_prefetch":
            return ops.spmv_csr_sliced_prefetch(x)
        return ops.spmv_csr_sliced(x)
"""

_SEAM_TABLE = """
    _DISTRIBUTED_STRATEGIES = {
        ("gs", "DenseOp", "allgather"): "dense_gs",
    }
"""


def test_dispatch_catches_hardcoded_variant_choice():
    found = _repo_codes(dispatch, {
        "src/repro/core/engine.py": _SEAM_TABLE,
        "src/repro/core/operators.py": BAD_SEAM})
    assert ("DX6", "matvec") in found, found


def test_dispatch_table_consulting_seam_is_silent():
    found = _repo_codes(dispatch, {
        "src/repro/core/engine.py": _SEAM_TABLE,
        "src/repro/core/operators.py": CLEAN_SEAM})
    assert [f for f in found if f[0] == "DX6"] == [], found


def test_dispatch_dx6_exempts_kernel_and_tune_modules():
    found = _repo_codes(dispatch, {
        "src/repro/core/engine.py": _SEAM_TABLE,
        "src/repro/kernels/ops.py": BAD_SEAM,
        "src/repro/tune/autotune.py": BAD_SEAM})
    assert [f for f in found if f[0] == "DX6"] == [], found


# -- pytree purity (PT) -----------------------------------------------------

BAD_PYTREE = """
    import jax.numpy as jnp
    from jax.tree_util import register_pytree_node_class

    @register_pytree_node_class
    class BadOp:
        def tree_flatten(self):
            aux = (self.shape, [1, 2], jnp.asarray(self.scale), self.vals)
            return (self.vals,), aux

        def tree_unflatten(cls, aux, leaves):
            return cls()

    @register_pytree_node_class
    class HalfOp:
        def tree_flatten(self):
            return (self.x,), None
"""


def test_pytree_purity_catches_bad_aux():
    codes = _codes(pytree_purity, BAD_PYTREE)
    assert "PT2" in codes, codes    # unhashable [1, 2] literal in aux
    assert "PT3" in codes, codes    # jnp.asarray(...) feeding aux
    assert "PT4" in codes, codes    # self.vals in both leaves and aux
    assert "PT1" in codes, codes    # HalfOp missing tree_unflatten


def test_pytree_purity_unregistered_flatten_flagged():
    src = """
        class Ghost:
            def tree_flatten(self):
                return (self.x,), None

            def tree_unflatten(cls, aux, leaves):
                return cls()
    """
    assert _codes(pytree_purity, src) == ["PT1"]


def test_pytree_purity_real_operators_are_clean():
    import os
    root = lint.repo_root()
    path = os.path.join(root, "src", "repro", "core", "operators.py")
    tree, src = lint.parse_file(path)
    assert pytree_purity.check_file("src/repro/core/operators.py",
                                    tree, src) == []


# -- trace safety (TS) ------------------------------------------------------

BAD_TRACED = """
    import functools
    import time
    import jax
    import numpy as np

    @functools.partial(jax.jit, static_argnames=("flag",))
    def impl(x, y, flag):
        t0 = time.time()                    # TS1
        noise = np.random.rand(4)           # TS2
        if x.sum() > 0:                     # TS3: branch on traced value
            y = y + noise
        if flag:                            # static_argnames: fine
            y = y * 2
        if x is not None:                   # structural: fine
            y = y + 1
        return y + t0
"""


def test_trace_safety_catches_bad_region():
    codes = _codes(trace_safety, BAD_TRACED)
    assert codes.count("TS3") == 1, codes   # only the traced `if`, not flag
    assert "TS1" in codes, codes
    assert "TS2" in codes, codes


def test_trace_safety_static_patterns_are_silent():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("action", "block"))
        def impl(op, xs_full, action, block):
            if xs_full is not None:
                y = xs_full
            if action == "gs" and block > 1:
                y = y + 1
            if isinstance(op, tuple):
                y = y * 2
            if op.shape[0] > 4:
                y = y - 1
            return y
    """
    assert _codes(trace_safety, src) == []


def test_trace_safety_nested_worker_params_are_traced():
    src = """
        import jax
        from repro.compat import shard_map

        def solve(op, mesh):
            def worker(x_slab, b_slab):
                if x_slab.sum() > 0:        # TS3 inside the worker
                    b_slab = b_slab + 1
                return b_slab
            return shard_map(worker, mesh=mesh)(op, op)
    """
    assert _codes(trace_safety, src) == ["TS3"]


# -- bitwise pin (BP) -------------------------------------------------------

def test_bitwise_pin_catches_allclose_under_bitwise_name():
    src = """
        import numpy as np

        def test_overlap_bitwise_vs_lockstep():
            a, b = make()
            np.testing.assert_allclose(a, b, rtol=1e-5)
    """
    assert _codes(bitwise_pin, src, "tests/test_fixture.py") == ["BP1", "BP2"]


def test_bitwise_pin_catches_docstring_claim_without_exact_compare():
    src = """
        def test_overlap_matches():
            '''overlap=True is bitwise-identical to the lockstep sync.'''
            a, b = make()
            assert abs(a - b).max() < 1e-6
    """
    assert _codes(bitwise_pin, src, "tests/test_fixture.py") == ["BP2"]


def test_bitwise_pin_accepts_exact_and_zero_tolerance():
    src = """
        import numpy as np

        def test_a2a_bitwise_identical():
            a, b = make()
            np.testing.assert_array_equal(a, b)

        def test_halo_bitwise_pin():
            a, b = make()
            np.testing.assert_allclose(a, b, rtol=0, atol=0)
    """
    assert _codes(bitwise_pin, src, "tests/test_fixture.py") == []


def test_bitwise_pin_reads_module_level_subprocess_scripts():
    src = '''
    SCRIPT = """
    import jax.numpy as jnp
    ra, rp = run_both()
    assert bool(jnp.array_equal(ra.x, rp.x))
    print("OK")
    """

    def test_rk_bitwise_forced_devices():
        run_forced_device_script(SCRIPT, marker="OK")
    '''
    assert _codes(bitwise_pin, src, "tests/test_fixture.py") == []


# -- dead modules (DM) ------------------------------------------------------

def test_dead_modules_flags_unreachable_template():
    files = {
        "src/repro/__init__.py": "",
        "src/repro/core/__init__.py": "from repro.core import engine",
        "src/repro/core/engine.py": "",
        "src/repro/models/__init__.py": "from repro.models import transformer",
        "src/repro/models/transformer.py": "",
    }
    found = _repo_codes(dead_modules, files)
    symbols = {s for _c, s in found}
    assert "repro.models" in symbols, found
    assert "repro.models.transformer" in symbols, found
    assert "repro.core.engine" not in symbols, found


def test_dead_modules_repo_has_only_baselined_survivors():
    """After the PR-8 prune, the only unreachable src module is the
    baselined banded test oracle."""
    assert dead_modules.unreachable_modules() == ["repro.kernels.ref_banded"]


# -- runner / baseline ------------------------------------------------------

def test_finding_key_excludes_line_numbers():
    from repro.analysis.common import Finding
    a = Finding(code="KP1", path="src/x.py", line=10, symbol="f", message="m")
    b = Finding(code="KP1", path="src/x.py", line=99, symbol="f", message="m")
    assert a.key == b.key


def test_repo_passes_fail_on_new_against_checked_in_baseline():
    assert lint.main(["--fail-on-new"]) == 0


def test_fail_on_new_rejects_unbaselined_findings(tmp_path):
    empty = tmp_path / "baseline.json"
    empty.write_text('{"findings": []}')
    # the repo currently carries (exactly) the baselined findings, so an
    # empty baseline must fail the gate
    assert lint.main(["--fail-on-new", "--baseline", str(empty)]) == 1
