"""Synchronous randomized Gauss-Seidel: the Leventhal-Lewis rate (paper
eq. 2), multi-RHS behaviour, the unit-diagonal reduction (Sec. 2.3), and the
TPU block variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (a_norm_sq, block_gs_solve, random_sparse_spd,
                        rgs_general, rgs_solve, theory, to_unit_diagonal)


@pytest.fixture(scope="module")
def prob():
    return random_sparse_spd(192, row_nnz=6, n_rhs=3, seed=3)


def test_monotone_expected_decrease(prob):
    """Error decreases at (close to) the proven linear rate, averaged over
    seeds.  E_m <= (1 - lam_min/n)^m E_0  (paper eq. 2)."""
    n = prob.n
    m = 4 * n
    errs = []
    for seed in range(8):
        res = rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                        prob.x_star, key=jax.random.key(seed),
                        num_iters=m, record_every=m)
        errs.append(np.asarray(res.err_sq[-1]))
    e0 = np.asarray(a_norm_sq(prob.A, -prob.x_star))
    bound = float(theory.ll_bound(1.0, m, float(prob.lam_min), n))
    mean_ratio = np.mean(errs, axis=0) / e0
    # Expectation bound with generous slack for 8-seed averaging noise.
    assert np.all(mean_ratio <= 3.0 * bound), (mean_ratio, bound)
    assert np.all(mean_ratio < 1e-1)


def test_converges_to_solution(prob):
    res = rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star), prob.x_star,
                    key=jax.random.key(0), num_iters=30 * prob.n)
    assert float(res.resid[-1].max()) < 1e-3


def test_multi_rhs_matches_single(prob):
    """Each RHS column evolves independently given shared directions."""
    res_all = rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                        prob.x_star, key=jax.random.key(7), num_iters=256)
    res_one = rgs_solve(prob.A, prob.b[:, :1],
                        jnp.zeros_like(prob.x_star[:, :1]),
                        prob.x_star[:, :1], key=jax.random.key(7),
                        num_iters=256)
    np.testing.assert_allclose(np.asarray(res_all.x[:, 0]),
                               np.asarray(res_one.x[:, 0]), atol=1e-5)


def test_unit_diagonal_reduction():
    """Sec 2.3: general iteration on B == unit-diagonal iteration on DBD
    with y = D x (same directions)."""
    rng = np.random.default_rng(0)
    G = rng.standard_normal((48, 48))
    B = G @ G.T + 8 * np.eye(48)
    Bj = jnp.asarray(B, jnp.float32)
    A, d = to_unit_diagonal(Bj)
    z = jnp.asarray(rng.standard_normal((48, 1)), jnp.float32)
    coords = jax.random.randint(jax.random.key(5), (400,), 0, 48)
    y = rgs_general(Bj, z, jnp.zeros((48, 1), jnp.float32), coords=coords,
                    num_iters=400)
    # unit-diagonal run on A x = D z
    bz = d[:, None] * z
    x_star = jnp.linalg.solve(A, bz)
    from repro.core.rgs import SolveResult  # reuse scan path via explicit loop
    x = jnp.zeros((48, 1), jnp.float32)
    for r in np.asarray(coords):
        x = x.at[r].add(bz[r] - A[r] @ x)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray((d[:, None] * x)[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_block_gs_converges(prob):
    res = block_gs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                         prob.x_star, key=jax.random.key(1), num_sweeps=30,
                         block=32, beta=0.9)
    assert float(res.resid[-1].max()) < 1e-2
    assert float(res.err_sq[-1].max()) < float(res.err_sq[0].max())
