"""Bit-exact equivalence of every sequential/simulator legacy entry point
against the frozen pre-refactor implementations (tests/legacy_solvers.py).

The refactor's contract (ISSUE 2): the unified engine behind ``rgs_solve``,
``block_gs_solve``, ``rk_solve``, ``async_rgs_solve``, ``async_rk_solve``
must reproduce the pre-refactor iterates BIT-FOR-BIT given the same PRNG
keys — same sampling, same operation order, same dtypes.  ``array_equal``,
not ``allclose``.  (The distributed entry points are pinned the same way in
test_engine_distributed.py, which needs forced multi-device subprocesses.)
"""
import jax
import jax.numpy as jnp
import pytest

import legacy_solvers as legacy
from repro.core import (async_rgs_solve, async_rk_solve, block_gs_solve,
                        random_lsq, random_sparse_spd, rgs_solve, rk_solve)


@pytest.fixture(scope="module")
def spd_prob():
    return random_sparse_spd(96, row_nnz=6, n_rhs=3, seed=3)


@pytest.fixture(scope="module")
def lsq_prob():
    return random_lsq(120, 24, n_rhs=2, noise=0.01, seed=1)


def _assert_same(new, old):
    assert bool(jnp.array_equal(new.x, old.x)), \
        float(jnp.abs(new.x - old.x).max())
    assert bool(jnp.array_equal(new.err_sq, old.err_sq))
    assert bool(jnp.array_equal(new.resid, old.resid))
    assert bool(jnp.array_equal(new.iters, old.iters))


def test_rgs_solve_bit_identical(spd_prob):
    x0 = jnp.zeros_like(spd_prob.x_star)
    kw = dict(key=jax.random.key(7), num_iters=192, record_every=96)
    _assert_same(rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star, **kw),
                 legacy.rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star,
                                  **kw))
    # damped step and end-only recording
    kw = dict(key=jax.random.key(9), num_iters=100, beta=0.6)
    _assert_same(rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star, **kw),
                 legacy.rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star,
                                  **kw))


def test_block_gs_solve_bit_identical(spd_prob):
    x0 = jnp.zeros_like(spd_prob.x_star)
    for block, beta in ((16, 0.9), (32, 1.0)):
        kw = dict(key=jax.random.key(2), num_sweeps=3, block=block, beta=beta)
        _assert_same(
            block_gs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star, **kw),
            legacy.block_gs_solve(spd_prob.A, spd_prob.b, x0,
                                  spd_prob.x_star, **kw))


def test_rk_solve_bit_identical(lsq_prob):
    x0 = jnp.zeros_like(lsq_prob.x_star)
    kw = dict(key=jax.random.key(5), num_iters=600, record_every=200)
    _assert_same(rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star, **kw),
                 legacy.rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star,
                                 **kw))
    kw = dict(key=jax.random.key(6), num_iters=250, beta=0.75)
    _assert_same(rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star, **kw),
                 legacy.rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star,
                                 **kw))


@pytest.mark.parametrize("read_model,delay_mode", [
    ("consistent", "fixed"),
    ("consistent", "uniform"),
    ("consistent", "cyclic"),
    ("inconsistent", "fixed"),
])
def test_async_rgs_bit_identical(spd_prob, read_model, delay_mode):
    x0 = jnp.zeros_like(spd_prob.x_star)
    kw = dict(key=jax.random.key(1), delay_key=jax.random.key(2),
              num_iters=200, tau=8, beta=0.7, read_model=read_model,
              delay_mode=delay_mode, record_every=100)
    _assert_same(
        async_rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star, **kw),
        legacy.async_rgs_solve(spd_prob.A, spd_prob.b, x0, spd_prob.x_star,
                               **kw))


@pytest.mark.parametrize("read_model", ["consistent", "inconsistent"])
def test_async_rk_bit_identical(lsq_prob, read_model):
    x0 = jnp.zeros_like(lsq_prob.x_star)
    kw = dict(key=jax.random.key(3), delay_key=jax.random.key(4),
              num_iters=300, tau=6, beta=0.8, read_model=read_model)
    _assert_same(
        async_rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star, **kw),
        legacy.async_rk_solve(lsq_prob.A, lsq_prob.b, x0, lsq_prob.x_star,
                              **kw))


def test_schedule_helpers_deduplicated():
    """effective_tau / rk_effective_tau are one engine helper now."""
    from repro.core import effective_tau, rk_effective_tau, scheduled_tau
    for p in (1, 2, 8):
        for ls in (1, 5, 64):
            assert effective_tau(p, ls) == scheduled_tau(p, ls) \
                == legacy.effective_tau(p, ls)
            assert rk_effective_tau(p, ls) \
                == scheduled_tau(p, ls, shared_stream=True) \
                == legacy.rk_effective_tau(p, ls)
