"""Engine validation sweep (ISSUE 3 + 4 satellites): record_every
divisibility raises ValueError naming both values, ambiguous Schedules are
rejected, sample_rows has defined behavior on all-zero row-norm slabs, the
distributed dispatch error enumerates the supported combinations, the EllOp
GS dispatch hole is closed (format-generic slab path, runs even at P=1),
and solve_async_sim warns — every call, not just at trace time — when it
densifies a sparse operator (the simulator ignores nnz_cost)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CsrOp, DenseOp, EllOp, Schedule, random_sparse_spd,
                        solve)
from repro.core.engine import (sample_rows, solve_async_sim, solve_distributed,
                               solve_sequential)
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def prob():
    return random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=0)


def test_sequential_record_every_value_error(prob):
    x0 = jnp.zeros_like(prob.x_star)
    with pytest.raises(ValueError, match=r"100.*must be divisible.*32"):
        solve_sequential(DenseOp(prob.A), prob.b, x0, prob.x_star,
                         action="gs", key=jax.random.key(0), num_iters=100,
                         record_every=32)


def test_async_sim_record_every_value_error(prob):
    x0 = jnp.zeros_like(prob.x_star)
    with pytest.raises(ValueError, match=r"100.*must be divisible.*32"):
        solve_async_sim(DenseOp(prob.A), prob.b, x0, prob.x_star,
                        action="gs", key=jax.random.key(0),
                        delay_key=jax.random.key(1), num_iters=100, tau=4,
                        record_every=32)


def test_schedule_rejects_ambiguous_modes(prob):
    # both sequential and distributed fields set: no single meaning
    with pytest.raises(ValueError, match="ambiguous"):
        solve(prob, key=jax.random.key(0),
              schedule=Schedule(num_iters=64, rounds=2, local_steps=4))
    with pytest.raises(ValueError, match="ambiguous"):
        Schedule(tau=4, rounds=2, local_steps=4).validate()
    # distributed without local_steps
    with pytest.raises(ValueError, match="local_steps"):
        solve(prob, key=jax.random.key(0), schedule=Schedule(rounds=2))
    # neither mode
    with pytest.raises(ValueError, match="num_iters"):
        solve(prob, key=jax.random.key(0), schedule=Schedule())
    # local_steps without rounds
    with pytest.raises(ValueError, match="local_steps without rounds"):
        solve(prob, key=jax.random.key(0),
              schedule=Schedule(num_iters=64, local_steps=4))
    # a well-formed sequential schedule still validates
    assert Schedule(num_iters=64).validate() == Schedule(num_iters=64)


def test_sample_rows_all_zero_slab_defined():
    """All-zero row norms (an empty shard after partitioning) must produce
    valid indices — defined as uniform sampling — not -inf-logit garbage."""
    picks = sample_rows(jax.random.key(0), jnp.zeros((16,)), 256)
    p = np.asarray(picks)
    assert p.min() >= 0 and p.max() < 16
    assert np.unique(p).size > 8          # uniform, not a constant
    # ...and positive-mass behavior is unchanged: zero rows never picked
    rn = jnp.asarray([0.0, 1.0, 0.0, 3.0])
    p2 = np.asarray(sample_rows(jax.random.key(1), rn, 512))
    assert set(np.unique(p2)) <= {1, 3}


def test_async_sim_densify_warns(prob):
    """The bounded-delay simulator silently ran sparse operators on their
    densified form; it now says so (and still produces the exact densified
    iterates — to_dense reconstructs stored values bit-for-bit)."""
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(action="gs", key=jax.random.key(0),
              delay_key=jax.random.key(1), num_iters=64, tau=4)
    with pytest.warns(UserWarning, match="densifies CsrOp.*nnz_cost"):
        rc = solve_async_sim(CsrOp.from_dense(prob.A), prob.b, x0,
                             prob.x_star, **kw)
    # the warning fires on EVERY call (it lives outside the jitted impl,
    # so jit caching cannot swallow it)
    with pytest.warns(UserWarning, match="densifies CsrOp"):
        solve_async_sim(CsrOp.from_dense(prob.A), prob.b, x0, prob.x_star,
                        **kw)
    with pytest.warns(UserWarning, match="densifies EllOp"):
        re = solve_async_sim(EllOp.from_dense(prob.A, width=32), prob.b, x0,
                             prob.x_star, **kw)
    # densification is exact: sparse-format runs equal the dense run
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # DenseOp must NOT warn
        rd = solve_async_sim(DenseOp(prob.A), prob.b, x0, prob.x_star, **kw)
    assert bool(jnp.array_equal(rc.x, rd.x))
    assert bool(jnp.array_equal(re.x, rd.x))


def test_dispatch_error_enumerates_supported(prob):
    mesh = make_host_mesh(1)
    x0 = jnp.zeros_like(prob.x_star)
    with pytest.raises(NotImplementedError) as ei:
        solve_distributed(DenseOp(prob.A), prob.b, x0, prob.x_star,
                          action="gs", sync="halo", key=jax.random.key(0),
                          mesh=mesh, rounds=2, local_steps=4)
    msg = str(ei.value)
    assert "supported combinations" in msg
    assert "BlockBandedOp" in msg and "CsrOp" in msg and "psum" in msg
    # a2a on a format without slab-neighbor metadata hits the same
    # enumerating error, not an AttributeError from the a2a prep
    with pytest.raises(NotImplementedError, match="supported combinations"):
        solve_distributed(DenseOp(prob.A), prob.b, x0, prob.x_star,
                          action="gs", sync="a2a", key=jax.random.key(0),
                          mesh=mesh, rounds=2, local_steps=4)
    # distributed block-GS is not silently downgraded to coordinate GS on
    # the sparse strategies
    with pytest.raises(NotImplementedError, match="block"):
        solve_distributed(CsrOp.from_dense(prob.A), prob.b, x0, prob.x_star,
                          action="gs", sync="allgather", block=16,
                          key=jax.random.key(0), mesh=mesh, rounds=2,
                          local_steps=4)


def test_ell_gs_distributed_dispatch_hole_closed(prob):
    """EllOp x action="gs" x sync="allgather" used to die in
    NotImplementedError; it now routes through the format-generic sparse
    slab path and tracks the dense strategy."""
    mesh = make_host_mesh(1)
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(action="gs", key=jax.random.key(2), mesh=mesh, rounds=4,
              local_steps=16, beta=0.8)
    eop = EllOp.from_dense(prob.A, width=32)      # width >= row_nnz: exact
    re = solve_distributed(eop, prob.b, x0, prob.x_star, sync="allgather",
                           **kw)
    rd = solve_distributed(DenseOp(prob.A), prob.b, x0, prob.x_star,
                           sync="allgather", **kw)
    assert float(jnp.abs(re.x - rd.x).max()) < 1e-4
    np.testing.assert_allclose(np.asarray(re.resid), np.asarray(rd.resid),
                               rtol=1e-3, atol=1e-5)
    # CSR goes through the same generic path
    rc = solve_distributed(CsrOp.from_dense(prob.A), prob.b, x0, prob.x_star,
                           sync="allgather", **kw)
    assert float(jnp.abs(rc.x - rd.x).max()) < 1e-4
