"""Asynchronous RGS under the bounded-delay model: the exact per-iteration
identity (eq. 7/14), Theorem 4.1/6.1 rate validation, and the step-size
theory of Sec. 5."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.core import (a_norm_sq, async_rgs_solve, iteration_identity_gap,
                        random_sparse_spd, rgs_solve, theory)


@pytest.fixture(scope="module")
def prob():
    return random_sparse_spd(160, row_nnz=6, n_rhs=2, seed=1)


@given(r=st.integers(0, 39), beta=st.floats(0.2, 1.0), seed=st.integers(0, 10**6))
def test_iteration_identity_eq7_eq14(r, beta, seed):
    """||x_{j+1}-x*||_A^2 identity holds exactly for ANY stale read."""
    prob = random_sparse_spd(40, row_nnz=4, seed=9)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(40), jnp.float32)
    x_stale = jnp.asarray(rng.standard_normal(40), jnp.float32)
    lhs, rhs = iteration_identity_gap(prob.A, prob.b[:, 0], x,
                                      prob.x_star[:, 0], x_stale, r, beta)
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=2e-4, atol=2e-4)


def test_tau0_matches_sync(prob):
    """tau=0 async == synchronous RGS bit-for-bit (same direction stream)."""
    k = jax.random.key(3)
    a = rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star), prob.x_star,
                  key=k, num_iters=300)
    b = async_rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                        prob.x_star, key=k, delay_key=jax.random.key(4),
                        num_iters=300, tau=0)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))


@pytest.mark.parametrize("delay_mode", ["fixed", "uniform", "cyclic"])
def test_consistent_read_converges(prob, delay_mode):
    tau = 8
    rho = float(theory.rho(prob.A))
    assert 2 * rho * tau < 1, "test problem must satisfy Thm 4.1's condition"
    m = 6 * prob.n
    res = async_rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                          prob.x_star, key=jax.random.key(0),
                          delay_key=jax.random.key(1), num_iters=m, tau=tau,
                          delay_mode=delay_mode)
    e0 = float(a_norm_sq(prob.A, -prob.x_star).max())
    assert float(res.err_sq[-1].max()) < 1e-2 * e0


def test_thm41a_epoch_factor(prob):
    """After an epoch of ~0.693 n / lam_max iterations, the measured expected
    error is below the Thm 4.1(a) factor (with seed-averaging slack)."""
    tau = 6
    rho = float(theory.rho(prob.A))
    kappa = float(prob.kappa)
    m = max(theory.epoch_len(float(prob.lam_max), prob.n), prob.n)
    factor = theory.thm41a_factor(rho, tau, kappa)
    assert 0 < factor < 1
    ratios = []
    for seed in range(6):
        res = async_rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                              prob.x_star, key=jax.random.key(10 + seed),
                              delay_key=jax.random.key(100 + seed),
                              num_iters=m, tau=tau, delay_mode="uniform")
        e0 = float(a_norm_sq(prob.A, -prob.x_star).max())
        ratios.append(float(res.err_sq[-1].max()) / e0)
    assert np.mean(ratios) <= factor * 1.25, (np.mean(ratios), factor)


def test_inconsistent_read_with_step_size(prob):
    """Thm 6.1: inconsistent reads converge with the optimal beta."""
    tau = 6
    rho2 = float(theory.rho2(prob.A))
    beta = theory.beta_opt_inconsistent(rho2, tau)
    assert theory.omega_tau(rho2, tau, beta) > 0
    m = 8 * prob.n
    res = async_rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                          prob.x_star, key=jax.random.key(2),
                          delay_key=jax.random.key(3), num_iters=m, tau=tau,
                          beta=beta, read_model="inconsistent", miss_prob=0.5)
    e0 = float(a_norm_sq(prob.A, -prob.x_star).max())
    assert float(res.err_sq[-1].max()) < 5e-2 * e0


def test_step_size_rescues_large_tau():
    """Sec. 5: for tau with 2*rho*tau > 1 (Thm 4.1 inapplicable), beta~ still
    converges while beta=1 with worst-case delays can stall or diverge."""
    prob = random_sparse_spd(96, row_nnz=12, offdiag=0.95, seed=5, n_rhs=1)
    rho = float(theory.rho(prob.A))
    tau = int(np.ceil(1.2 / (2 * rho)))      # violates 2 rho tau < 1
    beta = theory.beta_opt(rho, tau)
    m = 12 * prob.n
    damped = async_rgs_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star),
                             prob.x_star, key=jax.random.key(0),
                             delay_key=jax.random.key(1), num_iters=m,
                             tau=tau, beta=beta, delay_mode="fixed")
    e0 = float(a_norm_sq(prob.A, -prob.x_star).max())
    assert float(damped.err_sq[-1].max()) < 0.2 * e0


def test_theory_formulas():
    assert theory.nu_tau(0.1, 2, 1.0) == pytest.approx(1 - 2 * 0.1 * 2)
    b = theory.beta_opt(0.1, 2)
    assert b == pytest.approx(1 / 1.4)
    assert theory.nu_tau(0.1, 2, b) == pytest.approx(b, rel=1e-6)
    assert theory.beta_opt_inconsistent(0.2, 3) == pytest.approx(1 / (2 + 0.2 * 9))
