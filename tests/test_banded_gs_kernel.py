"""banded_gs Pallas kernel vs oracle + vs the halo solver's step math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_banded_spd
from repro.kernels.banded_gs import banded_gs_sweep, pack_bands_local
from repro.kernels.bbmv import dense_to_bands
from repro.kernels.ref_banded import banded_gs_sweep_ref


@pytest.mark.parametrize("block,bands,k,dtype", [
    (128, 1, 8, jnp.float32),
    (128, 2, 64, jnp.float32),
    (256, 1, 16, jnp.float32),
    (128, 2, 16, jnp.bfloat16),
])
def test_kernel_matches_oracle(block, bands, k, dtype):
    nb_local = 4
    nb = nb_local                      # single-worker window
    n = nb * block
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=0)
    Ab = dense_to_bands(prob.A, bands=bands, block=block)
    Ab = pack_bands_local(Ab, 0, nb_local, nb, bands).astype(dtype)
    b = prob.b.astype(dtype)
    halo = bands * block
    xw = jnp.pad(jnp.zeros_like(b), ((halo, halo), (0, 0)))
    picks = jax.random.randint(jax.random.key(1), (10,), 0, nb_local)
    out = banded_gs_sweep(Ab, b, xw, picks, block=block, bands=bands,
                          beta=0.9, interpret=True)
    want = banded_gs_sweep_ref(Ab, b, xw, picks, block=block, bands=bands,
                               beta=0.9)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_kernel_sweeps_solve_banded_system():
    """Repeated kernel sweeps drive the banded system's residual down (the
    single-worker tau=0 limit of the halo solver)."""
    block, bands, k = 128, 2, 8
    nb = 6
    n = nb * block
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=3)
    Ab_g = dense_to_bands(prob.A, bands=bands, block=block)
    Ab = pack_bands_local(Ab_g, 0, nb, nb, bands)
    halo = bands * block
    xw = jnp.pad(jnp.zeros_like(prob.b), ((halo, halo), (0, 0)))
    for sweep in range(30):
        picks = jax.random.permutation(jax.random.key(sweep), nb)
        xw = banded_gs_sweep(Ab, prob.b, xw, picks, block=block, bands=bands,
                             beta=1.0, interpret=True)
    x = xw[halo:halo + n]
    resid = float(jnp.linalg.norm(prob.b - prob.A @ x) /
                  jnp.linalg.norm(prob.b))
    assert resid < 1e-3, resid
    # halo stays untouched (the kernel only writes own rows)
    assert float(jnp.abs(xw[:halo]).max()) == 0.0
    assert float(jnp.abs(xw[halo + n:]).max()) == 0.0