import os
import subprocess
import sys

# Tests run against the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

# hypothesis is an optional dev dependency (requirements-dev.txt).  Tier-1
# must collect and run on a bare jax+pytest environment: register the ci
# profile only when hypothesis is importable; property-based test modules
# guard themselves with pytest.importorskip("hypothesis").
try:
    from hypothesis import settings  # noqa: E402
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess / forced multi-device)")


def run_in_subprocess(argv, *, timeout=600):
    """Run ``argv`` in a fresh interpreter from the repo root.

    The subprocess gets PYTHONPATH=src and is pinned to the CPU backend:
    the forced host-platform placeholder devices these tests rely on are
    CPU devices, and letting jax probe a (libtpu-equipped but TPU-less)
    image first can hang for minutes on multi-host discovery.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(argv, env=env, capture_output=True, text=True,
                          timeout=timeout,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def run_script_in_subprocess(script, *, timeout=600):
    """``run_in_subprocess`` for an inline ``python -c`` test script."""
    return run_in_subprocess([sys.executable, "-c", script], timeout=timeout)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
