import os
import subprocess
import sys
import textwrap

# Tests run against the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

# hypothesis is an optional dev dependency (requirements-dev.txt).  Tier-1
# must collect and run on a bare jax+pytest environment: register the ci
# profile only when hypothesis is importable; property-based test modules
# guard themselves with pytest.importorskip("hypothesis").
try:
    from hypothesis import settings  # noqa: E402
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess / forced multi-device)")


def run_in_subprocess(argv, *, timeout=600):
    """Run ``argv`` in a fresh interpreter from the repo root.

    The subprocess gets PYTHONPATH=src and is pinned to the CPU backend:
    the forced host-platform placeholder devices these tests rely on are
    CPU devices, and letting jax probe a (libtpu-equipped but TPU-less)
    image first can hang for minutes on multi-host discovery.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(argv, env=env, capture_output=True, text=True,
                          timeout=timeout,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def run_script_in_subprocess(script, *, timeout=600):
    """``run_in_subprocess`` for an inline ``python -c`` test script."""
    return run_in_subprocess([sys.executable, "-c", script], timeout=timeout)


def run_forced_device_script(script, *, num_devices=4, marker=None,
                             timeout=600):
    """Run a test script on a forced ``num_devices``-device host platform.

    The shared fixture of every multi-worker engine test: XLA_FLAGS must be
    set before jax imports, so the script runs in a fresh interpreter with
    the forced-device preamble prepended (the main test process keeps its
    single real device).  Asserts success; when ``marker`` is given, also
    asserts the script printed it (the proof it ran to its last line rather
    than silently exiting early).  Returns the completed process for any
    extra stdout checks.
    """
    preamble = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={num_devices}"\n'
    )
    out = run_script_in_subprocess(preamble + textwrap.dedent(script),
                                   timeout=timeout)
    assert out.returncode == 0, (out.stderr[-3000:], out.stdout[-500:])
    if marker is not None:
        assert marker in out.stdout, out.stdout
    return out


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
