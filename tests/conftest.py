import os

# Tests run against the single real CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
