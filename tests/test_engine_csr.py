"""CsrOp distributed paths on a forced 4-device host mesh (subprocess),
mirroring test_engine_distributed.py: the neighbor all-to-all sync strategy
(`sync="a2a"`) produces iterates IDENTICAL to all-gather and tracks the
dense reference; the dense-graph fallback is exact; per-worker local-
sampling CSR Kaczmarz converges on the sparse reference scenario and
reports the shared-stream scheduled staleness."""
import pytest

from conftest import run_forced_device_script

A2A_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (CsrOp, DenseOp, EllOp, Schedule,
                            block_banded_spd, random_sparse_lsq,
                            random_sparse_spd, solve)
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)

    # --- banded-structure CSR: a genuinely sparse neighbor graph ----------
    bb = block_banded_spd(512, block=16, bands=1, n_rhs=3, seed=2)
    cop = CsrOp.from_dense(bb.A)
    need = cop.slab_neighbors(4)
    assert need.diagonal().all() and not need[0, 2] and not need[0, 3], need
    x0 = jnp.zeros_like(bb.x_star)
    kw = dict(action="gs", key=jax.random.key(5), mesh=mesh, rounds=7,
              local_steps=20, beta=0.7)

    ra = solve_distributed(cop, bb.b, x0, bb.x_star, sync="a2a", **kw)
    rg = solve_distributed(cop, bb.b, x0, bb.x_star, sync="allgather", **kw)
    # a2a leaves exactly the never-read slabs stale: iterates and metrics
    # are bitwise identical to the all-gather strategy
    assert bool(jnp.array_equal(ra.x, rg.x))
    assert bool(jnp.array_equal(ra.err_sq, rg.err_sq))
    assert bool(jnp.array_equal(ra.resid, rg.resid))

    # sync="auto" picks a2a for an operator with slab-neighbor metadata
    rauto = solve_distributed(cop, bb.b, x0, bb.x_star, **kw)
    assert bool(jnp.array_equal(rauto.x, ra.x))

    # ...and the CSR slab strategy tracks the dense all-gather reference
    rd = solve_distributed(DenseOp(bb.A), bb.b, x0, bb.x_star,
                           sync="allgather", **kw)
    assert float(jnp.abs(ra.x - rd.x).max()) < 1e-4
    assert np.allclose(np.asarray(ra.err_sq), np.asarray(rd.err_sq),
                       rtol=1e-3, atol=1e-5)
    # the solve makes progress (A-norm error drops monotonically-ish; 7
    # rounds x 20 coordinate updates is ~one sweep of each 128-row slab)
    e = np.asarray(ra.err_sq)
    assert e[-1].max() < 0.6 * e[0].max(), e[:, 0]

    # EllOp rides the same format-generic path, a2a included
    eop = EllOp.from_dense(bb.A, width=48)
    re = solve_distributed(eop, bb.b, x0, bb.x_star, sync="a2a", **kw)
    assert float(jnp.abs(re.x - ra.x).max()) < 1e-4

    # --- dense neighbor graph: a2a falls back to all-gather, exactly ------
    sp = random_sparse_spd(256, row_nnz=8, n_rhs=2, seed=0)
    cop2 = CsrOp.from_dense(sp.A)
    assert cop2.slab_neighbors(4).all()
    y0 = jnp.zeros_like(sp.x_star)
    kw2 = dict(action="gs", key=jax.random.key(1), mesh=mesh, rounds=5,
               local_steps=8, beta=0.9)
    f_a = solve_distributed(cop2, sp.b, y0, sp.x_star, sync="a2a", **kw2)
    f_g = solve_distributed(cop2, sp.b, y0, sp.x_star, sync="allgather",
                            **kw2)
    assert bool(jnp.array_equal(f_a.x, f_g.x))

    # --- front door: solve(problem, format="csr", sync="a2a") -------------
    r_front = solve(bb, key=jax.random.key(5), mesh=mesh, format="csr",
                    sync="a2a", beta=0.7,
                    schedule=Schedule(rounds=7, local_steps=20))
    assert bool(jnp.array_equal(r_front.x, ra.x))
    print("A2A_OK")
"""


CSR_RK_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import CsrOp, DenseOp, random_sparse_lsq
    from repro.core.engine import scheduled_tau, solve_distributed

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(4)

    # sparse rectangular reference scenario: concurrent row projections
    # rarely collide, the regime where async RK keeps near-sequential rates
    lp = random_sparse_lsq(512, 128, row_nnz=8, n_rhs=2, noise=0.0, seed=0)
    ck = CsrOp.from_dense(lp.A)
    w0 = jnp.zeros_like(lp.x_star)
    kw = dict(action="rk", key=jax.random.key(0), mesh=mesh, rounds=60,
              local_steps=16, beta=0.9)
    rk = solve_distributed(ck, lp.b, w0, lp.x_star, **kw)

    # per-worker local sampling uses the shared-stream scheduled_tau bound
    # applied to the round's interleaved stream of P*local_steps picks —
    # one rule, shared by the engine, Schedule.effective_tau, and the CLIs
    from repro.core import Schedule
    assert int(rk.tau) == scheduled_tau(4, 16, local_sampling=True) == 63
    assert Schedule(rounds=60, local_steps=16).effective_tau(
        4, local_sampling=True) == 63
    # ...and degenerates exactly at P = 1 (tau = 0, like the other RK paths)
    rk1 = solve_distributed(ck, lp.b, w0, lp.x_star, action="rk",
                            key=jax.random.key(0), mesh=make_host_mesh(1),
                            rounds=2, local_steps=8, beta=0.9)
    assert int(rk1.tau) == 0

    # consistent system: converges to x* within tolerance
    rel = float(jnp.linalg.norm(lp.b - lp.A @ rk.x) / jnp.linalg.norm(lp.b))
    assert rel < 1e-2, rel
    e = np.asarray(rk.err_sq)
    assert e[-1].max() < 1e-3 * e[0].max(), e[:, 0]

    # at matched rounds the wall-clock-faithful local scheme does not trail
    # the global masked stream (every local step is a useful update)
    rd = solve_distributed(DenseOp(lp.A), lp.b, w0, lp.x_star, **kw)
    rel_d = float(jnp.linalg.norm(lp.b - lp.A @ rd.x) / jnp.linalg.norm(lp.b))
    assert rel <= rel_d * 1.5, (rel, rel_d)
    print("CSR_RK_OK")
"""


@pytest.mark.slow
def test_csr_a2a_matches_allgather_and_dense():
    run_forced_device_script(A2A_SCRIPT, marker="A2A_OK")


@pytest.mark.slow
def test_csr_rk_local_sampling():
    run_forced_device_script(CSR_RK_SCRIPT, marker="CSR_RK_OK")
