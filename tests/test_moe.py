"""MoE dispatch invariants: group-composition independence (no drops),
capacity accounting, router variants, EP-relevant shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models import moe as M


def _params(d, mcfg, seed=0):
    ini = L.Initializer(jax.random.key(seed), jnp.float32)
    return M.init_moe(ini, d, mcfg)[0]


@pytest.mark.parametrize("top_k,router", [(1, "softmax"), (2, "softmax"),
                                          (1, "sigmoid"), (2, "sigmoid")])
def test_token_output_independent_of_group(top_k, router):
    """With no capacity drops, a token's MoE output must not depend on what
    other tokens share its dispatch group (the top-k slot-collision bug)."""
    d, E = 32, 4
    mcfg = MoEConfig(num_experts=E, top_k=top_k, d_ff=64, router=router,
                     capacity_factor=8.0)
    params = _params(d, mcfg)
    x = jax.random.normal(jax.random.key(1), (2, 33, d))
    y_full, aux = M.apply_moe(params, x, mcfg)
    y_last, _ = M.apply_moe(params, x[:, -1:], mcfg)
    assert float(aux.drop_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(y_full[:, -1:]), np.asarray(y_last),
                               atol=1e-5, rtol=1e-5)


def test_group_size_invariance():
    d = 32
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=8.0)
    params = _params(d, mcfg)
    x = jax.random.normal(jax.random.key(2), (4, 64, d))
    y1, _ = M.apply_moe(params, x, mcfg, group=64)
    y2, _ = M.apply_moe(params, x, mcfg, group=256)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_capacity_drops_are_reported():
    """Force congestion: capacity_factor small + biased router -> drops > 0
    and dropped tokens produce zero expert output (shared expert aside)."""
    d, E = 16, 8
    mcfg = MoEConfig(num_experts=E, top_k=1, d_ff=32, capacity_factor=0.25)
    params = _params(d, mcfg)
    # bias the router to a single expert
    params["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.key(3), (1, 128, d))
    y, aux = M.apply_moe(params, x, mcfg)
    assert float(aux.drop_fraction) > 0.5
    # most token outputs are exactly zero (dropped, no shared expert)
    zero_rows = np.mean(np.abs(np.asarray(y)).sum(-1) < 1e-7)
    assert zero_rows > 0.5


def test_load_balance_loss_range():
    d = 16
    mcfg = MoEConfig(num_experts=4, top_k=1, d_ff=32)
    params = _params(d, mcfg)
    x = jax.random.normal(jax.random.key(4), (2, 64, d))
    _, aux = M.apply_moe(params, x, mcfg)
    # perfectly balanced -> 1.0; degenerate -> up to E
    assert 0.9 <= float(aux.load_balance_loss) <= 4.0


def test_shared_expert_always_applies():
    d = 16
    mcfg = MoEConfig(num_experts=4, top_k=1, d_ff=32, shared_expert=True,
                     capacity_factor=0.25)
    params = _params(d, mcfg)
    params["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.key(5), (1, 128, d))
    y, aux = M.apply_moe(params, x, mcfg)
    assert float(aux.drop_fraction) > 0.0
    # shared expert output means dropped tokens are NOT zero
    zero_rows = np.mean(np.abs(np.asarray(y)).sum(-1) < 1e-7)
    assert zero_rows < 0.05


@given(st.integers(2, 6), st.integers(1, 2), st.integers(0, 10**6))
def test_grad_flows_through_router(E, k, seed):
    d = 8
    mcfg = MoEConfig(num_experts=E, top_k=min(k, E), d_ff=16,
                     capacity_factor=8.0)
    params = _params(d, mcfg, seed=seed % 7)
    x = jax.random.normal(jax.random.key(seed % 11), (1, 16, d))

    def loss(p):
        y, _ = M.apply_moe(p, x, mcfg)
        return jnp.sum(y * y)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    assert float(jnp.abs(g["router"]).sum()) > 0   # routing weights get signal
