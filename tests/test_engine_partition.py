"""Partitioning + sparse-sync completion layer (ISSUE 4 tentpole).

In-process: the norm-balanced assignment's invariants (equal bin sizes,
norm mass within 2x of uniform, deterministic), permutation round-trips,
``permute_rows`` exactness for both formats and both permutation kinds, and
the validation surface (balanced needs padded rows / a distributed
schedule; symmetric needs square).

Subprocess (forced 4-device host mesh, shared conftest helper): the RK
``sync="a2a"`` two-phase column-slab exchange is BITWISE identical to the
delta psum on a sparse design (iterates and metrics), the dense-column-graph
fallback is exact, and ``partition="balanced"`` converges on a norm-skewed
design with per-slab norm mass within 2x of uniform — asserted in-test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced_device_script
from repro.core import (CsrOp, DenseOp, EllOp, Schedule, random_sparse_lsq,
                        random_sparse_spd, solve)
from repro.core import partition as pt
from repro.core.engine import solve_distributed
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def skewed_lsq():
    """Sparse rectangular design whose first quarter of rows carries ~99%
    of the norm mass — the case contiguous slabs get maximally wrong."""
    base = random_sparse_lsq(128, 32, row_nnz=6, n_rhs=2, seed=0)
    A = np.array(base.A)
    A[:32] *= 20.0
    return jnp.asarray(A)


def test_norm_balanced_assignment_invariants(skewed_lsq):
    cop = CsrOp.from_dense(skewed_lsq)
    rn = np.asarray(cop.row_norms_sq())
    nnz = np.asarray(cop.row_nnz)
    labels = pt.norm_balanced_assignment(rn, nnz, 4)
    # equal bin sizes — a hard sharding constraint
    assert (np.bincount(labels, minlength=4) == 32).all()
    # norm mass within 2x of uniform (the acceptance bound); the contiguous
    # assignment violates it on this design
    mass = np.asarray([rn[labels == w].sum() for w in range(4)])
    uniform = rn.sum() / 4
    assert mass.max() <= 2 * uniform, mass / uniform
    contiguous = rn.reshape(4, -1).sum(axis=1)
    assert contiguous.max() > 2 * uniform, contiguous / uniform
    # deterministic
    assert (labels == pt.norm_balanced_assignment(rn, nnz, 4)).all()
    with pytest.raises(ValueError, match="divide"):
        pt.norm_balanced_assignment(rn[:126], nnz[:126], 4)


def test_partition_permutation_roundtrip(skewed_lsq):
    cop = CsrOp.from_dense(skewed_lsq)
    rp = pt.balanced_row_permutation(cop, 4)
    perm, inv = np.asarray(rp.perm), np.asarray(rp.inv)
    assert sorted(perm) == list(range(128))
    assert (inv[perm] == np.arange(128)).all()
    # slab_norm_mass agrees with the assignment the permutation realizes
    rn = np.asarray(cop.row_norms_sq())
    mass = pt.slab_norm_mass(rn, perm, 4)
    np.testing.assert_allclose(mass.sum(), np.float64(rn).sum(), rtol=1e-6)
    assert mass.max() <= 2 * rn.sum() / 4


def test_permute_rows_exact(skewed_lsq):
    # row-only (rectangular RK): P A
    cop = CsrOp.from_dense(skewed_lsq)
    rp = pt.balanced_row_permutation(cop, 4)
    perm = np.asarray(rp.perm)
    permuted = pt.permute_rows(cop, rp)
    assert isinstance(permuted, CsrOp)
    np.testing.assert_allclose(np.asarray(permuted.to_dense()),
                               np.asarray(skewed_lsq)[perm], atol=0)
    # the permuted instance re-panelizes: its padded rows reconstruct too
    vals, cols = permuted.padded_rows()
    recon = jnp.zeros(permuted.shape).at[
        jnp.arange(128)[:, None], cols].add(vals)
    np.testing.assert_allclose(np.asarray(recon),
                               np.asarray(skewed_lsq)[perm], atol=0)

    # symmetric (square GS): P A P^T, both formats
    sp = random_sparse_spd(64, row_nnz=6, n_rhs=1, seed=1)
    for op in (CsrOp.from_dense(sp.A), EllOp.from_dense(sp.A, width=32)):
        rps = pt.balanced_row_permutation(op, 4)
        ps = np.asarray(rps.perm)
        want = np.asarray(sp.A)[ps][:, ps]
        got = pt.permute_rows(op, rps, symmetric=True)
        assert type(got) is type(op)
        np.testing.assert_allclose(np.asarray(got.to_dense()), want, atol=0)


def test_partition_validation_surface(skewed_lsq):
    cop = CsrOp.from_dense(skewed_lsq)
    rp = pt.balanced_row_permutation(cop, 4)
    with pytest.raises(ValueError, match="square"):
        pt.permute_rows(cop, rp, symmetric=True)      # 128 x 32
    with pytest.raises(NotImplementedError, match="padded-row"):
        pt.balanced_row_permutation(DenseOp(skewed_lsq), 4)
    # Schedule surface
    with pytest.raises(ValueError, match="unknown partition"):
        Schedule(rounds=2, local_steps=4, partition="graph").validate()
    with pytest.raises(ValueError, match="distributed-schedule"):
        Schedule(num_iters=64, partition="balanced").validate()
    sched = Schedule(rounds=2, local_steps=4, partition="balanced")
    assert sched.validate() == sched
    # engine surface: balanced partitioning of a dense operator is an error
    prob = random_sparse_spd(64, row_nnz=6, n_rhs=1, seed=0)
    mesh = make_host_mesh(1)
    with pytest.raises(NotImplementedError, match="padded-row"):
        solve_distributed(DenseOp(prob.A), prob.b,
                          jnp.zeros_like(prob.x_star), prob.x_star,
                          action="gs", key=jax.random.key(0), mesh=mesh,
                          rounds=2, local_steps=4, partition="balanced")
    with pytest.raises(ValueError, match="unknown partition"):
        solve_distributed(CsrOp.from_dense(prob.A), prob.b,
                          jnp.zeros_like(prob.x_star), prob.x_star,
                          action="gs", key=jax.random.key(0), mesh=mesh,
                          rounds=2, local_steps=4, partition="graph")


def test_balanced_partition_single_device(skewed_lsq):
    """The balanced path runs end-to-end on one device (permute,
    solve, un-permute) and the GS iterate comes back in original row
    order — its residual is computed against the *unpermuted* system."""
    prob = random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=3)
    mesh = make_host_mesh(1)
    x0 = jnp.zeros_like(prob.x_star)
    res = solve_distributed(CsrOp.from_dense(prob.A), prob.b, x0,
                            prob.x_star, action="gs", key=jax.random.key(1),
                            mesh=mesh, rounds=6, local_steps=64, beta=0.8,
                            partition="balanced")
    rel = float(jnp.linalg.norm(prob.b - prob.A @ res.x)
                / jnp.linalg.norm(prob.b))
    assert rel < 0.15, rel
    e = np.asarray(res.err_sq)
    assert e[-1].max() < 0.5 * e[0].max(), e[:, 0]


RK_A2A_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import CsrOp, EllOp, block_banded_spd, random_sparse_lsq
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)

    # banded-structure CSR: the column-slab graph is genuinely sparse
    bb = block_banded_spd(512, block=16, bands=1, n_rhs=3, seed=2)
    cop = CsrOp.from_dense(bb.A)
    need = cop.slab_neighbors(4)
    assert not need[0, 2] and not need[0, 3], need
    x0 = jnp.zeros_like(bb.x_star)
    kw = dict(action="rk", key=jax.random.key(0), mesh=mesh, rounds=60,
              local_steps=16, beta=0.9)
    ra = solve_distributed(cop, bb.b, x0, bb.x_star, sync="a2a", **kw)
    rp = solve_distributed(cop, bb.b, x0, bb.x_star, sync="psum", **kw)
    # the two-phase owner-reduce/broadcast carries exactly the psum's bits:
    # iterates AND metrics are bitwise identical
    assert bool(jnp.array_equal(ra.x, rp.x))
    assert bool(jnp.array_equal(ra.err_sq, rp.err_sq))
    assert bool(jnp.array_equal(ra.resid, rp.resid))
    assert int(ra.tau) == int(rp.tau) == 4 * 16 - 1

    # sync="auto" picks a2a for a sparse operator with slab-neighbor
    # metadata (and must therefore also equal the psum bitwise)
    rauto = solve_distributed(cop, bb.b, x0, bb.x_star, **kw)
    assert bool(jnp.array_equal(rauto.x, rp.x))

    # ...and the solve actually solves (consistent square system)
    rel = float(jnp.linalg.norm(bb.b - bb.A @ ra.x) / jnp.linalg.norm(bb.b))
    assert rel < 0.1, rel

    # EllOp rides the same strategy
    eop = EllOp.from_dense(bb.A, width=48)
    ea = solve_distributed(eop, bb.b, x0, bb.x_star, sync="a2a", **kw)
    ep = solve_distributed(eop, bb.b, x0, bb.x_star, sync="psum", **kw)
    assert bool(jnp.array_equal(ea.x, ep.x))

    # dense column graph (unstructured sparse LSQ): a2a falls back to the
    # delta psum, exactly
    lp = random_sparse_lsq(256, 64, row_nnz=8, n_rhs=2, noise=0.0, seed=0)
    ck = CsrOp.from_dense(lp.A)
    assert ck.slab_neighbors(4).all()
    w0 = jnp.zeros_like(lp.x_star)
    kw2 = dict(action="rk", key=jax.random.key(1), mesh=mesh, rounds=10,
               local_steps=8, beta=0.9)
    fa = solve_distributed(ck, lp.b, w0, lp.x_star, sync="a2a", **kw2)
    fp = solve_distributed(ck, lp.b, w0, lp.x_star, sync="psum", **kw2)
    assert bool(jnp.array_equal(fa.x, fp.x))
    print("RK_A2A_OK")
"""


BALANCED_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import CsrOp, Schedule, random_sparse_lsq, solve
    from repro.core import partition as pt
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)

    # norm-skewed sparse rectangular design: first quarter of rows carries
    # ~99% of the mass, so contiguous slabs break the balanced-norm-mass
    # assumption the per-worker local sampling law relies on
    base = random_sparse_lsq(512, 128, row_nnz=8, n_rhs=2, noise=0.0, seed=0)
    A = np.array(base.A)
    A[:128] *= 20.0
    rng = np.random.default_rng(5)
    xt = rng.standard_normal((128, 2)).astype(np.float32)
    Aj = jnp.asarray(A)
    bj = jnp.asarray(A @ xt)
    cop = CsrOp.from_dense(Aj)

    # the acceptance bound, asserted on the permutation the engine applies:
    # per-slab norm mass within 2x of uniform (contiguous exceeds it)
    rn = np.asarray(cop.row_norms_sq())
    rp = pt.balanced_row_permutation(cop, 4)
    uniform = rn.sum() / 4
    mass = pt.slab_norm_mass(rn, np.asarray(rp.perm), 4)
    assert mass.max() <= 2 * uniform, mass / uniform
    contig = pt.slab_norm_mass(rn, np.arange(512), 4)
    assert contig.max() > 2 * uniform, contig / uniform

    # balanced-partition RK converges on the skewed design
    w0 = jnp.zeros((128, 2))
    kw = dict(action="rk", key=jax.random.key(3), mesh=mesh, rounds=80,
              local_steps=16, beta=0.9)
    rb = solve_distributed(cop, bj, w0, jnp.asarray(xt),
                           partition="balanced", **kw)
    rel = float(jnp.linalg.norm(bj - Aj @ rb.x) / jnp.linalg.norm(bj))
    assert rel < 5e-2, rel
    # the error norm is dominated by the design's small singular directions
    # and decays slower than the residual; monotone progress is the claim
    e = np.asarray(rb.err_sq)
    assert e[-1].max() < 0.2 * e[0].max(), e[:, 0]

    # front door: Schedule(partition="balanced") reaches the same path
    from repro.core.kaczmarz import LSQProblem
    s = jnp.linalg.svd(Aj, compute_uv=False)
    prob = LSQProblem(A=Aj, b=bj, x_star=jnp.asarray(xt),
                      x_true=jnp.asarray(xt), sigma_min=s[-1],
                      sigma_max=s[0])
    rf = solve(prob, key=jax.random.key(3), mesh=mesh, format="csr",
               beta=0.9,
               schedule=Schedule(rounds=80, local_steps=16,
                                 partition="balanced"))
    assert bool(jnp.array_equal(rf.x, rb.x))

    # balanced GS on a square system un-permutes the iterate: the residual
    # of the *original* system drops
    from repro.core import random_sparse_spd
    sp = random_sparse_spd(256, row_nnz=8, n_rhs=2, seed=0)
    copg = CsrOp.from_dense(sp.A)
    y0 = jnp.zeros_like(sp.x_star)
    gb = solve_distributed(copg, sp.b, y0, sp.x_star, action="gs",
                           key=jax.random.key(2), mesh=mesh, rounds=10,
                           local_steps=32, beta=0.8, partition="balanced")
    relg = float(jnp.linalg.norm(sp.b - sp.A @ gb.x)
                 / jnp.linalg.norm(sp.b))
    assert relg < 0.15, relg
    eg = np.asarray(gb.err_sq)
    assert eg[-1].max() < 0.1 * eg[0].max(), eg[:, 0]
    print("BALANCED_OK")
"""


@pytest.mark.slow
def test_rk_a2a_bitwise_identical_to_psum():
    run_forced_device_script(RK_A2A_SCRIPT, marker="RK_A2A_OK")


@pytest.mark.slow
def test_balanced_partition_forced_devices():
    run_forced_device_script(BALANCED_SCRIPT, marker="BALANCED_OK")
