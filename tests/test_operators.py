"""Operator layer (repro.core.operators): matvec correctness of every
format against the dense oracle (Pallas kernels in interpret mode on CPU),
layout metadata the engine's sync selection relies on, and the sequential
engine's format-genericity (ELL / banded paths track the dense path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockBandedOp, CsrOp, DenseOp, EllOp, as_operator,
                        block_banded_spd, random_sparse_lsq,
                        random_sparse_spd)
from repro.core.engine import solve_sequential


@pytest.fixture(scope="module")
def banded_prob():
    return block_banded_spd(512, block=32, bands=2, n_rhs=4, seed=0)


@pytest.fixture(scope="module")
def sparse_prob():
    return random_sparse_spd(256, row_nnz=8, n_rhs=3, seed=1)


@pytest.mark.parametrize("n,block,bands,k", [(256, 32, 1, 2), (512, 64, 2, 4)])
def test_block_banded_matvec_vs_dense(n, block, bands, k):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=2)
    op = BlockBandedOp.from_dense(prob.A, block=block, bands=bands)
    want = np.asarray(prob.A @ prob.x_star)
    # Pallas kernel backend, interpret mode (CPU)
    np.testing.assert_allclose(
        np.asarray(op.matvec(prob.x_star, interpret=True)), want,
        atol=1e-4, rtol=1e-4)
    # pure-jnp reference backend
    np.testing.assert_allclose(np.asarray(op.matvec_ref(prob.x_star)), want,
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("width", [32, 48])  # >= max nnz/row: exact capture
def test_ell_matvec_vs_dense(sparse_prob, width):
    op = EllOp.from_dense(sparse_prob.A, width=width)
    want = np.asarray(sparse_prob.A @ sparse_prob.x_star)
    np.testing.assert_allclose(
        np.asarray(op.matvec(sparse_prob.x_star, interpret=True)), want,
        atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(op.matvec_ref(sparse_prob.x_star)), want,
        atol=1e-4, rtol=1e-4)


def test_to_dense_roundtrips(banded_prob, sparse_prob):
    bop = BlockBandedOp.from_dense(banded_prob.A, block=32, bands=2)
    np.testing.assert_allclose(np.asarray(bop.to_dense()),
                               np.asarray(banded_prob.A), atol=1e-6)
    eop = EllOp.from_dense(sparse_prob.A, width=32)
    np.testing.assert_allclose(np.asarray(eop.to_dense()),
                               np.asarray(sparse_prob.A), atol=1e-6)


def test_layout_metadata(banded_prob, sparse_prob):
    """halo width / shard specs / nnz cost — what the engine dispatches on."""
    dop = DenseOp(sparse_prob.A)
    bop = BlockBandedOp.from_dense(banded_prob.A, block=32, bands=2)
    eop = EllOp.from_dense(sparse_prob.A, width=16)
    assert dop.halo_width is None and eop.halo_width is None
    assert bop.halo_width == 2 * 32
    assert bop.nb == 16 and bop.block == 32 and bop.width == 5
    assert dop.nnz_cost() == 256 * 256
    assert bop.nnz_cost() == 16 * 5 * 32 * 32 < 512 * 512  # < dense storage
    assert eop.nnz_cost() == 256 * 16
    assert dop.shard_spec("w") == jax.sharding.PartitionSpec("w", None)
    # row norms agree across formats
    np.testing.assert_allclose(
        np.asarray(bop.row_norms_sq().reshape(-1)),
        np.asarray(DenseOp(banded_prob.A).row_norms_sq()), atol=1e-5,
        rtol=1e-4)


def test_as_operator_dispatch(sparse_prob):
    assert isinstance(as_operator(sparse_prob.A, "dense"), DenseOp)
    assert isinstance(
        as_operator(sparse_prob.A, "banded", block=32, bands=2),
        BlockBandedOp)
    assert isinstance(as_operator(sparse_prob.A, "ell", width=16), EllOp)
    assert isinstance(as_operator(sparse_prob.A, "csr"), CsrOp)
    with pytest.raises(ValueError):
        as_operator(sparse_prob.A, "coo")


def test_operators_are_pytrees(sparse_prob):
    """Operators pass through jit/tree transforms (the engine requires it)."""
    op = EllOp.from_dense(sparse_prob.A, width=16)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert len(leaves) == 2
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(op2, EllOp) and op2.width == 16

    @jax.jit
    def through(o, x):
        return o.matvec_ref(x)

    np.testing.assert_allclose(
        np.asarray(through(op, sparse_prob.x_star)),
        np.asarray(op.matvec_ref(sparse_prob.x_star)), atol=1e-6)


def test_sequential_engine_ell_tracks_dense(sparse_prob):
    """The same GS/RK action run through the ELL format stays within fp
    noise of the dense format (same keys => same index sequence)."""
    x0 = jnp.zeros_like(sparse_prob.x_star)
    eop = EllOp.from_dense(sparse_prob.A, width=32)   # width >= row_nnz: exact
    dop = DenseOp(sparse_prob.A)
    se = solve_sequential(eop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="gs", key=jax.random.key(4), num_iters=2048)
    sd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="gs", key=jax.random.key(4), num_iters=2048)
    assert float(jnp.abs(se.x - sd.x).max()) < 1e-4
    # row (Kaczmarz) action too — note sampling uses the ELL row norms,
    # which equal the dense row norms when the width captures every nonzero
    re = solve_sequential(eop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="rk", key=jax.random.key(5), num_iters=1024)
    rd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="rk", key=jax.random.key(5), num_iters=1024)
    assert float(jnp.abs(re.x - rd.x).max()) < 1e-4


# ---------------------------------------------------------------------------
# CsrOp: full protocol conformance against the dense oracle (ISSUE 3)
# ---------------------------------------------------------------------------

def test_csr_matvec_vs_dense(sparse_prob):
    op = CsrOp.from_dense(sparse_prob.A)
    want = np.asarray(sparse_prob.A @ sparse_prob.x_star)
    # Pallas segment-sum kernel, interpret mode (CPU)
    np.testing.assert_allclose(
        np.asarray(op.matvec(sparse_prob.x_star, interpret=True)), want,
        atol=1e-4, rtol=1e-4)
    # pure-jnp segment-sum reference
    np.testing.assert_allclose(np.asarray(op.matvec_ref(sparse_prob.x_star)),
                               want, atol=1e-4, rtol=1e-4)


def test_csr_matvec_rectangular():
    lp = random_sparse_lsq(96, 32, row_nnz=6, n_rhs=2, seed=3)
    op = CsrOp.from_dense(lp.A)
    want = np.asarray(lp.A @ lp.x_star)
    np.testing.assert_allclose(np.asarray(op.matvec(lp.x_star,
                                                    interpret=True)),
                               want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(op.matvec_ref(lp.x_star)), want,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(op.to_dense()), np.asarray(lp.A),
                               atol=1e-6)


def test_csr_row_access_vs_dense(sparse_prob):
    op = CsrOp.from_dense(sparse_prob.A)
    dop = DenseOp(sparse_prob.A)
    x = sparse_prob.x_star
    for r in (0, 7, 255):
        np.testing.assert_allclose(np.asarray(op.row_dot(r, x)),
                                   np.asarray(dop.row_dot(r, x)),
                                   atol=1e-5, rtol=1e-5)
    g = jnp.ones((x.shape[1],))
    np.testing.assert_allclose(np.asarray(op.rk_update(x, 7, g, 0.9)),
                               np.asarray(dop.rk_update(x, 7, g, 0.9)),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(op.row_panel(3, 16)),
                               np.asarray(dop.row_panel(3, 16)), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(op.residual_panel(sparse_prob.b, x, 3, 16)),
        np.asarray(dop.residual_panel(sparse_prob.b, x, 3, 16)),
        atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(op.row_norms_sq()),
                               np.asarray(dop.row_norms_sq()),
                               atol=1e-5, rtol=1e-5)


def test_csr_layout_metadata(sparse_prob, banded_prob):
    op = CsrOp.from_dense(sparse_prob.A)
    assert op.halo_width is None           # unstructured: no scalar halo
    assert op.shape == (256, 256)
    assert op.nnz_cost() == int((np.asarray(sparse_prob.A) != 0).sum())
    assert op.nnz_cost() < 256 * 256       # < dense storage
    assert op.shard_spec("w") == jax.sharding.PartitionSpec("w", None)
    # per-row reach refines the scalar halo: on a banded-structure matrix
    # it is bounded by the band, and slab neighbors are only the adjacent
    # slabs (what sync="a2a" exchanges along)
    bop = CsrOp.from_dense(banded_prob.A)  # block=32, bands=2 -> reach<160
    reach = np.asarray(bop.row_reach())
    assert reach.shape == (512,) and reach.max() < 5 * 32
    need = bop.slab_neighbors(4)
    assert need.shape == (4, 4) and need.diagonal().all()
    assert not need[0, 2] and not need[0, 3]     # far slabs never read
    # unstructured sparsity reads everywhere -> dense neighbor graph
    assert CsrOp.from_dense(sparse_prob.A).slab_neighbors(4).all()


def test_csr_padded_rows_reconstruct(sparse_prob):
    op = CsrOp.from_dense(sparse_prob.A)
    vals, cols = op.padded_rows()
    assert vals.shape == (256, op.row_cap) == cols.shape
    recon = jnp.zeros_like(sparse_prob.A).at[
        jnp.arange(256)[:, None], cols].add(vals)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(sparse_prob.A),
                               atol=1e-6)


def test_csr_is_pytree(sparse_prob):
    op = CsrOp.from_dense(sparse_prob.A)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert len(leaves) == 5
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(op2, CsrOp) and op2.shape == op.shape

    @jax.jit
    def through(o, x):
        return o.matvec_ref(x)

    np.testing.assert_allclose(
        np.asarray(through(op, sparse_prob.x_star)),
        np.asarray(op.matvec_ref(sparse_prob.x_star)), atol=1e-6)


def test_sequential_engine_csr_tracks_dense(sparse_prob):
    """GS / block-GS / RK actions through the CSR format stay within fp
    noise of the dense format (same keys => same index sequence)."""
    x0 = jnp.zeros_like(sparse_prob.x_star)
    cop = CsrOp.from_dense(sparse_prob.A)
    dop = DenseOp(sparse_prob.A)
    for action, kw in (("gs", {}), ("gs", {"block": 16}), ("rk", {})):
        ni = 512 if kw else 2048
        sc = solve_sequential(cop, sparse_prob.b, x0, sparse_prob.x_star,
                              action=action, key=jax.random.key(4),
                              num_iters=ni, **kw)
        sd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                              action=action, key=jax.random.key(4),
                              num_iters=ni, **kw)
        assert float(jnp.abs(sc.x - sd.x).max()) < 1e-4, (action, kw)


def test_sequential_engine_banded_converges(banded_prob):
    """Θ(nnz) sequential block-GS on the banded format actually solves."""
    op = BlockBandedOp.from_dense(banded_prob.A, block=32, bands=2)
    x0 = jnp.zeros_like(banded_prob.x_star)
    res = solve_sequential(op, banded_prob.b, x0, banded_prob.x_star,
                           action="gs", key=jax.random.key(3), num_iters=320,
                           beta=0.9, record_every=80)
    e = np.asarray(res.err_sq)
    assert e[-1].max() < 1e-2 * e[0].max()
    rel = float(jnp.linalg.norm(banded_prob.b - banded_prob.A @ res.x)
                / jnp.linalg.norm(banded_prob.b))
    assert rel < 1e-2, rel
