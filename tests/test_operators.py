"""Operator layer (repro.core.operators): one property-based conformance
grid over all four formats (ISSUE 4 satellite).

``check_conformance`` asserts the full operator protocol against the dense
oracle — matvec (Pallas kernel in interpret mode AND pure-jnp reference),
``row_norms_sq`` non-negative and consistent with ``row_panel`` reads,
``row_dot``/``rk_update`` row actions, ``padded_rows`` round-tripping the
matrix, ``slab_neighbors`` exactly the slab graph of the dense sparsity
pattern (symmetric whenever the pattern is, always True on the diagonal,
shape (P, P)), and ``to_dense`` reconstruction.  A deterministic
format x shape x sparsity grid always runs (tier-1 works on bare
jax+pytest); when hypothesis is installed the same checker fuzzes over
random shapes/sparsity/seeds.  The engine-facing tests (dispatch, pytree
flattening, sequential format-genericity) ride below unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockBandedOp, CsrOp, DenseOp, EllOp, as_operator,
                        block_banded_spd, random_sparse_lsq,
                        random_sparse_spd)
from repro.core.engine import solve_sequential

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare jax+pytest environment: deterministic grid only
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# The conformance checker
# ---------------------------------------------------------------------------

def _dense_slab_graph(An, num_workers):
    """Oracle for slab_neighbors: need[w, v] <=> row slab w stores a
    nonzero in column slab v (diagonal always True)."""
    m, n = An.shape
    rs, cs = m // num_workers, n // num_workers
    need = np.zeros((num_workers, num_workers), bool)
    for w in range(num_workers):
        for v in range(num_workers):
            need[w, v] = bool(
                (An[w * rs:(w + 1) * rs, v * cs:(v + 1) * cs] != 0).any())
    np.fill_diagonal(need, True)
    return need


def check_conformance(op, A, *, rtol=1e-4, atol=1e-4):
    """Assert the full operator protocol against the dense oracle ``A``.

    For a low-precision ``storage_dtype`` operator the caller passes the
    ROUNDED dense oracle (``f32(bf16(A))``): the operator's coefficients
    are exactly the rounded values, so every value comparison stays tight
    — only accumulation order separates the two sides — while
    ``row_norms_sq`` must come back f32 regardless of storage.
    """
    An = np.asarray(A)
    m, n = An.shape
    assert op.shape == (m, n)
    key = jax.random.key(hash((m, n)) % (2 ** 31))
    x = jax.random.normal(key, (n, 3), A.dtype)

    # matvec: Pallas kernel (interpret mode on CPU) and pure-jnp reference
    want = An @ np.asarray(x)
    kwargs = {"interpret": True} if not isinstance(op, DenseOp) else {}
    np.testing.assert_allclose(np.asarray(op.matvec(x, **kwargs)), want,
                               rtol=rtol, atol=atol)
    if hasattr(op, "matvec_ref"):
        np.testing.assert_allclose(np.asarray(op.matvec_ref(x)), want,
                                   rtol=rtol, atol=atol)

    # row_norms_sq: f32 whatever the storage dtype (sampling distributions
    # and RK divisors never degrade), non-negative, matches the dense rows
    rn_arr = op.row_norms_sq()
    assert rn_arr.dtype == jnp.float32
    rn = np.asarray(rn_arr).reshape(-1)
    assert rn.shape == (m,) and (rn >= 0).all()
    np.testing.assert_allclose(rn, (An * An).sum(axis=1), rtol=1e-4,
                               atol=1e-5)

    # ...and consistent with row_panel reads where the format has them
    # (panels come back in storage dtype; square in f32 like the operator)
    if isinstance(op, BlockBandedOp):
        panel = np.asarray(op.row_panel(0)).astype(np.float32)
        np.testing.assert_allclose((panel * panel).sum(axis=1),
                                   rn[:op.block], rtol=1e-4, atol=1e-5)
    elif hasattr(op, "row_panel"):
        block = max(m // 8, 1)
        if m % block == 0:
            panel = np.asarray(op.row_panel(1, block)).astype(np.float32)
            np.testing.assert_allclose((panel * panel).sum(axis=1),
                                       rn[block:2 * block], rtol=1e-4,
                                       atol=1e-5)

    # row actions (Θ(nnz/row) reads the sequential engine performs)
    b = jnp.asarray(An) @ x + 0.5
    if hasattr(op, "row_dot"):
        dop = DenseOp(jnp.asarray(An))
        for r in (0, m // 2, m - 1):
            np.testing.assert_allclose(np.asarray(op.row_dot(r, x)),
                                       np.asarray(dop.row_dot(r, x)),
                                       rtol=1e-4, atol=1e-5)
        g = jnp.ones((x.shape[1],))
        np.testing.assert_allclose(
            np.asarray(op.rk_update(x, m // 2, g, 0.9)),
            np.asarray(dop.rk_update(x, m // 2, g, 0.9)), atol=1e-5)

    # residual_panel: the block-GS read, vs the dense expression
    if isinstance(op, BlockBandedOp):
        bi = op.nb - 1
        rows = slice(bi * op.block, (bi + 1) * op.block)
        np.testing.assert_allclose(
            np.asarray(op.residual_panel(b, x, bi)),
            np.asarray(b[rows]) - An[rows] @ np.asarray(x),
            rtol=1e-4, atol=1e-4)
    elif hasattr(op, "residual_panel"):
        block = max(m // 8, 1)
        if m % block == 0:
            rows = slice(block, 2 * block)
            np.testing.assert_allclose(
                np.asarray(op.residual_panel(b, x, 1, block)),
                np.asarray(b[rows]) - An[rows] @ np.asarray(x),
                rtol=1e-4, atol=1e-4)

    # padded_rows round-trips the matrix (global column ids, zero padding)
    if hasattr(op, "padded_rows"):
        vals, cols = op.padded_rows()
        assert vals.shape == cols.shape and vals.shape[0] == m
        recon = jnp.zeros((m, n), vals.dtype).at[
            jnp.arange(m)[:, None], cols].add(vals)
        np.testing.assert_allclose(
            np.asarray(recon).astype(np.float32), An, atol=1e-6)

    # slab_neighbors IS the slab graph of the dense pattern — this
    # subsumes in-bounds shape/dtype and symmetry-when-the-pattern-is
    if hasattr(op, "slab_neighbors"):
        for P in (2, 4):
            if m % P or n % P:
                continue
            need = op.slab_neighbors(P)
            assert need.shape == (P, P) and need.dtype == bool
            assert need.diagonal().all()
            np.testing.assert_array_equal(need, _dense_slab_graph(An, P))
            if m == n and np.array_equal((An != 0), (An != 0).T):
                np.testing.assert_array_equal(need, need.T)

    # nnz_cost: stored slots cover the true nonzeros.  The banded layout
    # stores zero-padded border tiles, which can exceed dense storage when
    # the band width approaches the block count — every other format is
    # bounded by the dense count.
    nnz_true = int((An != 0).sum())
    assert nnz_true <= op.nnz_cost()
    if not isinstance(op, BlockBandedOp):
        assert op.nnz_cost() <= max(m * n, 1)

    # halo_width: finite iff the format bounds an update's reach
    if isinstance(op, BlockBandedOp):
        assert op.halo_width == op.bands * op.block
    else:
        assert op.halo_width is None

    # to_dense reconstructs the stored values
    np.testing.assert_allclose(
        np.asarray(op.to_dense()).astype(np.float32), An, atol=1e-6)


# ---------------------------------------------------------------------------
# Deterministic grid (always runs; tier-1 must not need hypothesis)
# ---------------------------------------------------------------------------

def _case(fmt, spec):
    if spec["kind"] == "spd":
        A = random_sparse_spd(spec["n"], row_nnz=spec["row_nnz"],
                              seed=spec["seed"]).A
    elif spec["kind"] == "lsq":
        A = random_sparse_lsq(spec["m"], spec["n"], row_nnz=spec["row_nnz"],
                              seed=spec["seed"]).A
    else:
        A = block_banded_spd(spec["n"], block=spec["block"],
                             bands=spec["bands"], seed=spec["seed"]).A
    if spec.get("zero_rows"):
        A = jnp.asarray(np.array(A) * (np.arange(A.shape[0]) % 3 != 0
                                       )[:, None].astype(np.float32))
    kw = {"storage_dtype": spec.get("storage_dtype")}
    if fmt == "banded":
        kw.update(block=spec["block"], bands=spec["bands"])
    elif fmt == "ell":
        kw.update(width=spec["width"])
    op = as_operator(A, fmt, **kw)
    if kw["storage_dtype"] is not None:
        # Low-precision storage: the oracle is the ROUNDED dense matrix —
        # the operator holds exactly those values, so the conformance
        # tolerances need no loosening.
        A = jnp.asarray(A).astype(kw["storage_dtype"]).astype(jnp.float32)
    return op, A


GRID = [
    ("dense", dict(kind="spd", n=64, row_nnz=6, seed=0)),
    ("dense", dict(kind="lsq", m=96, n=32, row_nnz=5, seed=1)),
    ("banded", dict(kind="banded", n=128, block=16, bands=1, seed=2)),
    ("banded", dict(kind="banded", n=256, block=32, bands=2, seed=3)),
    ("ell", dict(kind="spd", n=64, row_nnz=6, width=32, seed=4)),
    ("ell", dict(kind="spd", n=96, row_nnz=8, width=48, seed=5,
                 zero_rows=True)),
    ("csr", dict(kind="spd", n=64, row_nnz=6, seed=6)),
    ("csr", dict(kind="lsq", m=96, n=32, row_nnz=5, seed=7)),
    ("csr", dict(kind="lsq", m=64, n=16, row_nnz=3, seed=8,
                 zero_rows=True)),
    # mixed-precision storage: same protocol vs the bf16-rounded oracle
    ("dense", dict(kind="spd", n=64, row_nnz=6, seed=0,
                   storage_dtype="bfloat16")),
    ("banded", dict(kind="banded", n=128, block=16, bands=1, seed=2,
                    storage_dtype="bfloat16")),
    ("ell", dict(kind="spd", n=64, row_nnz=6, width=32, seed=4,
                 storage_dtype="bfloat16")),
    ("csr", dict(kind="spd", n=64, row_nnz=6, seed=6,
                 storage_dtype="bfloat16")),
    ("csr", dict(kind="lsq", m=96, n=32, row_nnz=5, seed=7,
                 storage_dtype="bfloat16")),
]


@pytest.mark.parametrize("fmt,spec", GRID,
                         ids=[f"{f}-{i}" for i, (f, _) in enumerate(GRID)])
def test_operator_conformance_grid(fmt, spec):
    op, A = _case(fmt, spec)
    check_conformance(op, A)


# ---------------------------------------------------------------------------
# Hypothesis layer: the same checker over random shapes/sparsity
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=10)
    @given(st.sampled_from([32, 48, 64, 96]),
           st.integers(2, 10), st.integers(0, 2 ** 16), st.booleans())
    def test_conformance_fuzz_square(n, row_nnz, seed, zero_rows):
        A = random_sparse_spd(n, row_nnz=min(row_nnz, n // 2),
                              seed=seed % 997).A
        if zero_rows:
            A = jnp.asarray(np.array(A) * (np.arange(n) % 4 != 1
                                           )[:, None].astype(np.float32))
        for fmt, kw in (("dense", {}), ("ell", dict(width=n)),
                        ("csr", {})):
            check_conformance(as_operator(A, fmt, **kw), A)

    @settings(deadline=None, max_examples=10)
    @given(st.sampled_from([(64, 16), (96, 32), (128, 32)]),
           st.integers(1, 8), st.integers(0, 2 ** 16))
    def test_conformance_fuzz_rectangular(shape, row_nnz, seed):
        m, n = shape
        A = random_sparse_lsq(m, n, row_nnz=min(row_nnz, n),
                              seed=seed % 997).A
        for fmt in ("dense", "csr"):
            check_conformance(as_operator(A, fmt), A)

    @settings(deadline=None, max_examples=6)
    @given(st.sampled_from([(128, 16, 1), (128, 32, 2), (256, 32, 1)]),
           st.integers(0, 2 ** 16))
    def test_conformance_fuzz_banded(cfg, seed):
        n, block, bands = cfg
        A = block_banded_spd(n, block=block, bands=bands,
                             seed=seed % 997).A
        check_conformance(
            as_operator(A, "banded", block=block, bands=bands), A)
        check_conformance(as_operator(A, "csr"), A)


# ---------------------------------------------------------------------------
# Engine-facing tests (dispatch, pytrees, sequential format-genericity)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def banded_prob():
    return block_banded_spd(512, block=32, bands=2, n_rhs=4, seed=0)


@pytest.fixture(scope="module")
def sparse_prob():
    return random_sparse_spd(256, row_nnz=8, n_rhs=3, seed=1)


def test_as_operator_dispatch(sparse_prob):
    assert isinstance(as_operator(sparse_prob.A, "dense"), DenseOp)
    assert isinstance(
        as_operator(sparse_prob.A, "banded", block=32, bands=2),
        BlockBandedOp)
    assert isinstance(as_operator(sparse_prob.A, "ell", width=16), EllOp)
    assert isinstance(as_operator(sparse_prob.A, "csr"), CsrOp)
    with pytest.raises(ValueError):
        as_operator(sparse_prob.A, "coo")


def test_storage_dtype_layout(sparse_prob):
    """Mixed-precision storage invariants the conformance grid cannot see:

    * ``storage_dtype=None`` is byte-identical to the pre-parameter layout
      (the bitwise-compatibility contract of DESIGN.md);
    * bf16 storage narrows the column-index stream to int16 when every
      global column id fits (n <= int16 max) — the pairing that makes the
      A-stream 2+2 bytes/slot instead of 4+4;
    * row bookkeeping (row_id/row_start/row_nnz) stays int32, and the
      pytree leaf counts are unchanged (dtype rides in the leaves, not
      the aux data).
    """
    A = sparse_prob.A
    base = CsrOp.from_dense(A)
    same = CsrOp.from_dense(A, storage_dtype=None)
    for lb, ls in zip(jax.tree_util.tree_leaves(base),
                      jax.tree_util.tree_leaves(same)):
        assert lb.dtype == ls.dtype
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(ls))

    lp = CsrOp.from_dense(A, storage_dtype="bfloat16")
    assert lp.data.dtype == jnp.bfloat16
    assert lp.indices.dtype == jnp.int16          # n=256 fits int16
    assert lp.row_id.dtype == jnp.int32
    assert lp.row_start.dtype == jnp.int32 and lp.row_nnz.dtype == jnp.int32
    assert len(jax.tree_util.tree_leaves(lp)) == 5
    assert lp.nnz == base.nnz and lp.row_cap == base.row_cap

    elp = EllOp.from_dense(A, width=16, storage_dtype="bfloat16")
    assert elp.vals.dtype == jnp.bfloat16 and elp.cols.dtype == jnp.int16
    assert len(jax.tree_util.tree_leaves(elp)) == 2

    with pytest.raises(ValueError):
        as_operator(A, "csr", storage_dtype="float16")


def test_shard_specs_and_structure(banded_prob, sparse_prob):
    """Metadata the conformance grid does not pin: shard specs and the
    banded tile geometry."""
    bop = BlockBandedOp.from_dense(banded_prob.A, block=32, bands=2)
    assert bop.nb == 16 and bop.block == 32 and bop.width == 5
    assert DenseOp(sparse_prob.A).shard_spec("w") == \
        jax.sharding.PartitionSpec("w", None)
    assert bop.shard_spec("w") == \
        jax.sharding.PartitionSpec("w", None, None, None)
    assert CsrOp.from_dense(sparse_prob.A).shard_spec("w") == \
        jax.sharding.PartitionSpec("w", None)


def test_csr_row_reach(banded_prob, sparse_prob):
    """Per-row reach refines the scalar halo: bounded by the band on a
    banded-structure matrix, and the slab graph of unstructured sparsity is
    dense (what the a2a fallback keys on)."""
    bop = CsrOp.from_dense(banded_prob.A)   # block=32, bands=2 -> reach<160
    reach = np.asarray(bop.row_reach())
    assert reach.shape == (512,) and reach.max() < 5 * 32
    need = bop.slab_neighbors(4)
    assert not need[0, 2] and not need[0, 3]      # far slabs never read
    assert CsrOp.from_dense(sparse_prob.A).slab_neighbors(4).all()


def test_operators_are_pytrees(sparse_prob):
    """Operators pass through jit/tree transforms (the engine requires it)."""
    op = EllOp.from_dense(sparse_prob.A, width=16)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert len(leaves) == 2
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(op2, EllOp) and op2.width == 16

    cop = CsrOp.from_dense(sparse_prob.A)
    cleaves, ctreedef = jax.tree_util.tree_flatten(cop)
    assert len(cleaves) == 5
    cop2 = jax.tree_util.tree_unflatten(ctreedef, cleaves)
    assert isinstance(cop2, CsrOp) and cop2.shape == cop.shape

    @jax.jit
    def through(o, x):
        return o.matvec_ref(x)

    for o in (op, cop):
        np.testing.assert_allclose(
            np.asarray(through(o, sparse_prob.x_star)),
            np.asarray(o.matvec_ref(sparse_prob.x_star)), atol=1e-6)


def test_sequential_engine_ell_tracks_dense(sparse_prob):
    """The same GS/RK action run through the ELL format stays within fp
    noise of the dense format (same keys => same index sequence)."""
    x0 = jnp.zeros_like(sparse_prob.x_star)
    eop = EllOp.from_dense(sparse_prob.A, width=32)   # width >= row_nnz: exact
    dop = DenseOp(sparse_prob.A)
    se = solve_sequential(eop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="gs", key=jax.random.key(4), num_iters=2048)
    sd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="gs", key=jax.random.key(4), num_iters=2048)
    assert float(jnp.abs(se.x - sd.x).max()) < 1e-4
    # row (Kaczmarz) action too — note sampling uses the ELL row norms,
    # which equal the dense row norms when the width captures every nonzero
    re = solve_sequential(eop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="rk", key=jax.random.key(5), num_iters=1024)
    rd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                          action="rk", key=jax.random.key(5), num_iters=1024)
    assert float(jnp.abs(re.x - rd.x).max()) < 1e-4


def test_sequential_engine_csr_tracks_dense(sparse_prob):
    """GS / block-GS / RK actions through the CSR format stay within fp
    noise of the dense format (same keys => same index sequence)."""
    x0 = jnp.zeros_like(sparse_prob.x_star)
    cop = CsrOp.from_dense(sparse_prob.A)
    dop = DenseOp(sparse_prob.A)
    for action, kw in (("gs", {}), ("gs", {"block": 16}), ("rk", {})):
        ni = 512 if kw else 2048
        sc = solve_sequential(cop, sparse_prob.b, x0, sparse_prob.x_star,
                              action=action, key=jax.random.key(4),
                              num_iters=ni, **kw)
        sd = solve_sequential(dop, sparse_prob.b, x0, sparse_prob.x_star,
                              action=action, key=jax.random.key(4),
                              num_iters=ni, **kw)
        assert float(jnp.abs(sc.x - sd.x).max()) < 1e-4, (action, kw)


def test_sequential_engine_banded_converges(banded_prob):
    """Θ(nnz) sequential block-GS on the banded format actually solves."""
    op = BlockBandedOp.from_dense(banded_prob.A, block=32, bands=2)
    x0 = jnp.zeros_like(banded_prob.x_star)
    res = solve_sequential(op, banded_prob.b, x0, banded_prob.x_star,
                           action="gs", key=jax.random.key(3), num_iters=320,
                           beta=0.9, record_every=80)
    e = np.asarray(res.err_sq)
    assert e[-1].max() < 1e-2 * e[0].max()
    rel = float(jnp.linalg.norm(banded_prob.b - banded_prob.A @ res.x)
                / jnp.linalg.norm(banded_prob.b))
    assert rel < 1e-2, rel
