"""Banded + halo-exchange distributed solver variants (§Perf structural
optimizations): convergence, and bit-identity of the halo iterates with the
all-gather version (the gathered entries outside the halo are never read)."""
import textwrap

import pytest

from conftest import run_script_in_subprocess

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import block_banded_spd
    from repro.core.parallel_rgs import parallel_rgs_banded, parallel_rgs_halo
    from repro.kernels.bbmv import dense_to_bands
    from repro.launch.mesh import make_host_mesh

    prob = block_banded_spd(1024, block=32, bands=2, n_rhs=4, seed=0)
    Ab = dense_to_bands(prob.A, bands=2, block=32)
    mesh = make_host_mesh(8)
    x0 = jnp.zeros_like(prob.x_star)

    rb = parallel_rgs_banded(Ab, prob.b, x0, prob.x_star,
                             key=jax.random.key(0), mesh=mesh, rounds=10,
                             local_steps=8, block=32, bands=2, beta=0.9)
    resid = float(jnp.linalg.norm(prob.b - prob.A @ rb.x) /
                  jnp.linalg.norm(prob.b))
    assert resid < 1e-3, resid

    rh = parallel_rgs_halo(Ab, prob.b, x0, key=jax.random.key(0), mesh=mesh,
                           rounds=10, local_steps=8, block=32, bands=2,
                           beta=0.9)
    # identical iterates: the halo IS the full information set for a band
    assert float(jnp.abs(rb.x - rh.x).max()) == 0.0

    # metrics off the hot loop changes nothing about the iterates
    rh2 = parallel_rgs_halo(Ab, prob.b, x0, key=jax.random.key(0), mesh=mesh,
                            rounds=10, local_steps=8, block=32, bands=2,
                            beta=0.9, with_metrics=False)
    assert float(jnp.abs(rh2.x - rh.x).max()) == 0.0

    # residual metric decreases over rounds
    r = np.asarray(rh.resid)[:, 0]
    assert r[-1] < 1e-2 * r[0]
    print("BANDED_OK")
""")


@pytest.mark.slow
def test_banded_and_halo_variants():
    out = run_script_in_subprocess(SCRIPT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BANDED_OK" in out.stdout
