"""Randomized Kaczmarz subsystem (paper Sec. 7): agreement with lstsq,
exact degeneracy of the distributed solver at P=1, the bounded-delay
simulator, and the Strohmer-Vershynin expected-error bound."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_script_in_subprocess
from repro.core import (async_rk_solve, parallel_rk_solve, random_lsq,
                        rk_effective_tau, rk_solve, theory)
from repro.launch.mesh import make_host_mesh


def test_rk_matches_lstsq_consistent():
    """On a consistent overdetermined system RK converges to the unique
    least-squares solution (== the planted coefficients)."""
    prob = random_lsq(240, 40, n_rhs=3, noise=0.0, col_scale=0.0, seed=0)
    assert bool(jnp.allclose(prob.x_star, prob.x_true))
    x0 = jnp.zeros_like(prob.x_star)
    res = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(1),
                   num_iters=4000, record_every=1000)
    rel = float(jnp.linalg.norm(res.x - prob.x_star) /
                jnp.linalg.norm(prob.x_star))
    assert rel < 1e-3, rel
    relresid = float(jnp.linalg.norm(prob.b - prob.A @ res.x) /
                     jnp.linalg.norm(prob.b))
    assert relresid < 1e-3, relresid
    # error drops by orders of magnitude over the recorded trajectory
    # (no strict per-record monotonicity: the tail sits at the f32 floor)
    e = np.asarray(res.err_sq).max(axis=1)
    assert e[-1] < 1e-3 * e[0], e


def test_rk_matches_lstsq_noisy():
    """With noisy b, RK reaches the low-accuracy neighborhood of the
    jnp.linalg.lstsq solution (its convergence horizon)."""
    prob = random_lsq(240, 40, n_rhs=3, noise=0.05, col_scale=0.0, seed=1)
    x0 = jnp.zeros_like(prob.x_star)
    res = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(2),
                   num_iters=6000)
    rel = float(jnp.linalg.norm(res.x - prob.x_star) /
                jnp.linalg.norm(prob.x_star))
    assert rel < 0.1, rel
    # and the residual sits within RK's convergence horizon of the optimum
    # (plain RK does not reach the LSQ residual exactly on inconsistent b)
    floor = float(jnp.linalg.norm(prob.b - prob.A @ prob.x_star))
    got = float(jnp.linalg.norm(prob.b - prob.A @ res.x))
    assert got < 2.0 * floor, (got, floor)


def test_parallel_p1_bit_identical_to_sequential():
    """The acceptance-criterion degeneracy: parallel_rk_solve on a 1-worker
    mesh reproduces sequential RK bit-for-bit (same key, same schedule)."""
    prob = random_lsq(256, 64, n_rhs=2, noise=0.0, col_scale=0.0, seed=1)
    x0 = jnp.zeros_like(prob.x_star)
    mesh = make_host_mesh(1)
    for local_steps, rounds in ((1, 64), (16, 8)):
        p = parallel_rk_solve(prob.A, prob.b, x0, prob.x_star,
                              key=jax.random.key(3), mesh=mesh,
                              rounds=rounds, local_steps=local_steps)
        s = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(3),
                     num_iters=rounds * local_steps)
        assert bool(jnp.array_equal(p.x, s.x)), (
            local_steps, float(jnp.abs(p.x - s.x).max()))
        assert p.tau == rk_effective_tau(1, local_steps) == 0


def test_rk_error_under_theory_bound():
    """E||x_t - x*||^2 <= rk_factor^t ||x0 - x*||^2 (Strohmer-Vershynin):
    the mean over independent runs stays under the bound curve (with slack
    for finite sampling)."""
    prob = random_lsq(160, 32, n_rhs=4, noise=0.0, col_scale=0.0, seed=2)
    x0 = jnp.zeros_like(prob.x_star)
    factor = float(theory.rk_factor(prob.A))
    assert 0.0 < factor < 1.0
    e0 = float(jnp.sum(prob.x_star**2))  # per-RHS errors summed below
    runs = []
    for seed in range(5):
        res = rk_solve(prob.A, prob.b, x0, prob.x_star,
                       key=jax.random.key(10 + seed), num_iters=1200,
                       record_every=200)
        runs.append(np.asarray(res.err_sq).sum(axis=1))
    mean_err = np.stack(runs).mean(axis=0)
    iters = np.asarray(res.iters)
    bound = np.asarray([float(theory.rk_bound(e0, int(t), factor))
                        for t in iters])
    assert (mean_err <= 3.0 * bound).all(), np.stack([mean_err, bound])


def test_async_rk_tau0_matches_sequential():
    """tau = 0 degenerates to synchronous RK (no invisible updates)."""
    prob = random_lsq(120, 24, n_rhs=2, noise=0.0, col_scale=0.0, seed=3)
    x0 = jnp.zeros_like(prob.x_star)
    a = async_rk_solve(prob.A, prob.b, x0, prob.x_star,
                       key=jax.random.key(5), delay_key=jax.random.key(6),
                       num_iters=500, tau=0)
    s = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(5),
                 num_iters=500)
    assert bool(jnp.allclose(a.x, s.x, atol=1e-5)), \
        float(jnp.abs(a.x - s.x).max())


@pytest.mark.parametrize("read_model", ["consistent", "inconsistent"])
def test_async_rk_converges_with_theory_step(read_model):
    """Delay-tau RK with beta~ = 1/(1+2 rho_rk tau) still contracts."""
    prob = random_lsq(160, 32, n_rhs=2, noise=0.0, col_scale=0.0, seed=4)
    x0 = jnp.zeros_like(prob.x_star)
    tau = 16
    beta = theory.beta_opt_rk(float(theory.rk_rho(prob.A)), tau)
    assert 0.0 < beta <= 1.0
    res = async_rk_solve(prob.A, prob.b, x0, prob.x_star,
                         key=jax.random.key(7), delay_key=jax.random.key(8),
                         num_iters=4000, tau=tau, beta=beta,
                         read_model=read_model, record_every=1000)
    e = np.asarray(res.err_sq).max(axis=1)
    assert e[-1] < 0.1 * float(jnp.sum(prob.x_star**2, axis=0).max()), e


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (parallel_rk_solve, random_lsq, rk_effective_tau,
                            rk_solve, theory)
    from repro.launch.mesh import make_host_mesh

    prob = random_lsq(512, 64, n_rhs=2, noise=0.0, col_scale=0.0, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    mesh = make_host_mesh(8)
    tau = rk_effective_tau(8, 16)
    beta = theory.beta_opt_rk(float(theory.rk_rho(prob.A)), tau)

    res = parallel_rk_solve(prob.A, prob.b, x0, prob.x_star,
                            key=jax.random.key(0), mesh=mesh, rounds=150,
                            local_steps=16, beta=beta)
    assert res.tau == tau == 15
    e = np.asarray(res.err_sq)
    assert e[-1].max() < 1e-2 * e[0].max(), e[:, 0]
    resid = float(jnp.linalg.norm(prob.b - prob.A @ res.x) /
                  jnp.linalg.norm(prob.b))
    assert resid < 0.05, resid

    # the stale schedule tracks the sequential solver closely: same picks,
    # staleness only within rounds
    seq = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(0),
                   num_iters=150 * 16, beta=beta)
    gap = float(jnp.linalg.norm(res.x - seq.x) / jnp.linalg.norm(seq.x))
    assert gap < 0.5, gap
    print("PARALLEL_RK_OK")
""")


@pytest.mark.slow
def test_parallel_rk_8_workers():
    out = run_script_in_subprocess(SCRIPT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PARALLEL_RK_OK" in out.stdout
