"""End-to-end trainer behaviour: loss goes down, checkpoint/restart resumes
bit-identically, and the paper's async-tau mode trains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_run_config, get_smoke_config
from repro.train import steps as ST
from repro.train.trainer import Trainer, make_data


def _trainer(tmpdir="", arch="xlstm-125m", steps=30, **kw):
    cfg = get_smoke_config(arch)
    rcfg = get_run_config(arch).with_(
        total_steps=steps, warmup_steps=2, loss_chunk=16, q_chunk=16,
        checkpoint_dir=str(tmpdir), learning_rate=3e-3, **kw)
    part = ST.make_partitioner(None, 4)
    data = make_data(cfg, seq_len=32, global_batch=4)
    return Trainer(cfg=cfg, rcfg=rcfg, part=part, data=data, log_every=5,
                   log_fn=lambda *_: None)


def test_loss_decreases():
    tr = _trainer(arch="qwen2-1.5b", steps=30)
    hist = tr.run(30)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.2, (first, last)


def test_checkpoint_restart_bit_identical(tmp_path):
    """4 straight steps == 2 steps + save + fresh trainer resume + 2 steps."""
    a = _trainer(tmp_path / "a", steps=4, checkpoint_every=2)
    a.run(4)
    ref = jax.tree.leaves(a.state.params)

    b1 = _trainer(tmp_path / "b", steps=4, checkpoint_every=2)
    b1.run(2)          # saves step_2 via checkpoint_every
    b2 = _trainer(tmp_path / "b", steps=4, checkpoint_every=2)  # resumes at 2
    assert int(b2.state.step) == 2
    b2.run(2)
    got = jax.tree.leaves(b2.state.params)
    for x, y in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_preemption_hook(tmp_path):
    tr = _trainer(tmp_path, steps=3)
    tr.request_checkpoint()
    tr.run(1)
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_tau_trains():
    """Bounded-staleness DP (the paper's technique): still converges, with
    the beta~-damped LR."""
    tr = _trainer(arch="qwen2-1.5b", steps=40, async_tau=2)
    hist = tr.run(40)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1
    # staleness damping: lr == schedule * beta~ = schedule / (1 + tau)
    sync = _trainer(arch="qwen2-1.5b", steps=40)
    h2 = sync.run(10)
    # entries at the same logged step (warmup passed by entry 1)
    np.testing.assert_allclose(hist[1]["lr"], h2[1]["lr"] / 3.0, rtol=1e-5)


def test_int8_compression_trains():
    tr = _trainer(arch="qwen2-1.5b", steps=30, grad_compression="int8")
    hist = tr.run(30)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1
