"""Serving-layer tests (ISSUE 9): the x_star=None front-door sweep, the
effective-configuration validation, the single-sourced record_every check,
and the continuous-batching service — concurrent tenants share one batched
launch (executor-cache counters prove it), bucket padding round-trips
bitwise against an unpadded solo solve, per-request tolerances exit early
inside a shared batch, and deadlines complete with partial iterates."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Schedule, random_sparse_lsq, random_sparse_spd, solve
from repro.core.engine import resolve_record_every, solve_batched
from repro.core.operators import as_operator
from repro.launch.mesh import make_host_mesh
from repro.serve import (
    ExecutorCache, SolverService, bucket_rhs, open_loop_load, pad_columns)


@pytest.fixture(scope="module")
def prob():
    return random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=0)


# -- satellite: x_star=None through every solve() path ----------------------

def _assert_blind_result(res, prob):
    """x_star=None: err is NaN (unknowable), resid is finite and real."""
    assert bool(jnp.isnan(res.err_sq).all())
    resid = np.asarray(res.resid)
    assert np.isfinite(resid).all()
    # the iterate genuinely converges toward A x = b, not just "no crash"
    final = np.linalg.norm(
        np.asarray(prob.b - prob.A @ res.x), axis=0)
    b_norm = np.linalg.norm(np.asarray(prob.b), axis=0)
    assert (final < 0.2 * b_norm).all()


def test_x_star_none_sequential(prob):
    blind = prob._replace(x_star=None)
    res = solve(blind, key=jax.random.key(0),
                schedule=Schedule(num_iters=2048, record_every=256))
    _assert_blind_result(res, prob)


def test_x_star_none_async_sim(prob):
    blind = prob._replace(x_star=None)
    res = solve(blind, key=jax.random.key(0),
                delay_key=jax.random.key(1),
                schedule=Schedule(num_iters=2048, tau=4, record_every=256))
    _assert_blind_result(res, prob)


def test_x_star_none_distributed(prob):
    blind = prob._replace(x_star=None)
    res = solve(blind, key=jax.random.key(0), format="csr",
                mesh=make_host_mesh(1),
                schedule=Schedule(rounds=8, local_steps=128))
    _assert_blind_result(res, prob)


def test_x_star_none_rk_path():
    lsq = random_sparse_lsq(96, 48, row_nnz=6, n_rhs=1, seed=1)
    res = solve(lsq._replace(x_star=None), key=jax.random.key(0),
                schedule=Schedule(num_iters=4096, record_every=512))
    assert bool(jnp.isnan(res.err_sq).all())
    assert np.isfinite(np.asarray(res.resid)).all()
    # RK iterate lives in column space — the x0 derivation must use
    # op.shape[1], not b's row count
    assert res.x.shape == (48, 1)


# -- satellite: effective-config validation + single-sourced record check ---

def test_fused_override_validated_before_dispatch(prob):
    """``fused=True`` forced onto the bounded-delay simulator must fail
    ``Schedule.validate()`` (an effective-config error), not reach a late
    warning-and-fallback path."""
    with pytest.raises(ValueError, match="bounded-delay simulator"):
        solve(prob, key=jax.random.key(0), delay_key=jax.random.key(1),
              schedule=Schedule(num_iters=64, tau=4), fused=True)
    with pytest.raises(ValueError, match="bounded-delay simulator"):
        Schedule(num_iters=64, tau=4, fused=True).validate()
    # the keyword can also DISABLE fused on a fused schedule: valid
    sched = Schedule(num_iters=64, tau=4, fused=True)
    res = solve(prob, key=jax.random.key(0), delay_key=jax.random.key(1),
                schedule=sched, fused=False)
    assert np.isfinite(np.asarray(res.resid)).all()


def test_record_every_single_source(prob):
    assert resolve_record_every(128, 32) == 32
    assert resolve_record_every(128, 0) == 128     # 0 = record once, at end
    with pytest.raises(ValueError, match=r"100.*must be divisible.*32"):
        resolve_record_every(100, 32)
    # the batched entry and the service both route through the same check
    op = as_operator(prob.A, "dense")
    with pytest.raises(ValueError, match=r"100.*must be divisible.*32"):
        solve_batched(op, prob.b, action="gs", key=jax.random.key(0),
                      num_iters=100, record_every=32, tol=0.0)
    with pytest.raises(ValueError, match=r"100.*must be divisible.*32"):
        SolverService(num_iters=100, record_every=32)


# -- tentpole: the continuous-batching service ------------------------------

def _service(prob, **kw):
    kw.setdefault("num_iters", 2048)
    kw.setdefault("record_every", 64)
    svc = SolverService(cache=ExecutorCache(), **kw)
    svc.register("spd", prob.A, action="gs", format="csr", seed=0)
    return svc


def test_concurrent_tenants_share_one_batched_launch(prob):
    """Three tenants submitting concurrently land in ONE batch: one entry
    in the executor cache (miss=1), one batched solve, and each tenant
    gets back exactly its own columns."""
    svc = _service(prob)
    rng = np.random.default_rng(3)
    widths = (1, 2, 3)
    blocks = [rng.standard_normal((64, w)).astype(np.float32)
              for w in widths]
    tickets = [None] * len(blocks)
    barrier = threading.Barrier(len(blocks))

    def tenant(i):
        barrier.wait()
        tickets[i] = svc.submit("spd", blocks[i], rtol=1e-3)

    threads = [threading.Thread(target=tenant, args=(i,))
               for i in range(len(blocks))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # all three queued before the loop starts: one drain -> one batch
    with svc:
        results = [t.result(timeout=120) for t in tickets]

    assert svc.stats.batches == 1
    assert svc.stats.batch_widths == [sum(widths)]    # 6 -> bucket 8
    assert svc.executors.stats() == {"hits": 0, "misses": 1, "entries": 1}
    for w, blk, r in zip(widths, blocks, results):
        assert r.x.shape == (64, w)
        assert np.asarray(r.converged).all()
        assert (np.asarray(r.resid)
                <= 1e-3 * np.linalg.norm(blk, axis=0)).all()

    # a later same-bucket batch REUSES the executor: hit, no new entry
    with svc:
        t2 = svc.submit("spd", rng.standard_normal((64, 6)), rtol=1e-3)
        assert np.asarray(t2.result(timeout=120).converged).all()
    assert svc.executors.stats() == {"hits": 1, "misses": 1, "entries": 1}


def test_bucket_padding_bitwise_vs_unpadded_solo(prob):
    """A width-3 request rides in a width-4 bucket; its columns must take
    bitwise the trajectory of an unpadded solo ``solve_batched`` (zero
    padding is exact: padded columns stay identically zero)."""
    b = np.random.default_rng(5).standard_normal((64, 3)).astype(np.float32)
    tol = (1e-3 * np.linalg.norm(b, axis=0)).astype(np.float32)

    svc = _service(prob)
    with svc:
        served = svc.submit("spd", b, tol=tol).result(timeout=120)
    assert svc.stats.batch_widths == [3]
    assert bucket_rhs(3) == 4                 # it really was padded

    op = as_operator(prob.A, "csr")
    solo = solve_batched(op, jnp.asarray(b), action="gs",
                         key=jax.random.key(0), num_iters=2048,
                         record_every=64, tol=tol)
    assert bool(jnp.array_equal(served.x, solo.x))
    assert bool(jnp.array_equal(served.resid, solo.resid))
    assert np.array_equal(np.asarray(served.rounds),
                          np.asarray(solo.rounds))
    # and the pad itself is inert: a padded run's real columns match too
    padded = solve_batched(op, pad_columns(jnp.asarray(b), 4), action="gs",
                           key=jax.random.key(0), num_iters=2048,
                           record_every=64,
                           tol=np.concatenate([tol, [np.inf]]))
    assert bool(jnp.array_equal(padded.x[:, :3], solo.x))


def test_per_request_tolerance_early_exit(prob):
    """A loose-tolerance tenant leaves its shared batch at an earlier
    record point than a tight-tolerance tenant — each is judged by its own
    tol, and each result satisfies it."""
    rng = np.random.default_rng(7)
    b1 = rng.standard_normal((64, 1)).astype(np.float32)
    b2 = rng.standard_normal((64, 1)).astype(np.float32)
    svc = _service(prob)
    t_loose = svc.submit("spd", b1, rtol=0.3)
    t_tight = svc.submit("spd", b2, rtol=1e-5)
    with svc:
        loose = t_loose.result(timeout=120)
        tight = t_tight.result(timeout=120)
    assert svc.stats.batches == 1             # they DID share a batch
    assert np.asarray(loose.converged).all()
    assert np.asarray(tight.converged).all()
    assert int(loose.rounds.max()) < int(tight.rounds.max())
    assert float(loose.resid[0]) <= 0.3 * np.linalg.norm(b1)
    assert float(tight.resid[0]) <= 1e-5 * np.linalg.norm(b2)
    # the early leaver stopped receiving partials once it completed
    assert len(t_loose.partials) < len(t_tight.partials)


def test_deadline_completes_with_partial_iterate(prob):
    """A request past its deadline is completed at the next record point
    with its current partial iterate, marked unconverged."""
    b = np.random.default_rng(9).standard_normal((64, 1)).astype(np.float32)
    svc = _service(prob)
    with svc:
        # rtol far below the f32 floor: can never converge; deadline in the
        # past: expires at the FIRST record point
        ticket = svc.submit("spd", b, rtol=1e-12, deadline_s=0.0)
        res = ticket.result(timeout=120)
    assert not np.asarray(res.converged).any()
    assert res.iters_run == 64                # one record chunk, then out
    assert np.isfinite(np.asarray(res.resid)).all()
    assert svc.stats.deadline_expired == 1


def test_streamed_partials_and_progress_callback(prob):
    """Partials stream at every record point the request is in flight at,
    monotone in iteration count, through both the ticket and the
    ``on_progress`` callback."""
    b = np.random.default_rng(11).standard_normal((64, 2)).astype(np.float32)
    seen = []
    svc = _service(prob)
    with svc:
        ticket = svc.submit("spd", b, rtol=1e-4, on_progress=seen.append)
        res = ticket.result(timeout=120)
    assert np.asarray(res.converged).all()
    assert ticket.partials == seen
    iters = [p.iters for p in ticket.partials]
    assert iters == sorted(set(iters))
    for p in ticket.partials:
        assert p.x.shape == (64, 2)           # bucket padding stripped
        assert p.resid.shape == (2,)
    # partials precede the exit round; the final result is not a partial
    assert all(p.iters < res.iters_run for p in ticket.partials)


def test_open_loop_load_converges(prob):
    """The load generator end to end: mixed widths, all requests converge,
    latency/throughput stats populated (the CI serve-smoke entry point)."""
    svc = _service(prob, batch_window_s=0.005)
    with svc:
        report = open_loop_load(svc, "spd", requests=8, rate_hz=400.0,
                                rhs_widths=(1, 2, 4), rtol=1e-3, seed=0)
    assert report.converged == report.requests == 8
    assert svc.stats.requests == 8
    assert report.qps > 0 and np.isfinite(report.p50_ms)
    assert report.p50_ms <= report.p99_ms
    assert len(report.latencies_ms) == 8
    # batching happened: fewer batches than requests
    assert svc.stats.batches < 8


def test_submit_validates_shape_and_service_restarts(prob):
    svc = _service(prob)
    with pytest.raises(ValueError, match="expects"):
        svc.submit("spd", np.zeros((32, 1), np.float32))
    with pytest.raises(KeyError):
        svc.submit("nope", np.zeros((64, 1), np.float32))
    # start/stop twice: the loop thread is restartable
    for _ in range(2):
        with svc:
            t = svc.submit("spd", np.ones((64,), np.float32), rtol=1e-2)
            assert np.asarray(t.result(timeout=120).converged).all()
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="already started"):
        svc.start()
        svc.start()
    svc.stop()
