"""Cross-implementation consistency of the distributed solvers: the
``parallel_rgs_halo`` docstring claims its iterates are IDENTICAL to
``parallel_rgs_banded`` (same key, same schedule) because the gathered
entries outside the halo are never read — and that ``with_metrics=False``
changes nothing about the iterates.  Verified here on a different
configuration (P=4, bands=1, uneven local_steps, damped beta) than the
convergence test in test_parallel_rgs2."""
import textwrap

import pytest

from conftest import run_script_in_subprocess

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import block_banded_spd
    from repro.core.parallel_rgs import parallel_rgs_banded, parallel_rgs_halo
    from repro.kernels.bbmv import dense_to_bands
    from repro.launch.mesh import make_host_mesh

    prob = block_banded_spd(512, block=16, bands=1, n_rhs=3, seed=2)
    Ab = dense_to_bands(prob.A, bands=1, block=16)
    mesh = make_host_mesh(4)
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(key=jax.random.key(5), mesh=mesh, rounds=7, local_steps=5,
              block=16, bands=1, beta=0.7)

    rb = parallel_rgs_banded(Ab, prob.b, x0, prob.x_star, **kw)
    rh = parallel_rgs_halo(Ab, prob.b, x0, **kw)
    # the docstring claim: identical iterates, not merely close
    assert float(jnp.abs(rb.x - rh.x).max()) == 0.0

    # with_metrics=False must not change iterates — for both variants
    rb2 = parallel_rgs_banded(Ab, prob.b, x0, prob.x_star,
                              with_metrics=False, **kw)
    rh2 = parallel_rgs_halo(Ab, prob.b, x0, with_metrics=False, **kw)
    assert float(jnp.abs(rb2.x - rb.x).max()) == 0.0
    assert float(jnp.abs(rh2.x - rh.x).max()) == 0.0
    # and the metrics-off outputs are the documented zero placeholders
    assert float(jnp.abs(rb2.err_sq).max()) == 0.0
    assert float(jnp.abs(rh2.resid).max()) == 0.0

    # both still make progress under the damped step
    resid = float(jnp.linalg.norm(prob.b - prob.A @ rh.x) /
                  jnp.linalg.norm(prob.b))
    assert resid < 0.5, resid
    print("CONSISTENCY_OK")
""")


@pytest.mark.slow
def test_halo_banded_identity_and_metrics_invariance():
    out = run_script_in_subprocess(SCRIPT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "CONSISTENCY_OK" in out.stdout
