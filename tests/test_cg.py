"""CG baseline + flexible CG preconditioned by randomized GS sweeps (the
paper's proposed future-work path, Sec. 8/9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cg_solve, fcg_solve, make_rgs_preconditioner,
                        laplacian_spd, random_sparse_spd)


@pytest.fixture(scope="module")
def prob():
    return random_sparse_spd(256, row_nnz=8, n_rhs=3, seed=2)


def test_cg_converges_fast(prob):
    res = cg_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star), prob.x_star,
                   num_iters=40)
    assert float(res.resid[-1].max()) < 1e-5
    # residual is (weakly) decreasing in the A-norm error
    e = np.asarray(res.err_sq[:, 0])
    assert e[-1] < e[0] * 1e-6


def test_cg_multi_rhs_independent(prob):
    res = cg_solve(prob.A, prob.b, jnp.zeros_like(prob.x_star), prob.x_star,
                   num_iters=30)
    one = cg_solve(prob.A, prob.b[:, 1:2], jnp.zeros_like(prob.x_star[:, 1:2]),
                   prob.x_star[:, 1:2], num_iters=30)
    np.testing.assert_allclose(np.asarray(res.x[:, 1]), np.asarray(one.x[:, 0]),
                               atol=1e-4)


def test_fcg_with_rgs_preconditioner_beats_plain_cg_periteration():
    """On an ill-conditioned Laplacian, FCG+RGS-sweeps reduces the residual
    at least as fast per iteration as plain CG (it does strictly more work
    per iteration; the point is that the changing preconditioner is stable
    in the flexible formulation)."""
    prob = laplacian_spd(16, shift=1e-2, n_rhs=2, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    iters = 12
    plain = cg_solve(prob.A, prob.b, x0, prob.x_star, num_iters=iters)
    pre = make_rgs_preconditioner(prob.A, sweeps=2, block=16, beta=1.0)
    flex = fcg_solve(prob.A, prob.b, x0, prob.x_star, precond=pre,
                     num_iters=iters)
    assert float(flex.resid[-1].max()) < float(plain.resid[-1].max())
    assert float(flex.resid[-1].max()) < 1e-2 * float(flex.resid[0].max())
