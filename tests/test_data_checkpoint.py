"""Data pipeline (deterministic random access) + mesh-agnostic atomic
checkpointing — the restart/elasticity substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train import checkpoint as ckpt


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_batches_are_deterministic():
    d1 = SyntheticLM(_cfg())
    d2 = SyntheticLM(_cfg())
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_steps_differ():
    d = SyntheticLM(_cfg())
    assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(_cfg()).batch_at(5)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@given(st.integers(0, 1000), st.integers(1, 4))
def test_host_slices_tile_the_global_batch(step, num_hosts):
    cfg = _cfg(global_batch=8)
    if 8 % num_hosts:
        return
    d = SyntheticLM(cfg)
    full = d.batch_at(step)["tokens"]
    per = 8 // num_hosts
    parts = [d.host_slice(step, h, num_hosts)["tokens"] for h in range(num_hosts)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_modality_stubs_shapes():
    d = SyntheticLM(_cfg(frames=10, d_model=12))
    b = d.batch_at(0)
    assert b["frames"].shape == (8, 10, 12)
    d = SyntheticLM(_cfg(patches=6, d_model=12))
    b = d.batch_at(0)
    assert b["patches"].shape == (8, 6, 12)
    assert (b["labels"][:, :6] == -1).all()   # patch positions have no target


def test_tokens_in_range():
    b = SyntheticLM(_cfg()).batch_at(9)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128


# -- checkpointing ------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "blocks": (jnp.ones((2, 2)), jnp.zeros(3))},
            "step": jnp.array(7)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree, extra={"data_step": 7})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, manifest = ckpt.restore(str(tmp_path), 7, like)
    assert manifest["extra"]["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_incomplete(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    # a crashed save: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_overwrite_same_step(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 2, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    ckpt.save(str(tmp_path), 2, tree2)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, _ = ckpt.restore(str(tmp_path), 2, like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))


def test_restore_casts_dtype(tmp_path):
    """Mesh/dtype-agnostic restore: loading into a bf16 'like' tree casts."""
    tree = {"w": jnp.ones((4,), jnp.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    like = {"w": jnp.zeros((4,), jnp.bfloat16)}
    restored, _ = ckpt.restore(str(tmp_path), 1, like)
    assert restored["w"].dtype == jnp.bfloat16
