"""Autotuning subsystem (repro.tune): table round-trip, the bitwise
fallback/forcing contract at every dispatch seam, and the committed CPU
smoke table's acceptance pins.

The contract under test (DESIGN.md §9): explicit choices (``fused``
bools, ``variant=``, ``skip_empty=``, integer ``rows_per_panel``) are
FORCED and bitwise-pinned to pre-autotune behavior; ``"auto"`` resolves
through the active table silently; a missing entry (or no table) runs
today's hardcoded default, bitwise-unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from conftest import run_forced_device_script
from repro.core import CsrOp, Schedule, random_sparse_spd, solve
from repro.core.engine import solve_sequential
from repro.tune import (TuneKey, TuningTable, shape_bucket, use_table)
from repro.tune import runtime
from repro.tune.table import default_path


def _table_for(op, *entries) -> TuningTable:
    """A synthetic in-memory table with the given (key, choice) pairs."""
    t = TuningTable(backend="cpu", device_kind="cpu", interpret_mode=True,
                    jax_version=jax.__version__)
    for key, choice in entries:
        t.record(key, choice, {choice: 1.0})
    return t


# -- table mechanics ---------------------------------------------------------

def test_shape_bucket_rounds_up_to_power_of_two():
    assert shape_bucket(1) == "n1"
    assert shape_bucket(1000) == "n1024"
    assert shape_bucket(1024) == "n1024"
    assert shape_bucket(1025) == "n2048"


def test_table_roundtrip_identical_choices(tmp_path):
    t = TuningTable(backend="cpu", device_kind="cpu", interpret_mode=True,
                    jax_version="0.x")
    t.record(TuneKey("sweep", "CsrOp", "gs", "n256", "f32"), "scan",
             {"scan": 10.0, "fused": 20.0})
    t.record(TuneKey("matvec", "CsrOp", "-", "n256", "f32"), "sliced",
             {"sliced": 1.0})
    path = t.save(tmp_path / "TUNE_test.json")
    back = TuningTable.load(path)
    assert back.choices() == t.choices()
    assert back.backend == "cpu" and back.interpret_mode is True


def test_table_load_drops_entries_on_version_mismatch(tmp_path):
    t = TuningTable(backend="cpu", version=999)
    t.record(TuneKey("sweep", "CsrOp", "gs", "n256", "f32"), "fused",
             {"fused": 1.0})
    back = TuningTable.load(t.save(tmp_path / "TUNE_old.json"))
    assert back.entries == {}   # fallback contract: unknown schema -> defaults


def test_schedule_rejects_non_tristate_fused():
    with pytest.raises(ValueError):
        Schedule(num_iters=8, fused="always").validate()


# -- fused-vs-scan seam ------------------------------------------------------

def _gs_problem(n=96):
    prob = random_sparse_spd(n, row_nnz=8, n_rhs=2, seed=0)
    cop = CsrOp.from_dense(prob.A)
    return prob, cop, jnp.zeros_like(prob.x_star)


def _seq(cop, prob, x0, fused):
    return solve_sequential(cop, prob.b, x0, prob.x_star, action="gs",
                            key=jax.random.key(3), num_iters=64,
                            record_every=32, fused=fused)


def test_resolve_fused_explicit_bools_never_overridden():
    _prob, cop, _x0 = _gs_problem()
    steer = _table_for(cop, (runtime.sweep_key(cop, "gs"), "fused"))
    with use_table(steer):
        assert runtime.resolve_fused(False, cop, "gs") is False
        assert runtime.resolve_fused(True, cop, "gs") is True
        assert runtime.resolve_fused("auto", cop, "gs") is True
    with use_table(None):
        assert runtime.resolve_fused("auto", cop, "gs") is False


def test_auto_missing_entry_is_bitwise_todays_default():
    prob, cop, x0 = _gs_problem()
    with use_table(None):
        auto = _seq(cop, prob, x0, "auto")
        scan = _seq(cop, prob, x0, False)
    assert_array_equal(np.asarray(auto.x), np.asarray(scan.x))
    assert_array_equal(np.asarray(auto.resid), np.asarray(scan.resid))


def test_auto_with_table_is_bitwise_the_forced_variant():
    prob, cop, x0 = _gs_problem()
    for choice, forced in (("fused", True), ("scan", False)):
        t = _table_for(cop, (runtime.sweep_key(cop, "gs"), choice))
        with use_table(t):
            auto = _seq(cop, prob, x0, "auto")
        explicit = _seq(cop, prob, x0, forced)
        assert_array_equal(np.asarray(auto.x), np.asarray(explicit.x))


# -- CSR matvec seam ---------------------------------------------------------

def _patchy_csr(n=96):
    prob = random_sparse_spd(n, row_nnz=8, n_rhs=1, seed=1)
    A = np.array(prob.A)
    A[0:32] = 0.0          # whole empty panels (rows_per_panel=8)
    return CsrOp.from_dense(jnp.asarray(A)), prob.x_star


def test_matvec_missing_entry_matches_prepr_auto_selection():
    prob, cop, _x0 = _gs_problem()
    pop, x = _patchy_csr()
    with use_table(None):
        # dense panels: auto picked the plain sliced kernel
        assert_array_equal(np.asarray(cop.matvec(prob.x_star)),
                           np.asarray(cop.matvec(prob.x_star,
                                                 skip_empty=False)))
        # empty panels present: auto picked the predicated twin
        assert_array_equal(np.asarray(pop.matvec(x)),
                           np.asarray(pop.matvec(x, skip_empty=True)))


def test_matvec_table_entry_steers_to_segsum_bitwise():
    prob, cop, _x0 = _gs_problem()
    t = _table_for(cop, (runtime.matvec_key(cop), "segsum"))
    with use_table(t):
        steered = cop.matvec(prob.x_star)
    assert_array_equal(np.asarray(steered),
                       np.asarray(cop.matvec_segsum(prob.x_star)))


def test_matvec_explicit_variant_beats_contrary_table():
    prob, cop, _x0 = _gs_problem()
    t = _table_for(cop, (runtime.matvec_key(cop), "segsum"))
    with use_table(t):
        forced = cop.matvec(prob.x_star, variant="sliced")
        skipped = cop.matvec(prob.x_star, skip_empty=False)
    with use_table(None):
        default = cop.matvec(prob.x_star, skip_empty=False)
    assert_array_equal(np.asarray(forced), np.asarray(default))
    assert_array_equal(np.asarray(skipped), np.asarray(default))


def test_matvec_unknown_variant_raises():
    prob, cop, _x0 = _gs_problem()
    with pytest.raises(ValueError, match="unknown matvec variant"):
        cop.matvec(prob.x_star, variant="blocked")


# -- rows_per_panel seam -----------------------------------------------------

def test_tuned_rows_per_panel_is_layout_only_bitwise():
    prob, _cop, _x0 = _gs_problem()
    t = _table_for(None, (runtime.panel_key(prob.A.shape[0]), "4"))
    with use_table(t):
        assert runtime.tuned_rows_per_panel(prob.A.shape[0]) == 4
        auto = solve(prob, key=jax.random.key(7), format="csr",
                     schedule=Schedule(num_iters=48, record_every=48))
    with use_table(None):
        assert runtime.tuned_rows_per_panel(prob.A.shape[0]) is None
        explicit = solve(prob, key=jax.random.key(7), format="csr",
                         rows_per_panel=4,
                         schedule=Schedule(num_iters=48, record_every=48))
        default = solve(prob, key=jax.random.key(7), format="csr",
                        schedule=Schedule(num_iters=48, record_every=48))
    # table-driven == explicitly forced == the default-8 layout: panel
    # grouping never changes per-row summation order
    assert_array_equal(np.asarray(auto.x), np.asarray(explicit.x))
    assert_array_equal(np.asarray(auto.x), np.asarray(default.x))


# -- the committed CPU smoke table -------------------------------------------

def test_committed_cpu_table_pins_scan_for_banded_gs_n1024():
    """The acceptance pin: on the CPU interpret-mode shape the committed
    table selects the scan engine for banded GS at the n=1024 bucket
    (the recorded BENCH inversion of the TPU design point)."""
    table = TuningTable.load(default_path("cpu"))
    assert table.backend == "cpu" and table.interpret_mode is True
    key = TuneKey("sweep", "BlockBandedOp", "gs", "n1024", "f32")
    assert table.lookup(key) == "scan"
    # and through the runtime seam an n<=1024 banded op resolves to scan
    class _Shim:                    # sweep_key reads class name + shape only
        shape = (1024, 1024)
    _Shim.__name__ = "BlockBandedOp"
    with use_table(table):
        assert runtime.fused_choice(_Shim(), "gs") == "scan"
        assert runtime.resolve_fused("auto", _Shim(), "gs") is False


# -- every strategy row resolves silently ------------------------------------

AUTO_RESOLVES_SCRIPT = """
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (BlockBandedOp, CsrOp, DenseOp, EllOp,
                            block_banded_spd, random_sparse_spd)
    from repro.core.engine import _DISTRIBUTED_STRATEGIES, solve_distributed
    from repro.kernels.bbmv import dense_to_bands
    from repro.launch.mesh import make_host_mesh
    from repro.tune import use_table

    mesh = make_host_mesh(4)
    bb = block_banded_spd(64, block=8, bands=1, n_rhs=2, seed=2)
    sp = random_sparse_spd(64, row_nnz=8, n_rhs=2, seed=0)
    width = int((np.asarray(sp.A) != 0).sum(1).max())
    ops = {
        "DenseOp": (DenseOp(sp.A), sp),
        "BlockBandedOp": (BlockBandedOp(
            dense_to_bands(bb.A, bands=1, block=8), bands=1), bb),
        "EllOp": (EllOp.from_dense(sp.A, width=width), sp),
        "CsrOp": (CsrOp.from_dense(sp.A), sp),
    }
    for table in (None,):   # missing-entry path: auto must stay silent
        with use_table(table):
            for (action, fmt, sync), kind in sorted(
                    _DISTRIBUTED_STRATEGIES.items()):
                op, prob = ops[fmt]
                x0 = jnp.zeros_like(prob.x_star)
                with warnings.catch_warnings(record=True) as caught:
                    warnings.simplefilter("always")
                    solve_distributed(
                        op, prob.b, x0, prob.x_star, action=action,
                        sync=sync, fused="auto", key=jax.random.key(1),
                        mesh=mesh, rounds=1, local_steps=4)
                fused_warns = [w for w in caught
                               if "fused" in str(w.message)]
                assert not fused_warns, (action, fmt, sync, fused_warns)
    print("AUTO_RESOLVES_OK")
"""


@pytest.mark.slow
def test_fused_auto_resolves_without_warnings_on_every_strategy_row():
    """``fused="auto"`` means nothing was forced: no strategy row —
    including the ones with no fused local phase — may emit the
    fused-fallback ``UserWarning`` on the auto path."""
    run_forced_device_script(AUTO_RESOLVES_SCRIPT, marker="AUTO_RESOLVES_OK")
