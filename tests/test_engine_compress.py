"""Compressed sync wire formats on a forced 4-device host mesh (subprocess).

Pins the ``Schedule(compress=...)`` contract: ``"none"`` is the default and
bitwise-identical to leaving the knob off; ``"bf16"`` halves the analytic
delta-psum payload and stays on the f32 iterate's convergence track;
``"int8_ef"`` carries the error-feedback residual through the round scan and
flushes it after the final round, so the returned iterate loses nothing a
f32 wire would have delivered; the halo strategy quantizes its edge payloads
(stateless — no feedback needed); unsupported strategies and the bitwise-
pinned a2a exchange fall back with a warning, exactly.
"""
import pytest

from conftest import run_forced_device_script

COMPRESS_RK_SCRIPT = """
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import CsrOp, Schedule, random_sparse_lsq, solve
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)
    prob = random_sparse_lsq(512, 256, row_nnz=6, n_rhs=2, seed=3)
    cop = CsrOp.from_dense(prob.A)
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(action="rk", key=jax.random.key(7), mesh=mesh, rounds=60,
              local_steps=16, beta=1.0, sync="psum")

    r_def = solve_distributed(cop, prob.b, x0, prob.x_star, **kw)
    r_none = solve_distributed(cop, prob.b, x0, prob.x_star,
                               compress="none", **kw)
    # the default IS compress="none", bitwise
    assert bool(jnp.array_equal(r_def.x, r_none.x))
    assert bool(jnp.array_equal(r_def.err_sq, r_none.err_sq))
    assert r_none.bytes_per_round == 4.0 * 256 * 2, r_none.bytes_per_round

    r_bf = solve_distributed(cop, prob.b, x0, prob.x_star,
                             compress="bf16", **kw)
    assert r_bf.bytes_per_round == r_none.bytes_per_round / 2
    r_ef = solve_distributed(cop, prob.b, x0, prob.x_star,
                             compress="int8_ef", **kw)
    assert r_ef.bytes_per_round < r_none.bytes_per_round / 3

    # all three reach the f32 wire's error scale: the compressed runs'
    # final A-free error is within a small factor of the exact wire's
    # (rate preserved, not just 'converges eventually')
    e_none = float(r_none.err_sq[-1].max())
    for name, r in (("bf16", r_bf), ("int8_ef", r_ef)):
        e = float(r.err_sq[-1].max())
        assert e < 4.0 * e_none + 1e-8, (name, e, e_none)
        # and it actually solved: orders of magnitude below the start
        assert e < 0.02 * float(r.err_sq[0].max()), (name, e)

    # overlap composes with EF: dlast + residual flushed after the scan
    r_ov = solve_distributed(cop, prob.b, x0, prob.x_star, compress="int8_ef",
                             overlap=True, **kw)
    e_ov = float(r_ov.err_sq[-1].max())
    assert e_ov < 0.05 * float(r_ov.err_sq[0].max()), e_ov
    assert r_ov.lag is not None

    # a2a + compress: warned fallback to the compressed psum wire, bitwise
    with warnings.catch_warnings(record=True) as wl:
        warnings.simplefilter("always")
        r_a2a = solve_distributed(cop, prob.b, x0, prob.x_star,
                                  **{**kw, "sync": "a2a"}, compress="bf16")
    assert any("bitwise" in str(w.message) for w in wl)
    assert bool(jnp.array_equal(r_a2a.x, r_bf.x))

    # the solve() front door threads schedule.compress (and storage_dtype)
    sched = Schedule(rounds=60, local_steps=16, compress="bf16")
    r_solve = solve(prob, key=jax.random.key(7), mesh=mesh, format="csr",
                    schedule=sched)
    assert bool(jnp.array_equal(r_solve.x, r_bf.x))
    r_lp = solve(prob, key=jax.random.key(7), mesh=mesh, format="csr",
                 schedule=sched, storage_dtype="bfloat16")
    assert float(r_lp.err_sq[-1].max()) < 0.02 * float(r_lp.err_sq[0].max())
    print("COMPRESS_RK_OK")
"""

COMPRESS_HALO_SCRIPT = """
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import BlockBandedOp, DenseOp, block_banded_spd
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)
    bb = block_banded_spd(512, block=16, bands=1, n_rhs=2, seed=5)
    bop = BlockBandedOp.from_dense(bb.A, block=16, bands=1)
    x0 = jnp.zeros_like(bb.x_star)
    kw = dict(action="gs", key=jax.random.key(2), mesh=mesh, rounds=40,
              local_steps=16, beta=0.8, sync="halo")

    r_none = solve_distributed(bop, bb.b, x0, bb.x_star, **kw)
    r_bf = solve_distributed(bop, bb.b, x0, bb.x_star, compress="bf16", **kw)
    r_i8 = solve_distributed(bop, bb.b, x0, bb.x_star, compress="int8_ef",
                             **kw)
    assert r_none.bytes_per_round == 2 * 4.0 * 16 * 2, r_none.bytes_per_round
    assert r_bf.bytes_per_round == r_none.bytes_per_round / 2
    e_none = float(r_none.err_sq[-1].max())
    for name, r in (("bf16", r_bf), ("int8", r_i8)):
        e = float(r.err_sq[-1].max())
        assert e < 4.0 * e_none + 1e-8, (name, e, e_none)
        assert e < 1e-4 * float(r.err_sq[0].max()), (name, e)

    # overlapped halo composes with the codec
    r_ovl = solve_distributed(bop, bb.b, x0, bb.x_star, compress="bf16",
                              overlap=True, **kw)
    assert float(r_ovl.err_sq[-1].max()) < 1e-4 * float(
        r_ovl.err_sq[0].max())

    # strategies without a compressed wire: warned fallback, exact
    dop = DenseOp(bb.A)
    dkw = dict(action="gs", key=jax.random.key(2), mesh=mesh, rounds=10,
               local_steps=16, beta=0.8, sync="allgather")
    r_d = solve_distributed(dop, bb.b, x0, bb.x_star, **dkw)
    with warnings.catch_warnings(record=True) as wl:
        warnings.simplefilter("always")
        r_dc = solve_distributed(dop, bb.b, x0, bb.x_star, compress="bf16",
                                 **dkw)
    assert any("no compressed wire" in str(w.message) for w in wl)
    assert bool(jnp.array_equal(r_d.x, r_dc.x))
    print("COMPRESS_HALO_OK")
"""


@pytest.mark.slow
def test_compressed_rk_delta_sync():
    run_forced_device_script(COMPRESS_RK_SCRIPT, marker="COMPRESS_RK_OK")


@pytest.mark.slow
def test_compressed_halo_exchange():
    run_forced_device_script(COMPRESS_HALO_SCRIPT, marker="COMPRESS_HALO_OK")


def test_schedule_compress_validation():
    from repro.core import Schedule
    with pytest.raises(ValueError, match="unknown compress"):
        Schedule(rounds=2, local_steps=4, compress="fp8").validate()
    with pytest.raises(ValueError, match="distributed-schedule option"):
        Schedule(num_iters=10, compress="bf16").validate()
    Schedule(rounds=2, local_steps=4, compress="int8_ef").validate()
