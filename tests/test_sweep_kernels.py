"""Fused sweep-kernel layer (PR 5): parity with the scan engine across the
operator-conformance grid, kernel-level fuzz on ragged/degenerate pick
streams, the CSR matvec overhaul, and the distributed fused local phases.

Parity contract (ISSUE 5 acceptance): ``fused=True`` iterates match the
scan engine **bitwise** for the GS action (identical update order, exact
masking) and to ≤ 1e-5 relative error for the RK action; formats without a
sweep kernel fall back to the scan with a ``UserWarning``.
"""
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_banded_spd, random_sparse_lsq, random_sparse_spd
from repro.core.engine import Schedule, sample_rows, solve, solve_sequential
from repro.core.operators import BlockBandedOp, CsrOp, DenseOp, EllOp
from repro.kernels import ops

from conftest import run_forced_device_script
from test_operators import GRID, _case


def _solve(op, b, x0, x_star, action, fused):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        return solve_sequential(op, b, x0, x_star, action=action,
                                key=jax.random.key(7), num_iters=48,
                                record_every=24, beta=0.9, fused=fused)


@pytest.mark.parametrize("fmt,spec", GRID,
                         ids=[f"{f}-{i}" for i, (f, _) in enumerate(GRID)])
def test_fused_matches_scan_on_grid(fmt, spec):
    """solve_sequential(fused=True) vs the scan engine over the full
    operator-conformance grid: GS bitwise, RK <= 1e-5 relative."""
    op, A = _case(fmt, spec)
    m, n = op.shape
    k = 2
    x_star = jax.random.normal(jax.random.key(11), (n, k), jnp.float32)
    b = jnp.asarray(np.asarray(A)) @ x_star
    x0 = jnp.zeros_like(x_star)

    actions = []
    if m == n:
        actions.append("gs")
    if fmt != "banded":      # sequential banded RK is not a scan path either
        actions.append("rk")
    for action in actions:
        rs = _solve(op, b, x0, x_star, action, fused=False)
        rf = _solve(op, b, x0, x_star, action, fused=True)
        if action == "gs":
            np.testing.assert_array_equal(np.asarray(rs.x), np.asarray(rf.x))
            np.testing.assert_array_equal(np.asarray(rs.err_sq),
                                          np.asarray(rf.err_sq))
            np.testing.assert_array_equal(np.asarray(rs.resid),
                                          np.asarray(rf.resid))
        else:
            denom = float(jnp.linalg.norm(rs.x)) or 1.0
            assert float(jnp.linalg.norm(rs.x - rf.x)) / denom <= 1e-5
            np.testing.assert_allclose(np.asarray(rs.resid),
                                       np.asarray(rf.resid), rtol=1e-4,
                                       atol=1e-5)


def test_fused_fallback_warns_and_matches():
    """Formats without a sweep kernel (dense) fall back to the scan with a
    UserWarning — and produce the scan's exact iterates."""
    prob = random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=3)
    op = DenseOp(prob.A)
    x0 = jnp.zeros_like(prob.x_star)
    for action in ("gs", "rk"):
        rs = solve_sequential(op, prob.b, x0, prob.x_star, action=action,
                              key=jax.random.key(1), num_iters=16)
        with pytest.warns(UserWarning, match="no fused sweep kernel"):
            rf = solve_sequential(op, prob.b, x0, prob.x_star, action=action,
                                  key=jax.random.key(1), num_iters=16,
                                  fused=True)
        np.testing.assert_array_equal(np.asarray(rs.x), np.asarray(rf.x))


def test_fused_front_door():
    """Schedule(fused=True) through solve() reaches the sweep path (csr,
    bitwise GS); on the bounded-delay simulator it is rejected at
    ``Schedule.validate()`` (effective-config validation, ISSUE 9) — the
    old warn-and-ignore fallback silently ran a different execution mode
    than the schedule asked for."""
    prob = random_sparse_spd(64, row_nnz=6, n_rhs=2, seed=4)
    kw = dict(key=jax.random.key(2), format="csr")
    r0 = solve(prob, schedule=Schedule(num_iters=32, record_every=16), **kw)
    r1 = solve(prob, schedule=Schedule(num_iters=32, record_every=16,
                                       fused=True), **kw)
    np.testing.assert_array_equal(np.asarray(r0.x), np.asarray(r1.x))
    # the solve(..., fused=...) override beats schedule.fused
    r2 = solve(prob, schedule=Schedule(num_iters=32, record_every=16),
               fused=True, **kw)
    np.testing.assert_array_equal(np.asarray(r0.x), np.asarray(r2.x))
    with pytest.raises(ValueError, match="bounded-delay simulator"):
        solve(prob, delay_key=jax.random.key(3),
              schedule=Schedule(num_iters=16, tau=4, fused=True), **kw)


# ---------------------------------------------------------------------------
# Kernel-level references and degenerate pick-stream fuzz
# ---------------------------------------------------------------------------

def _gs_ref(op, b, x, picks, beta=1.0):
    def step(x, r):
        return x.at[r].add(beta * (b[r] - op.row_dot(r, x))), None
    return jax.lax.scan(step, x, picks)[0]


def _rk_ref(op, b, rn, x, picks, beta=1.0):
    def step(x, r):
        g = (b[r] - op.row_dot(r, x)) / rn[r]
        return op.rk_update(x, r, g, beta), None
    return jax.lax.scan(step, x, picks)[0]


@pytest.mark.parametrize("picks", [
    [],                          # empty sweep: the kernel must be a no-op
    [5, 5, 5, 5],                # repeated row (self-coupled updates)
    [12, 12, 0, 5, 12],          # ragged last panel (m=13, R=8) + repeats
    [0, 12, 6, 3, 9, 1],
], ids=["empty", "repeated", "last-panel", "mixed"])
def test_sweep_rows_degenerate_picks(picks):
    # GS needs a square system (rows index the iterate): n=13 keeps the
    # last CSR panel ragged (13 % 8 != 0) so pick 12 exercises it.
    k = 3
    sprob = random_sparse_spd(13, row_nnz=3, n_rhs=k, seed=5)
    x_sq = jax.random.normal(jax.random.key(6), (13, k))
    picks = jnp.asarray(picks, jnp.int32)
    cop = CsrOp.from_dense(sprob.A)
    for op in (cop, EllOp(*cop.padded_rows())):
        vals, cols = op.padded_rows()
        got = ops.sweep_rows_gs(vals, cols, sprob.b, x_sq, picks, beta=0.7)
        want = _gs_ref(op, sprob.b, x_sq, picks, beta=0.7)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # RK additionally covers the rectangular shape (writes land in column
    # space, so picks range over all 13 rows while x has 8).
    lprob = random_sparse_lsq(13, 8, row_nnz=3, n_rhs=k, seed=5)
    x_rect = jax.random.normal(jax.random.key(6), (8, k))
    lcop = CsrOp.from_dense(lprob.A)
    for op in (lcop, EllOp(*lcop.padded_rows())):
        vals, cols = op.padded_rows()
        rn = op.row_norms_sq()
        got = ops.sweep_rows_rk(vals, cols, lprob.b, rn, x_rect, picks,
                                beta=0.7)
        want = _rk_ref(op, lprob.b, rn, x_rect, picks, beta=0.7)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_sweep_rows_zero_rows_are_noops():
    """GS picks landing on all-zero rows only move x by beta*b[r] — and the
    kernel agrees with the scan reference bitwise (the masked windows carry
    exact zeros)."""
    m = n = 16
    A = np.array(random_sparse_spd(n, row_nnz=4, seed=7).A)
    A[::5] = 0.0
    op = CsrOp.from_dense(jnp.asarray(A))
    b = jnp.ones((m, 2))
    x = jnp.zeros((n, 2))
    picks = jnp.asarray([0, 5, 10, 15, 5, 0], jnp.int32)
    vals, cols = op.padded_rows()
    got = ops.sweep_rows_gs(vals, cols, b, x, picks)
    want = _gs_ref(op, b, x, picks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_banded_sweeps_empty_picks():
    prob = block_banded_spd(64, block=16, bands=1, n_rhs=2, seed=8)
    op = BlockBandedOp.from_dense(prob.A, block=16, bands=1)
    empty = jnp.zeros((0,), jnp.int32)
    x = jax.random.normal(jax.random.key(9), (64, 2))
    out = op.gs_sweep(prob.b, x, empty)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    halo = op.bands * op.block
    xw = jnp.pad(x, ((halo, halo), (0, 0)))
    dw = jnp.zeros_like(xw)
    rn = jnp.where(op.row_norms_sq() > 0, op.row_norms_sq(), 1.0)
    xo, do = ops.banded_rk_sweep(op.A_bands, prob.b, rn, xw, dw, empty,
                                 empty, block=op.block, bands=op.bands)
    np.testing.assert_array_equal(np.asarray(xo), np.asarray(xw))
    np.testing.assert_array_equal(np.asarray(do), np.asarray(dw))


def test_rk_sweep_long_stream_stays_close():
    """A full sampled RK sweep (the engine's actual pick law, many steps)
    stays within the 1e-5 relative-parity budget on a rectangular
    system."""
    prob = random_sparse_lsq(128, 32, row_nnz=6, n_rhs=2, seed=10)
    op = CsrOp.from_dense(prob.A)
    rn = op.row_norms_sq()
    picks = sample_rows(jax.random.key(12), rn, 256)
    x = jnp.zeros((32, 2))
    vals, cols = op.padded_rows()
    got = ops.sweep_rows_rk(vals, cols, prob.b, rn, x, picks, beta=0.9)
    want = _rk_ref(op, prob.b, rn, x, picks, beta=0.9)
    denom = float(jnp.linalg.norm(want)) or 1.0
    assert float(jnp.linalg.norm(got - want)) / denom <= 1e-5


# ---------------------------------------------------------------------------
# CSR matvec overhaul: sliced gather-accumulate is the default path
# ---------------------------------------------------------------------------

def test_csr_matvec_paths_agree():
    """Default (sliced), forced-skip, and legacy segsum matvecs agree with
    the dense oracle; auto-selection picks predication exactly when the
    pattern has empty panels."""
    prob = random_sparse_spd(96, row_nnz=7, n_rhs=3, seed=13)
    x = jax.random.normal(jax.random.key(14), (96, 3))
    cop = CsrOp.from_dense(prob.A)
    want = prob.A @ x
    for y in (cop.matvec(x), cop.matvec(x, skip_empty=True),
              cop.matvec(x, skip_empty=False), cop.matvec_segsum(x)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    assert not bool((np.asarray(cop.panel_nnz()) == 0).any())

    Ap = np.array(prob.A)
    Ap[0:cop.rows_per_panel] = 0.0
    pop = CsrOp.from_dense(jnp.asarray(Ap))
    assert bool((np.asarray(pop.panel_nnz()) == 0).any())
    # predicated and plain kernels are bitwise-identical
    np.testing.assert_array_equal(
        np.asarray(pop.matvec(x, skip_empty=True)),
        np.asarray(pop.matvec(x, skip_empty=False)))


def test_csr_sliced_rows_view():
    """The sliced view reconstructs the matrix and is memoized on concrete
    operators."""
    prob = random_sparse_lsq(13, 8, row_nnz=3, n_rhs=1, seed=15)
    op = CsrOp.from_dense(prob.A)
    vals, cols = op.sliced_rows()
    mp = -(-13 // op.rows_per_panel) * op.rows_per_panel
    assert vals.shape == cols.shape and vals.shape[0] == mp
    assert vals.shape[1] % 8 == 0 and vals.shape[1] >= op.row_cap
    recon = jnp.zeros((13, 8)).at[
        jnp.arange(mp)[:, None].clip(0, 12), cols].add(
            jnp.where(jnp.arange(mp)[:, None] < 13, vals, 0.0))
    np.testing.assert_allclose(np.asarray(recon), np.asarray(prob.A),
                               atol=1e-6)
    assert op.sliced_rows()[0] is vals          # memoized


# ---------------------------------------------------------------------------
# Distributed fused local phases (forced-4-device subprocess)
# ---------------------------------------------------------------------------

DIST_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import block_banded_spd
    from repro.core.operators import BlockBandedOp, CsrOp
    from repro.core.engine import solve_distributed
    from repro.launch.mesh import make_host_mesh

    prob = block_banded_spd(256, block=16, bands=1, n_rhs=3, seed=2)
    op = BlockBandedOp.from_dense(prob.A, block=16, bands=1)
    mesh = make_host_mesh(4)
    x0 = jnp.zeros_like(prob.x_star)
    kw = dict(key=jax.random.key(5), mesh=mesh, rounds=5, local_steps=4,
              beta=0.8)
    for action, syncs in (("gs", ("allgather", "halo")), ("rk", ("psum",))):
        for sync in syncs:
            r0 = solve_distributed(op, prob.b, x0, prob.x_star,
                                   action=action, sync=sync, **kw)
            r1 = solve_distributed(op, prob.b, x0, prob.x_star,
                                   action=action, sync=sync, fused=True,
                                   **kw)
            assert jnp.array_equal(r0.x, r1.x), (action, sync)
            assert jnp.array_equal(r0.resid, r1.resid), (action, sync)
            assert jnp.array_equal(r0.err_sq, r1.err_sq), (action, sync)

    # sparse distributed local phases are fused too (PR 6): GS bitwise
    # under both syncs, local-sampling RK to roundoff
    cop = CsrOp.from_dense(prob.A)
    for sync in ("allgather", "a2a"):
        r0 = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                               sync=sync, **kw)
        r1 = solve_distributed(cop, prob.b, x0, prob.x_star, action="gs",
                               sync=sync, fused=True, **kw)
        assert jnp.array_equal(r0.x, r1.x), sync
        assert jnp.array_equal(r0.err_sq, r1.err_sq), sync
        assert jnp.array_equal(r0.resid, r1.resid), sync
    r0 = solve_distributed(cop, prob.b, x0, prob.x_star, action="rk",
                           sync="psum", **kw)
    r1 = solve_distributed(cop, prob.b, x0, prob.x_star, action="rk",
                           sync="psum", fused=True, **kw)
    denom = float(jnp.linalg.norm(r0.x)) or 1.0
    assert float(jnp.linalg.norm(r0.x - r1.x)) / denom <= 1e-5

    # strategies without a fused phase (dense) fall back with a warning
    import warnings
    from repro.core.operators import DenseOp
    dop = DenseOp(prob.A)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r2 = solve_distributed(dop, prob.b, x0, prob.x_star, action="gs",
                               sync="allgather", fused=True, **kw)
    assert any("no fused sweep kernel" in str(x.message) for x in w)
    r3 = solve_distributed(dop, prob.b, x0, prob.x_star, action="gs",
                           sync="allgather", **kw)
    assert jnp.array_equal(r2.x, r3.x)
    print("FUSED_DIST_OK")
""")


@pytest.mark.slow
def test_distributed_fused_matches_scan():
    run_forced_device_script(DIST_SCRIPT, marker="FUSED_DIST_OK")
