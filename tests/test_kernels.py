"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + allclose, per the kernels/ contract — plus ragged/degenerate-shape
fuzzing of the sparse matvec kernels (ISSUE 4 satellite): empty panels,
all-zero rows, single-row panels, ragged last panels, and the bitwise
identity of the scalar-prefetch empty-panel-skipping spmv_csr variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CsrOp, block_banded_spd, random_sparse_spd
from repro.core.spd import ell_from_dense
from repro.kernels import ops, ref
from repro.kernels.bbmv import dense_to_bands

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare jax+pytest environment: deterministic cases only
    HAVE_HYPOTHESIS = False


@pytest.mark.parametrize("n,block,k", [(256, 128, 8), (512, 128, 64), (512, 256, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gs_sweep(n, block, k, dtype):
    prob = block_banded_spd(n, block=block, bands=1, n_rhs=k, seed=0)
    A = prob.A.astype(dtype)
    b = prob.b.astype(dtype)
    x0 = jnp.zeros_like(b)
    blocks = jax.random.randint(jax.random.key(1), (12,), 0, n // block)
    out = ops.block_gs_sweep(A, b, x0, blocks, block=block, beta=0.9)
    want = ref.block_gs_sweep_ref(A, b, x0, blocks, block=block, beta=0.9)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,block,bands,k", [(256, 128, 1, 4), (512, 128, 2, 8),
                                             (768, 256, 1, 16)])
def test_bbmv(n, block, bands, k):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=1)
    Ab = dense_to_bands(prob.A, bands=bands, block=block)
    out = ops.bbmv(Ab, prob.x_star, bands=bands, block=block)
    want = ref.bbmv_ref(prob.A, prob.x_star)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,width,k", [(256, 32, 4), (384, 48, 8)])
def test_spmv_ell(n, width, k):
    prob = random_sparse_spd(n, row_nnz=width // 4, n_rhs=k, seed=2)
    vals, cols = ell_from_dense(prob.A, width)
    out = ops.spmv_ell(vals, cols, prob.x_star, tile=128)
    want = ref.spmv_ell_ref(vals, cols, prob.x_star)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # with enough ELL width the kernel equals the dense matvec too
    np.testing.assert_allclose(np.asarray(out), np.asarray(prob.A @ prob.x_star),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Sparse matvec kernels on ragged/degenerate shapes (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def _random_sparse(m, n, row_nnz, seed, *, zero_row_stride=0,
                   zero_panel=None, rows_per_panel=8):
    """Dense (m, n) matrix with ~row_nnz nonzeros/row, optionally zeroing
    every ``zero_row_stride``-th row and a whole panel of rows."""
    rng = np.random.default_rng(seed)
    A = np.zeros((m, n), np.float32)
    for i in range(m):
        cols = rng.choice(n, size=min(row_nnz, n), replace=False)
        A[i, cols] = rng.standard_normal(cols.size).astype(np.float32)
    if zero_row_stride:
        A[::zero_row_stride] = 0.0
    if zero_panel is not None:
        lo = zero_panel * rows_per_panel
        A[lo:lo + rows_per_panel] = 0.0
    return A


def _check_csr_kernels(A, *, rows_per_panel, k=3, seed=9):
    """Both spmv_csr variants vs the segment-sum reference vs dense, and
    the skip variant bitwise-equal to the base kernel."""
    m, n = A.shape
    op = CsrOp.from_dense(jnp.asarray(A), rows_per_panel=rows_per_panel)
    x = jax.random.normal(jax.random.key(seed), (n, k))
    want = A @ np.asarray(x)
    y_base = op.matvec(x, interpret=True)
    y_skip = op.matvec(x, interpret=True, skip_empty=True)
    y_ref = op.matvec_ref(x)
    np.testing.assert_allclose(np.asarray(y_base), want, atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_ref), want, atol=1e-4,
                               rtol=1e-4)
    assert bool(jnp.array_equal(y_base, y_skip)), \
        float(jnp.abs(y_base - y_skip).max())


@pytest.mark.parametrize("case", [
    # empty panels: a zeroed 8-row panel plus every 3rd row zero
    dict(m=64, n=64, row_nnz=6, zero_row_stride=3, zero_panel=2,
         rows_per_panel=8),
    # single-row panels (rows_per_panel=1): every panel is one row,
    # zero rows become entirely empty panels
    dict(m=40, n=24, row_nnz=4, zero_row_stride=5, rows_per_panel=1),
    # ragged last panel: m not a multiple of rows_per_panel
    dict(m=53, n=32, row_nnz=5, rows_per_panel=8),
    # rectangular wide + a zero panel
    dict(m=32, n=96, row_nnz=7, zero_panel=0, rows_per_panel=8),
    # everything empty: the all-zero matrix
    dict(m=24, n=16, row_nnz=0, rows_per_panel=8),
])
def test_spmv_csr_degenerate_shapes(case):
    rows_per_panel = case.pop("rows_per_panel")
    A = _random_sparse(**case, seed=11, rows_per_panel=rows_per_panel)
    _check_csr_kernels(A, rows_per_panel=rows_per_panel)


def test_spmv_ell_degenerate_shapes():
    # all-zero rows pad to duplicate column 0 entries with zero values —
    # the kernel must not double-count them
    prob = random_sparse_spd(256, row_nnz=6, n_rhs=2, seed=4)
    A = np.array(prob.A)
    A[::4] = 0.0
    vals, cols = ell_from_dense(jnp.asarray(A), 32)
    x = jax.random.normal(jax.random.key(5), (256, 2))
    out = ops.spmv_ell(vals, cols, x, tile=128)
    np.testing.assert_allclose(np.asarray(out), A @ np.asarray(x),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.spmv_ell_ref(vals, cols, x)),
                               atol=1e-5)
    # width-1 windows (a diagonal-ish matrix), non-tile-aligned n falls
    # back to the reference path inside ops.spmv_ell — still exact
    D = np.zeros((72, 72), np.float32)
    D[np.arange(72), (np.arange(72) * 7) % 72] = \
        np.random.default_rng(0).standard_normal(72).astype(np.float32)
    dv, dc = ell_from_dense(jnp.asarray(D), 1)
    xd = jax.random.normal(jax.random.key(6), (72, 3))
    np.testing.assert_allclose(np.asarray(ops.spmv_ell(dv, dc, xd)),
                               D @ np.asarray(xd), atol=1e-4, rtol=1e-4)


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=12)
    @given(m=st.integers(1, 80), n=st.sampled_from([8, 16, 32, 64]),
           row_nnz=st.integers(0, 8), rows_per_panel=st.sampled_from([1, 4, 8]),
           zero_row_stride=st.sampled_from([0, 2, 3]),
           seed=st.integers(0, 2 ** 16))
    def test_spmv_csr_fuzz(m, n, row_nnz, rows_per_panel, zero_row_stride,
                           seed):
        A = _random_sparse(m, n, row_nnz, seed % 997,
                           zero_row_stride=zero_row_stride,
                           rows_per_panel=rows_per_panel)
        _check_csr_kernels(A, rows_per_panel=rows_per_panel, k=2)

    @settings(deadline=None, max_examples=10)
    @given(n=st.sampled_from([128, 256, 384]), row_nnz=st.integers(1, 10),
           width_pad=st.integers(0, 8), seed=st.integers(0, 2 ** 16))
    def test_spmv_ell_fuzz(n, row_nnz, width_pad, seed):
        prob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=2,
                                 seed=seed % 997)
        An = np.asarray(prob.A)
        width = int((An != 0).sum(axis=1).max()) + width_pad
        vals, cols = ell_from_dense(prob.A, width)
        x = jax.random.normal(jax.random.key(seed % 101), (n, 2))
        out = ops.spmv_ell(vals, cols, x, tile=128)
        np.testing.assert_allclose(np.asarray(out), An @ np.asarray(x),
                                   atol=1e-3, rtol=1e-3)


def test_block_gs_kernel_solves():
    """End-to-end: repeated kernel sweeps actually solve the system."""
    prob = block_banded_spd(512, block=128, bands=1, n_rhs=8, seed=5)
    x = jnp.zeros_like(prob.b)
    nb = 512 // 128
    for sweep in range(40):
        blocks = jax.random.permutation(jax.random.key(sweep), nb)
        x = ops.block_gs_sweep(prob.A, prob.b, x, blocks, block=128, beta=1.0)
    resid = float(jnp.linalg.norm(prob.b - prob.A @ x) / jnp.linalg.norm(prob.b))
    assert resid < 1e-3, resid
