"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + allclose, per the kernels/ contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_banded_spd, random_sparse_spd
from repro.core.spd import ell_from_dense
from repro.kernels import ops, ref
from repro.kernels.bbmv import dense_to_bands


@pytest.mark.parametrize("n,block,k", [(256, 128, 8), (512, 128, 64), (512, 256, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gs_sweep(n, block, k, dtype):
    prob = block_banded_spd(n, block=block, bands=1, n_rhs=k, seed=0)
    A = prob.A.astype(dtype)
    b = prob.b.astype(dtype)
    x0 = jnp.zeros_like(b)
    blocks = jax.random.randint(jax.random.key(1), (12,), 0, n // block)
    out = ops.block_gs_sweep(A, b, x0, blocks, block=block, beta=0.9)
    want = ref.block_gs_sweep_ref(A, b, x0, blocks, block=block, beta=0.9)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,block,bands,k", [(256, 128, 1, 4), (512, 128, 2, 8),
                                             (768, 256, 1, 16)])
def test_bbmv(n, block, bands, k):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=1)
    Ab = dense_to_bands(prob.A, bands=bands, block=block)
    out = ops.bbmv(Ab, prob.x_star, bands=bands, block=block)
    want = ref.bbmv_ref(prob.A, prob.x_star)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,width,k", [(256, 32, 4), (384, 48, 8)])
def test_spmv_ell(n, width, k):
    prob = random_sparse_spd(n, row_nnz=width // 4, n_rhs=k, seed=2)
    vals, cols = ell_from_dense(prob.A, width)
    out = ops.spmv_ell(vals, cols, prob.x_star, tile=128)
    want = ref.spmv_ell_ref(vals, cols, prob.x_star)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # with enough ELL width the kernel equals the dense matvec too
    np.testing.assert_allclose(np.asarray(out), np.asarray(prob.A @ prob.x_star),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,H,KV,D,S,chunk", [
    (2, 8, 2, 64, 1024, 256),
    (1, 4, 1, 128, 512, 128),
    (3, 12, 4, 64, 512, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, KV, D, S, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = ops.decode_attention(q, kc, vc, lengths, chunk=chunk)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_decode_attention_masked_tail():
    """Everything past ``lengths`` must be ignored: poisoning the invalid
    tail of the cache cannot change the output."""
    B, H, KV, D, S = 2, 4, 2, 64, 512
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jnp.array([100, 317])
    base = ops.decode_attention(q, kc, vc, lengths, chunk=128)
    mask = jnp.arange(S)[None, :, None, None] >= lengths[:, None, None, None]
    kc2 = jnp.where(mask, 1e6, kc)
    vc2 = jnp.where(mask, -1e6, vc)
    poisoned = ops.decode_attention(q, kc2, vc2, lengths, chunk=128)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               atol=1e-5)


def test_block_gs_kernel_solves():
    """End-to-end: repeated kernel sweeps actually solve the system."""
    prob = block_banded_spd(512, block=128, bands=1, n_rhs=8, seed=5)
    x = jnp.zeros_like(prob.b)
    nb = 512 // 128
    for sweep in range(40):
        blocks = jax.random.permutation(jax.random.key(sweep), nb)
        x = ops.block_gs_sweep(prob.A, prob.b, x, blocks, block=128, beta=1.0)
    resid = float(jnp.linalg.norm(prob.b - prob.A @ x) / jnp.linalg.norm(prob.b))
    assert resid < 1e-3, resid
