"""Dry-run machinery smoke (deliverable e, in miniature): one small cell
lowers + compiles on the production 16x16 and 2x16x16 meshes inside a
subprocess with 512 placeholder devices, and the roofline record is sane."""
import json
import sys

import pytest

from conftest import run_in_subprocess

from repro import roofline as RL


@pytest.mark.slow
def test_dryrun_one_cell_both_meshes(tmp_path):
    out = run_in_subprocess(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "decode_32k", "--mesh", "both", "--no-unroll",
         "--out", str(tmp_path)],
        timeout=900)
    assert out.returncode == 0, out.stderr[-2000:] + out.stdout[-500:]
    for mesh in ("16x16", "2x16x16"):
        rec = json.load(open(tmp_path / f"qwen2-1.5b__decode_32k__{mesh}.json"))
        assert rec["ok"], rec
        assert rec["flops_per_device"] > 0
        assert rec["bytes_per_device"] > 0
        assert rec["bottleneck"] in ("compute", "memory", "collective")
        assert rec["memory"].get("argument_size_in_bytes", 0) > 0


# -- HLO collective parser (pure-unit, no compilation) ------------------------

def test_shape_bytes():
    assert RL.shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert RL.shape_bytes("bf16[8]") == 16
    assert RL.shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert RL.shape_bytes("pred[]") == 0 or RL.shape_bytes("pred[]") == 1


def test_collective_parse_and_wire_model():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(f32[4,128]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), replica_groups=[16,16]<=[256] to_apply=%add
  %cp = f32[32]{0} collective-permute(f32[32]{0} %z), source_target_pairs={{0,1}}
"""
    stats = RL.collective_bytes(hlo)
    ag = 64 * 128 * 4 * 15 / 16
    ar = 2 * 1024 * 2 * 15 / 16
    cp = 32 * 4
    assert stats.by_kind["all-gather"][1] == pytest.approx(ag)
    assert stats.by_kind["all-reduce"][1] == pytest.approx(ar)
    assert stats.by_kind["collective-permute"][1] == pytest.approx(cp)
    assert stats.wire_bytes == pytest.approx(ag + ar + cp)


def test_analyze_bottleneck():
    r = RL.analyze({"flops": 1e12, "bytes accessed": 1e9}, "", chips=256,
                   model_flops=6e14)
    assert r.t_comp > r.t_mem >= r.t_coll
    assert r.bottleneck == "compute"
    assert 0 < r.useful_ratio
