"""Distributed engine: bit-exact equivalence of the parallel legacy entry
points vs the frozen pre-refactor implementations, the halo-vs-allgather
iterate identity *through the unified driver*, and the new block-banded
Kaczmarz strategy end-to-end — all on a forced 4-device host mesh in a
subprocess (the main test process keeps its single real device)."""
import pytest

from conftest import run_forced_device_script

EQUIV_SCRIPT = """
    import sys
    sys.path.insert(0, "tests")
    import jax, jax.numpy as jnp, numpy as np
    import legacy_solvers as legacy
    from repro.core import (block_banded_spd, parallel_rgs_banded,
                            parallel_rgs_halo, parallel_rgs_solve,
                            parallel_rk_solve, random_lsq, random_sparse_spd)
    from repro.kernels.bbmv import dense_to_bands
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)

    def same(a, b):
        assert bool(jnp.array_equal(a, b)), float(jnp.abs(a - b).max())

    # --- dense GS, coordinate and block granularity -----------------------
    prob = random_sparse_spd(256, row_nnz=8, n_rhs=2, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    for block, ls, beta in ((1, 16, 0.8), (4, 4, 0.9)):
        kw = dict(key=jax.random.key(block), mesh=mesh, rounds=6,
                  local_steps=ls, block=block, beta=beta)
        n = parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star, **kw)
        o = legacy.parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star, **kw)
        same(n.x, o.x); same(n.err_sq, o.err_sq); same(n.resid, o.resid)
        assert int(n.tau) == int(o.tau)

    # --- banded GS (all-gather) and halo variant --------------------------
    bb = block_banded_spd(512, block=16, bands=1, n_rhs=3, seed=2)
    Ab = dense_to_bands(bb.A, bands=1, block=16)
    xb0 = jnp.zeros_like(bb.x_star)
    kw = dict(key=jax.random.key(5), mesh=mesh, rounds=7, local_steps=5,
              block=16, bands=1, beta=0.7)
    nb = parallel_rgs_banded(Ab, bb.b, xb0, bb.x_star, **kw)
    ob = legacy.parallel_rgs_banded(Ab, bb.b, xb0, bb.x_star, **kw)
    same(nb.x, ob.x); same(nb.err_sq, ob.err_sq); same(nb.resid, ob.resid)

    nh = parallel_rgs_halo(Ab, bb.b, xb0, **kw)
    oh = legacy.parallel_rgs_halo(Ab, bb.b, xb0, **kw)
    same(nh.x, oh.x); same(nh.resid, oh.resid)
    # the satellite fix: err_sq no longer silently carries the squared
    # residual (legacy bug) — it is NaN when no x_star is supplied
    assert bool(jnp.isnan(nh.err_sq).all())

    # metrics-off invariance through the engine (legacy contract)
    nb2 = parallel_rgs_banded(Ab, bb.b, xb0, bb.x_star, with_metrics=False,
                              **kw)
    nh2 = parallel_rgs_halo(Ab, bb.b, xb0, with_metrics=False, **kw)
    same(nb2.x, nb.x); same(nh2.x, nh.x)
    assert float(jnp.abs(nb2.err_sq).max()) == 0.0
    assert float(jnp.abs(nh2.resid).max()) == 0.0

    # --- dense RK ---------------------------------------------------------
    lp = random_lsq(256, 32, n_rhs=2, noise=0.0, seed=0)
    w0 = jnp.zeros_like(lp.x_star)
    kw = dict(key=jax.random.key(0), mesh=mesh, rounds=10, local_steps=8,
              beta=0.9)
    nk = parallel_rk_solve(lp.A, lp.b, w0, lp.x_star, **kw)
    ok = legacy.parallel_rk_solve(lp.A, lp.b, w0, lp.x_star, **kw)
    same(nk.x, ok.x); same(nk.err_sq, ok.err_sq); same(nk.resid, ok.resid)
    print("LEGACY_EQUIV_OK")
"""


DRIVER_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import BlockBandedOp, block_banded_spd
    from repro.core.engine import solve_distributed
    from repro.kernels.bbmv import dense_to_bands
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4)
    bb = block_banded_spd(512, block=16, bands=1, n_rhs=3, seed=2)
    op = BlockBandedOp(dense_to_bands(bb.A, bands=1, block=16), bands=1)
    x0 = jnp.zeros_like(bb.x_star)
    kw = dict(action="gs", key=jax.random.key(5), mesh=mesh, rounds=7,
              local_steps=5, beta=0.7)

    # halo-vs-allgather iterate identity through the unified driver, with
    # x_star supplied so both report the A-norm error
    rh = solve_distributed(op, bb.b, x0, bb.x_star, sync="halo", **kw)
    rg = solve_distributed(op, bb.b, x0, bb.x_star, sync="allgather", **kw)
    assert float(jnp.abs(rh.x - rg.x).max()) == 0.0
    # window-local A-norm error agrees with the all-gather metric path
    assert np.allclose(np.asarray(rh.err_sq), np.asarray(rg.err_sq),
                       rtol=1e-3, atol=1e-5), (rh.err_sq, rg.err_sq)
    # sync="auto" picks halo for a finite-halo operator
    ra = solve_distributed(op, bb.b, x0, bb.x_star, **kw)
    assert float(jnp.abs(ra.x - rh.x).max()) == 0.0

    # --- block-banded Kaczmarz: the new action x format point -------------
    rk = solve_distributed(op, bb.b, x0, bb.x_star, action="rk",
                           key=jax.random.key(0), mesh=mesh, rounds=30,
                           local_steps=16, beta=0.9)
    assert int(rk.tau) == 15
    r = np.asarray(rk.resid)[:, 0]
    assert r[-1] < 1e-2 * r[0], r
    rel = float(jnp.linalg.norm(bb.b - bb.A @ rk.x) / jnp.linalg.norm(bb.b))
    assert rel < 1e-2, rel
    e = np.asarray(rk.err_sq)
    assert e[-1].max() < 1e-2 * e[0].max(), e[:, 0]
    print("DRIVER_OK")
"""


@pytest.mark.slow
def test_parallel_legacy_bit_identity():
    run_forced_device_script(EQUIV_SCRIPT, marker="LEGACY_EQUIV_OK")


@pytest.mark.slow
def test_unified_driver_halo_allgather_and_banded_rk():
    run_forced_device_script(DRIVER_SCRIPT, marker="DRIVER_OK")
