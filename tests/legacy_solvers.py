"""Frozen pre-refactor solver implementations (PR 2 reference copies).

These are the exact solver bodies that shipped before the unified
operator/engine refactor (commit c42105b), kept verbatim — imports merged,
module docstrings dropped, nothing else touched — so that
tests/test_engine_equivalence.py can assert the refactored entry points
produce BIT-IDENTICAL iterates given the same PRNG keys.  Do not edit the
arithmetic here: this file is the contract.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.core import spd


class SolveResult(NamedTuple):
    x: jax.Array
    err_sq: jax.Array
    resid: jax.Array
    iters: jax.Array


class ParallelSolveResult(NamedTuple):
    x: jax.Array
    err_sq: jax.Array
    resid: jax.Array
    tau: int


def _record(A, b, x, x_star):
    e = x - x_star
    return spd.a_norm_sq(A, e), jnp.linalg.norm(b - A @ x, axis=0)


# ---------------------------------------------------------------------------
# core/rgs.py (pre-refactor)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_iters", "record_every"))
def rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    n = A.shape[0]
    rec = record_every or num_iters
    assert num_iters % rec == 0
    coords = jax.random.randint(key, (num_iters,), 0, n)

    def step(x, r):
        gamma = b[r] - A[r] @ x          # (k,)
        return x.at[r].add(beta * gamma), None

    def chunk(x, cs):
        x, _ = jax.lax.scan(step, x, cs)
        return x, _record(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(chunk, x0, coords.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


@functools.partial(jax.jit, static_argnames=("num_sweeps", "block"))
def block_gs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_sweeps: int,
    block: int,
    beta: float = 1.0,
) -> SolveResult:
    n = A.shape[0]
    nb = n // block
    steps = num_sweeps * nb
    blocks = jax.random.randint(key, (steps,), 0, nb)

    def step(x, bi):
        rows = bi * block + jnp.arange(block)
        Ab = A[rows]                      # (block, n)
        gamma = b[rows] - Ab @ x          # (block, k)
        return x.at[rows].add(beta * gamma), None

    def sweep(x, bs):
        x, _ = jax.lax.scan(step, x, bs)
        return x, _record(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(sweep, x0, blocks.reshape(num_sweeps, nb))
    return SolveResult(x=x, err_sq=errs, resid=resids,
                       iters=(1 + jnp.arange(num_sweeps)) * nb)


# ---------------------------------------------------------------------------
# core/parallel_rgs.py (pre-refactor)
# ---------------------------------------------------------------------------

def effective_tau(num_workers: int, local_steps: int) -> int:
    return (num_workers - 1) * local_steps


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "beta",
                     "unroll"),
)
def parallel_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 1,
    beta: float = 1.0,
    unroll: bool = False,
) -> ParallelSolveResult:
    num_workers = mesh.shape[axis]
    n = A.shape[0]
    slab = n // num_workers
    assert slab * num_workers == n and slab % block == 0
    round_keys = jax.random.split(key, rounds)

    def worker(A_sh, b_sh, xs_sh, x0_full, keys):
        w = jax.lax.axis_index(axis)
        col0 = w * slab

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, slab // block)
            delta = pvary(
                jnp.zeros((slab, b_sh.shape[1]), x.dtype), (axis,)
            )

            def step(delta, bi):
                rows = bi * block + jnp.arange(block)
                Ar = A_sh[rows]                          # (block, n)
                stale = Ar @ x                           # stale replica read
                own = jax.lax.dynamic_slice(Ar, (0, col0), (block, slab))
                g = b_sh[rows] - stale - own @ delta
                return delta.at[rows].add(beta * g), None

            delta, _ = jax.lax.scan(step, delta, picks,
                                    unroll=local_steps if unroll else 1)
            x = x + jax.lax.all_gather(delta, axis, axis=0, tiled=True)
            e_local = jax.lax.dynamic_slice_in_dim(x, col0, slab, 0) - xs_sh
            err = jax.lax.psum(
                jnp.einsum("sk,sk->k", e_local, A_sh @ (x - _xstar_full(x))), axis
            )
            r_local = b_sh - A_sh @ x
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return x, (err, jnp.sqrt(rsq))

        def _xstar_full(x):
            return jax.lax.all_gather(xs_sh, axis, axis=0, tiled=True)

        x, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), keys,
            unroll=rounds if unroll else 1,
        )
        x_slab = jax.lax.dynamic_slice_in_dim(x, col0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(None, None), P(None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A, b, x_star, x0, round_keys)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids, tau=effective_tau(num_workers, local_steps)
    )


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "bands",
                     "beta", "unroll", "with_metrics"),
)
def parallel_rgs_banded(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star_or_none,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,
) -> ParallelSolveResult:
    num_workers = mesh.shape[axis]
    n, k = b.shape
    nb = n // block
    slab = n // num_workers
    nb_local = slab // block
    assert nb * block == n and nb_local * block == slab
    width = A_bands.shape[1]
    assert width == 2 * bands + 1
    round_keys = jax.random.split(key, rounds)

    def worker(Ab_sh, b_sh, keys, x0_full, xs_full):
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def banded_apply(xw, bi_local):
            gb = w * nb_local + bi_local            # global block-row index
            acc = jax.lax.dynamic_slice_in_dim(
                b_sh, bi_local * block, block, 0).astype(jnp.float32)
            tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi_local, 1, 0)[0]
            for d in range(width):
                cb = gb + d - bands                  # global column block
                cbc = jnp.clip(cb, 0, nb - 1)
                xs = jax.lax.dynamic_slice_in_dim(xw, cbc * block, block, 0)
                contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
                valid = (cb >= 0) & (cb < nb)
                acc = acc - jnp.where(valid, contrib, 0.0)
            return acc.astype(xw.dtype)

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)
            xw = x

            def step(xw, bi):
                g = banded_apply(xw, bi)
                rows0 = row0 + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, rows0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, rows0, 0), None

            xw, _ = jax.lax.scan(step, xw, picks,
                                 unroll=local_steps if unroll else 1)
            own = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
            x = jax.lax.all_gather(own, axis, axis=0, tiled=True)
            if not with_metrics:
                z = jnp.zeros((b_sh.shape[1],), jnp.float32)
                return x, (z, z)
            r_local = b_sh - _banded_matvec(Ab_sh, x, w, nb, nb_local, block,
                                            bands)
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            if xs_full is not None:
                e_own = own - jax.lax.dynamic_slice_in_dim(xs_full, row0, slab, 0)
                esq = jax.lax.psum(
                    jnp.einsum("sk,sk->k", e_own,
                               -r_local + (b_sh - _banded_matvec(
                                   Ab_sh, xs_full, w, nb, nb_local, block, bands))),
                    axis)
            else:
                esq = rsq
            return x, (esq, jnp.sqrt(rsq))

        x, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), keys,
            unroll=rounds if unroll else 1)
        x_slab = jax.lax.dynamic_slice_in_dim(x, row0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(None),
                  P(None, None), P(None, None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A_bands, b, round_keys, x0, x_star_or_none)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=effective_tau(num_workers, local_steps))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "bands",
                     "beta", "unroll", "with_metrics"),
)
def parallel_rgs_halo(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,
) -> ParallelSolveResult:
    num_workers = mesh.shape[axis]
    n, k = b.shape
    nb = n // block
    slab = n // num_workers
    nb_local = slab // block
    halo = bands * block
    assert halo <= slab, "halo exchange needs bands*block <= slab"
    width = 2 * bands + 1
    round_keys = jax.random.split(key, rounds)
    down = [(i, i + 1) for i in range(num_workers - 1)]
    up = [(i + 1, i) for i in range(num_workers - 1)]

    def worker(Ab_sh, b_sh, x0_sh, keys):
        w = jax.lax.axis_index(axis)

        def exchange(xw):
            own = jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0)
            lo_edge = own[:halo]
            hi_edge = own[-halo:]
            from_prev = jax.lax.ppermute(hi_edge, axis, down)
            from_next = jax.lax.ppermute(lo_edge, axis, up)
            xw = jax.lax.dynamic_update_slice_in_dim(xw, from_prev, 0, 0)
            return jax.lax.dynamic_update_slice_in_dim(
                xw, from_next, halo + slab, 0)

        def banded_apply(xw, bi):
            gb = w * nb_local + bi
            acc = jax.lax.dynamic_slice_in_dim(
                b_sh, bi * block, block, 0).astype(jnp.float32)
            tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi, 1, 0)[0]
            for d in range(width):
                cb = gb + d - bands
                xs = jax.lax.dynamic_slice_in_dim(
                    xw, jnp.clip((bi + d) * block, 0, slab + 2 * halo - block),
                    block, 0)
                contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
                acc = acc - jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
            return acc.astype(xw.dtype)

        def round_body(xw, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)

            def step(xw, bi):
                g = banded_apply(xw, bi)
                r0 = halo + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, r0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, r0, 0), None

            xw, _ = jax.lax.scan(step, xw, picks,
                                 unroll=local_steps if unroll else 1)
            xw = exchange(xw)
            if not with_metrics:
                z = jnp.zeros((k,), jnp.float32)
                return xw, (z, z)
            resid2 = jnp.zeros((k,), jnp.float32)
            for bi in range(nb_local):
                r = banded_apply(xw, bi).astype(jnp.float32)
                resid2 = resid2 + jnp.einsum("bk,bk->k", r, r)
            rsq = jax.lax.psum(resid2, axis)
            return xw, (rsq, jnp.sqrt(rsq))

        xw0 = jnp.pad(x0_sh, ((halo, halo), (0, 0)))
        xw0 = exchange(xw0)
        xw, (errs, resids) = jax.lax.scan(round_body, xw0, keys,
                                          unroll=rounds if unroll else 1)
        return jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0), errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(axis, None),
                  P(None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A_bands, b, x0, round_keys)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=effective_tau(num_workers, local_steps))


def _banded_matvec(Ab_sh, x, w, nb, nb_local, block, bands):
    width = 2 * bands + 1

    def one(bi):
        gb = w * nb_local + bi
        acc = jnp.zeros((block, x.shape[1]), jnp.float32)
        for d in range(width):
            cb = gb + d - bands
            cbc = jnp.clip(cb, 0, nb - 1)
            xs = jax.lax.dynamic_slice_in_dim(x, cbc * block, block, 0)
            contrib = jnp.dot(Ab_sh[bi, d], xs, preferred_element_type=jnp.float32)
            acc = acc + jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
        return acc.astype(x.dtype)

    out = jax.vmap(one)(jnp.arange(nb_local))
    return out.reshape(nb_local * block, x.shape[1])


# ---------------------------------------------------------------------------
# core/kaczmarz.py (pre-refactor)
# ---------------------------------------------------------------------------

def row_norms_sq(A: jax.Array) -> jax.Array:
    return jnp.einsum("mn,mn->m", A, A)


def sample_rows(key: jax.Array, A: jax.Array, num: int) -> jax.Array:
    return jax.random.categorical(key, jnp.log(row_norms_sq(A)), shape=(num,))


def _record_lsq(A, b, x, x_star):
    e = x - x_star
    return jnp.einsum("nk,nk->k", e, e), jnp.linalg.norm(b - A @ x, axis=0)


@functools.partial(jax.jit, static_argnames=("num_iters", "record_every"))
def rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    rn = row_norms_sq(A)
    rec = record_every or num_iters
    assert num_iters % rec == 0
    rows = sample_rows(key, A, num_iters)

    def step(x, r):
        g = (b[r] - A[r] @ x) / rn[r]               # (k,)
        return x + beta * A[r][:, None] * g[None, :], None

    def chunk(x, rs):
        x, _ = jax.lax.scan(step, x, rs)
        return x, _record_lsq(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(chunk, x0, rows.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "tau", "record_every", "read_model", "delay_mode"),
)
def async_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    k = b.shape[1]
    rn = row_norms_sq(A)
    rec = record_every or num_iters
    assert num_iters % rec == 0
    rows = sample_rows(key, A, num_iters)
    t_buf = max(tau, 1)

    if read_model == "consistent":
        if delay_mode == "fixed":
            delays = jnp.full((num_iters,), tau, jnp.int32)
        elif delay_mode == "uniform":
            delays = jax.random.randint(delay_key, (num_iters,), 0, tau + 1)
        elif delay_mode == "cyclic":
            delays = (jnp.arange(num_iters) % (tau + 1)).astype(jnp.int32)
        else:
            raise ValueError(delay_mode)
        aux = delays
    elif read_model == "inconsistent":
        aux = jax.random.bernoulli(delay_key, miss_prob, (num_iters, t_buf))
    else:
        raise ValueError(read_model)

    ring_r0 = jnp.zeros((t_buf,), jnp.int32)
    ring_c0 = jnp.zeros((t_buf, k), x0.dtype)
    offsets = jnp.arange(t_buf)

    def step(carry, inp):
        x, ring_r, ring_c, j = carry
        r, a = inp
        it_idx = j - 1 - offsets
        valid = it_idx >= 0
        if read_model == "consistent":
            invisible = (offsets < a) & valid
        else:
            invisible = a & valid & (offsets < tau)
        slots = jnp.mod(it_idx, t_buf)
        rs = ring_r[slots]
        cs = ring_c[slots]
        w = jnp.where(invisible, A[rs] @ A[r], 0.0)
        corr = w @ cs
        gamma = (b[r] - A[r] @ x + corr) / rn[r]
        c = beta * gamma
        x = x + A[r][:, None] * c[None, :]
        ring_r = ring_r.at[jnp.mod(j, t_buf)].set(r)
        ring_c = ring_c.at[jnp.mod(j, t_buf)].set(c)
        return (x, ring_r, ring_c, j + 1), None

    def chunk(carry, inp):
        carry, _ = jax.lax.scan(step, carry, inp)
        errs = _record_lsq(A, b, carry[0], x_star)
        return carry, errs

    inps = (rows.reshape(-1, rec), aux.reshape((-1, rec) + aux.shape[1:]))
    carry = (x0, ring_r0, ring_c0, jnp.array(0, jnp.int32))
    carry, (errs, resids) = jax.lax.scan(chunk, carry, inps)
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=carry[0], err_sq=errs, resid=resids, iters=iters)


def rk_effective_tau(num_workers: int, local_steps: int) -> int:
    return 0 if num_workers == 1 else local_steps - 1


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "beta", "unroll"),
)
def parallel_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    beta: float = 1.0,
    unroll: bool = False,
) -> ParallelSolveResult:
    num_workers = mesh.shape[axis]
    m = A.shape[0]
    slab = m // num_workers
    assert slab * num_workers == m, (
        f"worker count ({num_workers}) must divide the row count ({m})")
    rn = row_norms_sq(A)
    picks = sample_rows(key, A, rounds * local_steps).reshape(rounds, local_steps)

    def worker(A_sh, b_sh, rn_sh, x0_full, xs_full, picks):
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def round_body(xw, picks_r):
            delta = pvary(jnp.zeros_like(xw), (axis,))

            def step(carry, p):
                xw, delta = carry
                li = p - row0
                mine = (li >= 0) & (li < slab)
                lic = jnp.clip(li, 0, slab - 1)
                Ar = A_sh[lic]                               # (n,)
                g = (b_sh[lic] - Ar @ xw) / rn_sh[lic]       # (k,)
                upd = jnp.where(mine, beta, 0.0) * Ar[:, None] * g[None, :]
                return (xw + upd, delta + upd), None

            (xw, delta), _ = jax.lax.scan(
                step, (xw, delta), picks_r,
                unroll=local_steps if unroll else 1)
            if num_workers > 1:
                xw = xw + (jax.lax.psum(delta, axis) - delta)
            err = jnp.einsum("nk,nk->k", xw - xs_full, xw - xs_full)
            r_local = b_sh - A_sh @ xw
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return xw, (err, jnp.sqrt(rsq))

        xw, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), picks,
            unroll=rounds if unroll else 1)
        return xw, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(None, None),
                  P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A, b, rn, x0, x_star, picks)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=rk_effective_tau(num_workers, local_steps))


# ---------------------------------------------------------------------------
# core/async_rgs.py (pre-refactor)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "tau", "record_every", "read_model", "delay_mode"),
)
def async_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    n = A.shape[0]
    k = b.shape[1]
    rec = record_every or num_iters
    assert num_iters % rec == 0

    coords = jax.random.randint(key, (num_iters,), 0, n)
    t_buf = max(tau, 1)

    if read_model == "consistent":
        if delay_mode == "fixed":
            delays = jnp.full((num_iters,), tau, jnp.int32)
        elif delay_mode == "uniform":
            delays = jax.random.randint(delay_key, (num_iters,), 0, tau + 1)
        elif delay_mode == "cyclic":
            delays = (jnp.arange(num_iters) % (tau + 1)).astype(jnp.int32)
        else:
            raise ValueError(delay_mode)
        aux = delays
    elif read_model == "inconsistent":
        aux = jax.random.bernoulli(delay_key, miss_prob, (num_iters, t_buf))
    else:
        raise ValueError(read_model)

    ring_r0 = jnp.zeros((t_buf,), jnp.int32)
    ring_g0 = jnp.zeros((t_buf, k), x0.dtype)

    offsets = jnp.arange(t_buf)

    def step(carry, inp):
        x, ring_r, ring_g, j = carry
        r, a = inp
        it_idx = j - 1 - offsets
        valid = it_idx >= 0
        if read_model == "consistent":
            invisible = (offsets < a) & valid
        else:
            invisible = a & valid & (offsets < tau)
        slots = jnp.mod(it_idx, t_buf)
        rs = ring_r[slots]
        gs = ring_g[slots]
        w = jnp.where(invisible, A[r, rs], 0.0)
        corr = w @ gs
        gamma = b[r] - A[r] @ x + corr
        applied = beta * gamma
        x = x.at[r].add(applied)
        ring_r = ring_r.at[jnp.mod(j, t_buf)].set(r)
        ring_g = ring_g.at[jnp.mod(j, t_buf)].set(applied)
        return (x, ring_r, ring_g, j + 1), None

    def chunk(carry, inp):
        carry, _ = jax.lax.scan(step, carry, inp)
        errs = _record(A, b, carry[0], x_star)
        return carry, errs

    inps = (coords.reshape(-1, rec), aux.reshape((-1, rec) + aux.shape[1:]))
    carry = (x0, ring_r0, ring_g0, jnp.array(0, jnp.int32))
    carry, (errs, resids) = jax.lax.scan(chunk, carry, inps)
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=carry[0], err_sq=errs, resid=resids, iters=iters)
