"""Per-arch smoke tests (deliverable f): every assigned architecture, as a
REDUCED config of the same family, runs forward + one train step on CPU with
correct output shapes and no NaNs; and the serving path (prefill -> decode)
exactly matches the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ShapeConfig, get_run_config, get_smoke_config
from repro.models import transformer as T
from repro.train import steps as ST

B, S = 2, 32


def _batch(cfg, with_labels=True, key=1):
    toks = jax.random.randint(jax.random.key(key), (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if with_labels:
        batch["labels"] = toks[:, 1:]
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_len, cfg.d_model))
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (B, cfg.frontend_len, cfg.d_model))
    return toks, batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params, specs = T.init_params(cfg, jax.random.key(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda s: not isinstance(s, (dict, tuple)))
    _, batch = _batch(cfg, with_labels=False)
    hidden, _, moe_loss = T.forward(params, cfg, batch, remat="none")
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())
    logits = T.unembed_logits(params, cfg, hidden)
    assert logits.shape[-1] >= cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    rcfg = get_run_config(arch).with_(total_steps=10, warmup_steps=2,
                                      loss_chunk=16, q_chunk=16)
    part = ST.make_partitioner(None, B)
    state, _ = ST.init_train_state(cfg, rcfg, part, jax.random.key(0))
    step_fn, _ = ST.make_train_step(cfg, rcfg, part)
    _, batch = _batch(cfg)
    state, metrics = jax.jit(step_fn)(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(arch):
    """The serving path is exact: prefill S tokens, decode token S+1, and
    compare against the full-sequence forward at position S+1."""
    cfg = get_smoke_config(arch)
    part = ST.make_partitioner(None, B)
    params, _ = T.init_params(cfg, jax.random.key(0))
    toks, batch = _batch(cfg, with_labels=False)

    full = dict(batch)
    full["tokens"] = toks
    hid_full, _, _ = T.forward(params, cfg, full, remat="none")
    ref = T.unembed_logits(params, cfg, hid_full[:, -1:])[:, 0]

    prefill = ST.make_prefill_step(cfg, part, capacity_len=S + 1)
    _, cache = prefill(params, batch)
    serve = ST.make_serve_step(cfg, part, ShapeConfig("t", S + 1, B, "decode"))
    logits, new_cache = serve(params, cache, toks[:, S:S + 1], jnp.int32(S))
    rel = float(jnp.max(jnp.abs(logits - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-3, rel
    # cache structure is stable under decode (jit-compatible loop)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_microbatch_accumulation_matches_full_batch():
    """Gradient accumulation is exact in fp32: 1 microbatch == 2."""
    cfg = get_smoke_config("qwen2-1.5b")
    part = ST.make_partitioner(None, B)
    rcfg = get_run_config("qwen2-1.5b").with_(total_steps=10, warmup_steps=0,
                                              loss_chunk=16, q_chunk=16)
    _, batch = _batch(cfg)
    state, _ = ST.init_train_state(cfg, rcfg, part, jax.random.key(0))
    s1, m1 = jax.jit(ST.make_train_step(cfg, rcfg, part)[0])(state, batch)
    rcfg2 = rcfg.with_(microbatches=2)
    s2, m2 = jax.jit(ST.make_train_step(cfg, rcfg2, part)[0])(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    a = jax.tree.leaves(s1.params)[0]
    b = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_long_context_ring_semantics():
    """Sliding-window ring: decoding far past the window keeps only the last
    ``window`` keys — outputs equal a fresh prefill of the suffix window."""
    cfg = get_smoke_config("gemma3-1b").with_(
        num_layers=2, layer_pattern=("local",), window=8)
    part = ST.make_partitioner(None, 1)
    params, _ = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 64), 0, cfg.vocab_size)
    prefill = ST.make_prefill_step(cfg, part, capacity_len=65)
    serve = ST.make_serve_step(cfg, part, ShapeConfig("t", 65, 1, "decode"))
    _, cache = prefill(params, {"tokens": toks[:, :63]})
    got, _ = serve(params, cache, toks[:, 63:64], jnp.int32(63))
    hid, _, _ = T.forward(params, cfg, {"tokens": toks}, remat="none")
    want = T.unembed_logits(params, cfg, hid[:, -1:])[:, 0]
    rel = float(jnp.max(jnp.abs(got - want))) / (float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < 2e-3, rel
