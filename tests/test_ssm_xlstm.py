"""Sequence-mixer math: Mamba chunked scan and xLSTM parallel/recurrent
equivalence — the invariants behind the long_500k cells."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import xlstm as X

CFG = get_smoke_config("jamba-v0.1-52b")
XCFG = get_smoke_config("xlstm-125m")


def _mamba_params(seed=0):
    ini = L.Initializer(jax.random.key(seed), jnp.float32)
    return S.init_mamba(ini, CFG)[0]


def test_mamba_chunk_invariance():
    """The chunked associative scan equals any other chunking exactly."""
    params = _mamba_params()
    x = jax.random.normal(jax.random.key(1), (2, 60, CFG.d_model))
    ys = [np.asarray(S.mamba_forward(params, x, CFG, chunk=c))
          for c in (4, 15, 60)]
    np.testing.assert_allclose(ys[0], ys[1], atol=1e-5)
    np.testing.assert_allclose(ys[0], ys[2], atol=1e-5)


def test_mamba_prefill_decode_handoff():
    params = _mamba_params()
    x = jax.random.normal(jax.random.key(2), (2, 33, CFG.d_model))
    y_full = S.mamba_forward(params, x, CFG)
    y_pre, state = S.mamba_forward(params, x[:, :32], CFG, return_state=True)
    np.testing.assert_allclose(np.asarray(y_full[:, :32]), np.asarray(y_pre),
                               atol=1e-5)
    y_dec, state2 = S.mamba_decode(params, x[:, 32:], state, CFG)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y_dec),
                               atol=1e-5)
    assert state2["h"].shape == state["h"].shape


def test_mamba_sequential_decode_chain():
    """Pure decode from t=0 reproduces the parallel forward."""
    params = _mamba_params()
    x = jax.random.normal(jax.random.key(3), (1, 12, CFG.d_model))
    y_full = S.mamba_forward(params, x, CFG)
    cache = S.init_mamba_cache(CFG, 1, jnp.float32)
    outs = []
    for t in range(12):
        y, cache = S.mamba_decode(params, x[:, t:t + 1], cache, CFG)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=1e-4)


def _mlstm_params(seed=0):
    ini = L.Initializer(jax.random.key(seed), jnp.float32)
    return X.init_mlstm(ini, XCFG)[0]


def test_mlstm_parallel_equals_recurrent():
    params = _mlstm_params()
    x = jax.random.normal(jax.random.key(4), (1, 16, XCFG.d_model))
    y_par = X.mlstm_forward(params, x, XCFG)
    cache = X.init_mlstm_cache(XCFG, 1, jnp.float32)
    outs = []
    for t in range(16):
        y, cache = X.mlstm_decode(params, x[:, t:t + 1], cache, XCFG)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_par), atol=1e-4)


def test_mlstm_qchunk_invariance():
    params = _mlstm_params()
    x = jax.random.normal(jax.random.key(5), (2, 32, XCFG.d_model))
    y1 = X.mlstm_forward(params, x, XCFG, q_chunk=8)
    y2 = X.mlstm_forward(params, x, XCFG, q_chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_mlstm_prefill_state_matches_recurrence():
    params = _mlstm_params()
    x = jax.random.normal(jax.random.key(6), (1, 10, XCFG.d_model))
    _, state = X.mlstm_forward(params, x, XCFG, return_state=True)
    cache = X.init_mlstm_cache(XCFG, 1, jnp.float32)
    for t in range(10):
        _, cache = X.mlstm_decode(params, x[:, t:t + 1], cache, XCFG)
    np.testing.assert_allclose(np.asarray(state["C"]), np.asarray(cache["C"]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["n"]), np.asarray(cache["n"]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["m"]), np.asarray(cache["m"]),
                               atol=1e-4)


def test_slstm_forward_decode_consistency():
    ini = L.Initializer(jax.random.key(7), jnp.float32)
    params = X.init_slstm(ini, XCFG)[0]
    x = jax.random.normal(jax.random.key(8), (2, 9, XCFG.d_model))
    y_seq, state = X.slstm_forward(params, x, XCFG, return_state=True)
    cache = X.init_slstm_cache(XCFG, 2, jnp.float32)
    outs = []
    for t in range(9):
        y, cache = X.slstm_decode(params, x[:, t:t + 1], cache, XCFG)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["h"]), np.asarray(cache["h"]),
                               atol=1e-5)


def test_state_is_finite_long_sequences():
    """Stabilized gates: no overflow over long spans (the 500k regime in
    miniature)."""
    params = _mlstm_params()
    x = 3.0 * jax.random.normal(jax.random.key(9), (1, 256, XCFG.d_model))
    y = X.mlstm_forward(params, x, XCFG)
    assert bool(jnp.isfinite(y).all())
