"""Optimizers, the bounded-staleness async update (the paper's trainer-level
technique) and the int8 gradient codec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.optim import (adafactor, adamw, async_state_specs,
                         clip_by_global_norm, compression, global_norm,
                         init_async_grads, push_pop, staleness_beta,
                         warmup_cosine)


def _quadratic():
    A = jnp.diag(jnp.array([1.0, 4.0, 9.0]))
    b = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return 0.5 * p @ A @ p - b @ p
    x_star = jnp.linalg.solve(A, b)
    return loss, x_star


@pytest.mark.parametrize("make", [lambda: adamw(weight_decay=0.0),
                                  lambda: adafactor(weight_decay=0.0,
                                                    momentum_dtype=jnp.float32)])
def test_optimizer_minimizes_quadratic(make):
    loss, x_star = _quadratic()
    opt = make()
    params = {"p": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(400):
        g = jax.grad(lambda pp: loss(pp["p"]))(params)
        params, state = opt.update(g, state, params, 0.05)
    np.testing.assert_allclose(np.asarray(params["p"]), np.asarray(x_star),
                               atol=0.05)


def test_adamw_state_structure_matches_params():
    opt = adamw()
    params = {"a": jnp.ones((4, 4)), "nested": ({"b": jnp.ones(3)},)}
    st_ = opt.init(params)
    assert jax.tree.structure(st_.m) == jax.tree.structure(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, st2 = opt.update(g, st_, params, 1e-2)
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    assert int(st2.count) == 1


def test_adafactor_factored_state_shapes():
    opt = adafactor()
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((32,))}
    s = opt.init(params)
    assert s.vr["w"].shape == (64,)
    assert s.vc["w"].shape == (32,)
    assert s.v["w"].shape == (0,)          # factored leaf: no full moment
    assert s.v["b"].shape == (32,)         # vector leaf: unfactored


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, n = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(n), np.sqrt(90.0), rtol=1e-5)


def test_warmup_cosine_schedule():
    s = warmup_cosine(1e-3, warmup=10, total=110)
    assert float(s(jnp.array(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.array(10))), 1e-3, rtol=1e-5)
    assert float(s(jnp.array(110))) <= 1.01 * 1e-4 + 1e-9 + 1e-4


# -- the paper's bounded-staleness update -----------------------------------

def test_staleness_beta_is_papers_formula():
    # beta~ = 1/(1 + 2 rho tau) with rho_hat = 0.5 -> 1/(1+tau)
    assert staleness_beta(0) == 1.0
    assert staleness_beta(3) == pytest.approx(1.0 / 4.0)
    assert staleness_beta(2, rho_hat=0.25) == pytest.approx(1.0 / 2.0)


def test_push_pop_delays_exactly_tau_steps():
    tau = 3
    params = {"w": jnp.zeros(2)}
    state = init_async_grads(params, tau)
    popped_seq = []
    for t in range(8):
        g = {"w": jnp.full(2, float(t + 1))}
        popped, state = push_pop(state, g)
        popped_seq.append(float(popped["w"][0]))
    # cold start: tau zeros, then gradients delayed by exactly tau
    assert popped_seq == [0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0]


def test_async_state_specs_shapes():
    from jax.sharding import PartitionSpec as P
    specs = {"w": P("data", "model")}
    s = async_state_specs(specs, tau=2)
    assert s.ring["w"] == P(None, "data", "model")


# -- int8 gradient codec -----------------------------------------------------

@given(st.integers(0, 10**6), st.floats(0.1, 100.0))
def test_compression_roundtrip_error_bound(seed, scale):
    """|dequant(quant(g)) - g| <= max|block| / 127 / 2 per block (symmetric
    rounding) — the wire format is lossy but bounded."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(scale * rng.standard_normal((300,)), jnp.float32)}
    out = compression.roundtrip(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    bound = np.abs(np.asarray(g["w"])).max() / 127.0 * 0.5 + 1e-6
    assert err.max() <= bound * 1.001


def test_compression_preserves_shape_dtype():
    g = {"a": jnp.ones((7, 13), jnp.bfloat16), "b": jnp.zeros((5,), jnp.float32)}
    out = compression.roundtrip(g)
    for k in g:
        assert out[k].shape == g[k].shape and out[k].dtype == g[k].dtype


@given(st.integers(0, 10**6), st.integers(1, 700))
def test_quantize_elementwise_scale_bound(seed, size):
    """ELEMENTWISE |dequant(quant(g)) - g| <= scale/2 against the actual
    per-block scales the codec emitted (the tree-level test above only
    bounds via the global max).  Also pins ``quantization_error_bound`` as
    exactly half the largest scale — the eps ``theory.perturbed_factor``
    consumes."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((size,)) *
                    rng.lognormal(size=(size,)), jnp.float32)
    q, scales = compression.quantize_array(g)
    out = compression.dequantize_array(q, scales, shape=g.shape)
    err = np.abs(np.asarray(out) - np.asarray(g))
    # err lives in the padded/blocked frame: block i covers elements
    # [i*BLOCK, (i+1)*BLOCK) and must obey that block's own scale.
    s = np.asarray(scales)
    for i in range(len(s)):
        blk = err[i * compression.BLOCK:(i + 1) * compression.BLOCK]
        assert blk.max() <= s[i] * 0.5 * (1 + 1e-6) + 1e-12, (i, s[i])
    bound = float(compression.quantization_error_bound(g))
    np.testing.assert_allclose(bound, s.max() * 0.5, rtol=1e-6)
    assert err.max() <= bound * (1 + 1e-6) + 1e-12


def test_roundtrip_array_matches_tree_codec():
    """The per-array helpers (the engine's in-graph wire format) are the
    single-leaf forms of the tree codec — same blocks, same scales."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((37, 5)), jnp.float32)
    via_tree = compression.roundtrip({"g": g})["g"]
    via_array = compression.roundtrip_array(g)
    np.testing.assert_array_equal(np.asarray(via_tree), np.asarray(via_array))
    bf = compression.bf16_roundtrip_array(g)
    assert bf.dtype == g.dtype
    np.testing.assert_array_equal(
        np.asarray(bf), np.asarray(g.astype(jnp.bfloat16).astype(g.dtype)))


def test_error_feedback_drift_free_vs_naive():
    """A signal far below one quantization step: naive int8 rounds it to
    zero EVERY round (unbounded drift of the accumulated error), while the
    error-feedback residual accumulates until it crosses a step and fires —
    cumulative delivered mass tracks the truth to within one step."""
    rounds, n = 200, 64
    base = jnp.linspace(-1.0, 1.0, n)          # sets the block scale
    tiny = 1e-4                                 # << scale/127
    sig = base * 0 + tiny
    ef = compression.init_error_feedback({"w": jnp.zeros(n)})
    naive_sum = np.zeros(n)
    ef_sum = np.zeros(n)
    for _ in range(rounds):
        payload = {"w": base + sig}
        naive_sum += np.asarray(compression.roundtrip(payload)["w"])
        sent, ef = compression.compress_with_feedback(payload, ef)
        ef_sum += np.asarray(sent["w"])
    true_sum = np.asarray(base + sig) * rounds
    step = 2.0 / 127.0                          # one quantization step
    naive_err = np.abs(naive_sum - true_sum).max()
    ef_err = np.abs(ef_sum - true_sum).max()
    assert ef_err <= step * 1.5, ef_err          # bounded by ~one step
    assert naive_err >= rounds * tiny * 0.9      # drifts linearly in rounds
    assert ef_err < naive_err / 5


def test_error_feedback_reduces_bias():
    """With error feedback, the long-run mean of transmitted gradients equals
    the true mean (drift-free), unlike plain quantization of a tiny signal."""
    ef = compression.init_error_feedback({"w": jnp.zeros(64)})
    true = jnp.full((64,), 1e-4)   # far below one quantization step of noise
    base = jnp.linspace(-1.0, 1.0, 64)
    sent_sum = jnp.zeros(64)
    for i in range(50):
        g = {"w": base * 0 + true}
        sent, ef = compression.compress_with_feedback(g, ef)
        sent_sum = sent_sum + sent["w"]
    mean_sent = np.asarray(sent_sum) / 50
    np.testing.assert_allclose(mean_sent.mean(), 1e-4, rtol=0.2)
