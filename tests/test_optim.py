"""The int8 (+error-feedback) and bf16 wire codecs behind
``Schedule.compress``.  The optimizer/async-gradient tests that used to
live here went with the pruned LLM-template ``optim.adamw`` /
``optim.async_update`` modules (PR 8)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, strategies as st

from repro.optim import compression


@given(st.integers(0, 10**6), st.floats(0.1, 100.0))
def test_compression_roundtrip_error_bound(seed, scale):
    """|dequant(quant(g)) - g| <= max|block| / 127 / 2 per block (symmetric
    rounding) — the wire format is lossy but bounded."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(scale * rng.standard_normal((300,)), jnp.float32)}
    out = compression.roundtrip(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    bound = np.abs(np.asarray(g["w"])).max() / 127.0 * 0.5 + 1e-6
    assert err.max() <= bound * 1.001


def test_compression_preserves_shape_dtype():
    g = {"a": jnp.ones((7, 13), jnp.bfloat16), "b": jnp.zeros((5,), jnp.float32)}
    out = compression.roundtrip(g)
    for k in g:
        assert out[k].shape == g[k].shape and out[k].dtype == g[k].dtype


@given(st.integers(0, 10**6), st.integers(1, 700))
def test_quantize_elementwise_scale_bound(seed, size):
    """ELEMENTWISE |dequant(quant(g)) - g| <= scale/2 against the actual
    per-block scales the codec emitted (the tree-level test above only
    bounds via the global max).  Also pins ``quantization_error_bound`` as
    exactly half the largest scale — the eps ``theory.perturbed_factor``
    consumes."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((size,)) *
                    rng.lognormal(size=(size,)), jnp.float32)
    q, scales = compression.quantize_array(g)
    out = compression.dequantize_array(q, scales, shape=g.shape)
    err = np.abs(np.asarray(out) - np.asarray(g))
    # err lives in the padded/blocked frame: block i covers elements
    # [i*BLOCK, (i+1)*BLOCK) and must obey that block's own scale.
    s = np.asarray(scales)
    for i in range(len(s)):
        blk = err[i * compression.BLOCK:(i + 1) * compression.BLOCK]
        assert blk.max() <= s[i] * 0.5 * (1 + 1e-6) + 1e-12, (i, s[i])
    bound = float(compression.quantization_error_bound(g))
    np.testing.assert_allclose(bound, s.max() * 0.5, rtol=1e-6)
    assert err.max() <= bound * (1 + 1e-6) + 1e-12


def test_roundtrip_array_matches_tree_codec():
    """The per-array helpers (the engine's in-graph wire format) are the
    single-leaf forms of the tree codec — same blocks, same scales."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((37, 5)), jnp.float32)
    via_tree = compression.roundtrip({"g": g})["g"]
    via_array = compression.roundtrip_array(g)
    np.testing.assert_array_equal(np.asarray(via_tree), np.asarray(via_array))
    bf = compression.bf16_roundtrip_array(g)
    assert bf.dtype == g.dtype
    np.testing.assert_array_equal(
        np.asarray(bf), np.asarray(g.astype(jnp.bfloat16).astype(g.dtype)))


def test_error_feedback_drift_free_vs_naive():
    """A signal far below one quantization step: naive int8 rounds it to
    zero EVERY round (unbounded drift of the accumulated error), while the
    error-feedback residual accumulates until it crosses a step and fires —
    cumulative delivered mass tracks the truth to within one step."""
    rounds, n = 200, 64
    base = jnp.linspace(-1.0, 1.0, n)          # sets the block scale
    tiny = 1e-4                                 # << scale/127
    sig = base * 0 + tiny
    ef = compression.init_error_feedback({"w": jnp.zeros(n)})
    naive_sum = np.zeros(n)
    ef_sum = np.zeros(n)
    for _ in range(rounds):
        payload = {"w": base + sig}
        naive_sum += np.asarray(compression.roundtrip(payload)["w"])
        sent, ef = compression.compress_with_feedback(payload, ef)
        ef_sum += np.asarray(sent["w"])
    true_sum = np.asarray(base + sig) * rounds
    step = 2.0 / 127.0                          # one quantization step
    naive_err = np.abs(naive_sum - true_sum).max()
    ef_err = np.abs(ef_sum - true_sum).max()
    assert ef_err <= step * 1.5, ef_err          # bounded by ~one step
    assert naive_err >= rounds * tiny * 0.9      # drifts linearly in rounds
    assert ef_err < naive_err / 5


def test_error_feedback_reduces_bias():
    """With error feedback, the long-run mean of transmitted gradients equals
    the true mean (drift-free), unlike plain quantization of a tiny signal."""
    ef = compression.init_error_feedback({"w": jnp.zeros(64)})
    true = jnp.full((64,), 1e-4)   # far below one quantization step of noise
    base = jnp.linspace(-1.0, 1.0, 64)
    sent_sum = jnp.zeros(64)
    for _ in range(50):
        g = {"w": base * 0 + true}
        sent, ef = compression.compress_with_feedback(g, ef)
        sent_sum = sent_sum + sent["w"]
    mean_sent = np.asarray(sent_sum) / 50
    np.testing.assert_allclose(mean_sent.mean(), 1e-4, rtol=0.2)
