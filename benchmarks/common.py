"""Shared benchmark plumbing: CSV emission, JSON persistence, timing."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

#: Repo root — where the persisted BENCH_*.json files land so successive
#: PRs can diff them (printed records alone left no perf trajectory).
REPO_ROOT = Path(__file__).resolve().parent.parent


def emit(name: str, **fields):
    """One CSV-ish record per line: benchmark,key=value,..."""
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}", flush=True)


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "ndim", 1) == 0:
        return obj.item()                     # numpy / jax scalars
    if hasattr(obj, "tolist"):
        return obj.tolist()                   # numpy / jax arrays
    return obj


def provenance() -> dict:
    """Where a benchmark's numbers actually came from: jax version,
    backend, device kind, and whether Pallas ran in interpret mode
    (``repro.kernels.ops.interpret_default`` — the same predicate the
    kernel wrappers use), so interpret-mode CPU timings can never
    masquerade as hardware numbers when BENCH files are diffed."""
    from repro.kernels.ops import interpret_default
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            "interpret_mode": interpret_default()}


def write_json(name: str, payload) -> Path:
    """Persist a benchmark payload as ``BENCH_<name>.json`` at the repo
    root (round-trippable: numpy/jax scalars and arrays are plain lists).
    Every payload is stamped with ``provenance()``."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    payload = dict(_jsonable(payload))
    payload["provenance"] = provenance()
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[bench] wrote {path}", flush=True)
    return path


def timed(fn, *args, warmup: int = 1, iters: int = 3, stat: str = "median"):
    """Wall time of ``fn(*args)`` with block_until_ready.

    ``stat="median"`` (default) or ``"min"`` — min-of-N is the trustworthy
    statistic for BENCH_*.json deltas (one-sided noise: a run can only be
    slowed down by interference, never sped up), so bench_kernels times
    with ``stat="min"`` and a ``--repeats`` flag.
    """
    if stat not in ("min", "median"):
        raise ValueError(f"unknown stat: {stat!r}")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[0] if stat == "min" else times[len(times) // 2]
