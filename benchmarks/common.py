"""Shared benchmark plumbing: CSV emission + timed execution."""
from __future__ import annotations

import time

import jax


def emit(name: str, **fields):
    """One CSV-ish record per line: benchmark,key=value,..."""
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}", flush=True)


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
