"""RK vs CG-on-normal-equations in the low-accuracy regime (paper Sec. 7/8).

Equal-work comparison on overdetermined least squares: one RK sweep
(m row updates, O(mn) flops) vs one CG iteration on A^T A (two A matvecs,
O(mn) flops).  Reports per-sweep residual trajectories, wall time, and the
sweep count at which each solver first reaches the low-accuracy targets the
paper's regression workload needs (1e-1, 1e-2 relative residual above the
LSQ optimum).

Honest-reporting note (mirrors fig1_residual): with the fair baseline —
Jacobi-rescaled normal equations, Sec. 2.3 — CG leads at high accuracy
even on skewed designs.  RK's measured edge is the first sweeps (it
reaches the 1e-1 low-accuracy target in ~2 sweeps, before CG's spectrum
advantage compounds) plus the paper's scalability argument: an RK sweep
has ZERO global synchronization points while every CG iteration pays 2
blocking all-reduces, after an up-front A^T A formation the row-action
method never needs.

    PYTHONPATH=src python -m benchmarks.bench_lsq

A full run persists its records (wall-clock, relresid trajectories, problem
dims, P/tau) to BENCH_lsq.json at the repo root so later PRs can diff the
perf trajectory.  The ``overlap_tau`` section (``run_overlap_tau``, forced
4-device subprocess) records scheduled vs measured staleness for the
overlapped-sync variants and the theory quantities at both.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

import time

from benchmarks.common import emit, timed, write_json
from repro.core import (BlockBandedOp, CsrOp, Schedule, block_banded_spd,
                        cg_solve, random_lsq, random_sparse_lsq, rk_solve,
                        solve, theory, to_unit_diagonal)
from repro.core.engine import scheduled_tau, solve_distributed
from repro.launch.mesh import make_host_mesh


def _first_at(relresid, targets, floor):
    out = {}
    for t in targets:
        hit = np.nonzero(relresid <= floor + t)[0]
        out[t] = int(hit[0]) + 1 if hit.size else 0   # 0 = never reached
    return out


def run(m: int = 4096, n: int = 512, rhs: int = 8, sweeps: int = 12,
        noise: float = 0.01, col_scale: float = 1.0, seed: int = 0):
    prob = random_lsq(m, n, n_rhs=rhs, noise=noise, col_scale=col_scale,
                      seed=seed)
    x0 = jnp.zeros_like(prob.x_star)
    bn = float(jnp.linalg.norm(prob.b))
    floor = float(jnp.linalg.norm(prob.b - prob.A @ prob.x_star)) / bn

    res = rk_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(1),
                   num_iters=sweeps * m, record_every=m)
    # Jacobi-rescaled normal equations (Sec. 2.3) — the strongest fair
    # version of the baseline on skewed designs.
    An, dn = to_unit_diagonal(prob.A.T @ prob.A)
    bn_eq = dn[:, None] * (prob.A.T @ prob.b)
    cg = cg_solve(An, bn_eq, x0, prob.x_star / dn[:, None], num_iters=sweeps)

    rk_r = np.linalg.norm(np.asarray(res.resid), axis=1) / bn
    # CG records the (rescaled) normal-equation residual per iteration
    # (per-iteration x is not kept); the final true residual is recomputed.
    cg_ne = np.linalg.norm(np.asarray(cg.resid), axis=1)
    cg_final = float(jnp.linalg.norm(
        prob.b - prob.A @ (dn[:, None] * cg.x))) / bn

    t_rk = timed(lambda: rk_solve(prob.A, prob.b, x0, prob.x_star,
                                  key=jax.random.key(1), num_iters=sweeps * m,
                                  record_every=m).x)
    t_cg = timed(lambda: cg_solve(An, bn_eq, x0, prob.x_star / dn[:, None],
                                  num_iters=sweeps).x)
    t_ne = timed(lambda: prob.A.T @ prob.A)   # normal-equation formation cost

    for s in range(sweeps):
        emit("bench_lsq", sweep=s + 1, rk_relresid=f"{rk_r[s]:.4e}",
             cg_ne_resid=f"{cg_ne[s]:.4e}")
    hits = _first_at(rk_r, (1e-1, 1e-2), floor)
    emit("bench_lsq", summary=1, m=m, n=n, rhs=rhs,
         kappa=f"{float(prob.kappa):.1f}", floor=f"{floor:.3e}",
         rk_final=f"{rk_r[-1]:.3e}", cg_final=f"{cg_final:.3e}",
         rk_sweeps_to_1e1=hits[1e-1], rk_sweeps_to_1e2=hits[1e-2],
         rk_s=f"{t_rk:.2f}", cg_s=f"{t_cg:.2f}", ne_form_s=f"{t_ne:.2f}",
         rk_syncs_per_sweep=0, cg_syncs_per_iter=2,
         theory_factor=f"{float(theory.rk_factor(prob.A)):.6f}")
    return {
        "m": m, "n": n, "rhs": rhs, "sweeps": sweeps,
        "kappa": float(prob.kappa), "floor": floor,
        "rk_relresid": rk_r, "cg_ne_resid": cg_ne,
        "rk_final_relresid": float(rk_r[-1]), "cg_final_relresid": cg_final,
        "rk_sweeps_to_1e1": hits[1e-1], "rk_sweeps_to_1e2": hits[1e-2],
        "rk_wall_s": t_rk, "cg_wall_s": t_cg, "ne_form_wall_s": t_ne,
        "theory_factor": float(theory.rk_factor(prob.A)),
    }


def run_banded_rk(n: int = 2048, block: int = 64, bands: int = 2,
                  rhs: int = 8, rounds: int = 40, local_steps: int = 32,
                  beta: float = 0.9, seed: int = 0, workers: int = 0):
    """Block-banded Kaczmarz through the unified distributed driver — the
    Kaczmarz action × BlockBandedOp point of the engine's action×format
    grid (ISSUE 2 acceptance).  Each step reads/writes only (2*bands+1)
    MXU-shaped tiles, so the row action keeps the paper's Θ(nnz) cost on
    the TPU-native layout; sync is the RK-style delta psum with scheduled
    staleness local_steps - 1.
    """
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=rhs, seed=seed)
    op = BlockBandedOp.from_dense(prob.A, block=block, bands=bands)
    x0 = jnp.zeros_like(prob.x_star)
    workers = workers or len(jax.devices())
    mesh = make_host_mesh(workers)
    tau = scheduled_tau(workers, local_steps, shared_stream=True)

    t0 = time.perf_counter()
    res = solve_distributed(op, prob.b, x0, prob.x_star, action="rk",
                            key=jax.random.key(1), mesh=mesh, rounds=rounds,
                            local_steps=local_steps, beta=beta)
    jax.block_until_ready(res.x)
    wall = time.perf_counter() - t0
    r = np.linalg.norm(np.asarray(res.resid), axis=1)
    bn = float(jnp.linalg.norm(prob.b))
    rel = float(jnp.linalg.norm(prob.b - prob.A @ res.x)) / bn
    emit("bench_lsq_banded_rk", n=n, block=block, bands=bands, rhs=rhs,
         workers=workers, rounds=rounds, local_steps=local_steps, tau=tau,
         beta=beta, nnz_frac=f"{op.nnz_cost() / (n * n):.4f}",
         relresid_first=f"{r[0] / bn:.3e}", relresid_last=f"{r[-1] / bn:.3e}",
         final_relresid=f"{rel:.3e}", wall_s=f"{wall:.2f}")
    return {
        "n": n, "block": block, "bands": bands, "rhs": rhs,
        "workers": workers, "rounds": rounds, "local_steps": local_steps,
        "tau": tau, "beta": beta, "nnz_frac": op.nnz_cost() / (n * n),
        "relresid_first": float(r[0] / bn), "relresid_last": float(r[-1] / bn),
        "final_relresid": rel, "wall_s": wall,
    }


def run_csr_rk(m: int = 2048, n: int = 512, row_nnz: int = 16, rhs: int = 8,
               rounds: int = 60, local_steps: int = 32, beta: float = 0.9,
               seed: int = 0, workers: int = 0):
    """General-sparse Kaczmarz through the unified distributed driver — the
    Kaczmarz action × CsrOp point (ISSUE 3 tentpole): per-worker *local*
    row sampling (each worker draws from its own slab ∝ its row norms, so
    every step is a useful update — wall-clock-faithful, unlike the global
    masked stream) with delta-psum sync; the shared-stream scheduled
    staleness applies to the round's interleaved P*local_steps stream
    (tau = workers*local_steps - 1).
    """
    prob = random_sparse_lsq(m, n, row_nnz=row_nnz, n_rhs=rhs, seed=seed)
    op = CsrOp.from_dense(prob.A)
    x0 = jnp.zeros_like(prob.x_star)
    workers = workers or len(jax.devices())
    mesh = make_host_mesh(workers)
    # local sampling: the round's interleaved shared stream has
    # workers*local_steps picks (every worker's step is useful work)
    tau = scheduled_tau(workers, local_steps, shared_stream=True,
                        local_sampling=True)

    t0 = time.perf_counter()
    res = solve_distributed(op, prob.b, x0, prob.x_star, action="rk",
                            key=jax.random.key(1), mesh=mesh, rounds=rounds,
                            local_steps=local_steps, beta=beta)
    jax.block_until_ready(res.x)
    wall = time.perf_counter() - t0
    r = np.linalg.norm(np.asarray(res.resid), axis=1)
    bn = float(jnp.linalg.norm(prob.b))
    rel = float(jnp.linalg.norm(prob.b - prob.A @ res.x)) / bn
    emit("bench_lsq_csr_rk", m=m, n=n, row_nnz=row_nnz, rhs=rhs,
         workers=workers, rounds=rounds, local_steps=local_steps, tau=tau,
         beta=beta, nnz_frac=f"{op.nnz_cost() / (m * n):.4f}",
         relresid_first=f"{r[0] / bn:.3e}", relresid_last=f"{r[-1] / bn:.3e}",
         final_relresid=f"{rel:.3e}", wall_s=f"{wall:.2f}")
    return {
        "m": m, "n": n, "row_nnz": row_nnz, "rhs": rhs,
        "workers": workers, "rounds": rounds, "local_steps": local_steps,
        "tau": tau, "beta": beta, "nnz_frac": op.nnz_cost() / (m * n),
        "relresid_first": float(r[0] / bn), "relresid_last": float(r[-1] / bn),
        "final_relresid": rel, "wall_s": wall,
    }


def run_partitioned_rk(m: int = 2048, n: int = 512, row_nnz: int = 16,
                       rhs: int = 8, rounds: int = 60, local_steps: int = 32,
                       beta: float = 0.9, skew: float = 20.0, seed: int = 0,
                       workers: int = 0):
    """Contiguous vs norm-balanced slab assignment on a skewed design
    (ISSUE 4 tentpole): the first quarter of rows is scaled by ``skew``, so
    contiguous slabs concentrate the norm mass on one worker — biasing the
    stationary row law of per-worker local sampling away from the global
    Strohmer–Vershynin distribution and skewing per-round work.  Reports
    per-slab norm mass (max/uniform) and the convergence trajectory under
    both assignments.
    """
    from repro.core import partition as pt

    base = random_sparse_lsq(m, n, row_nnz=row_nnz, n_rhs=rhs, seed=seed)
    A = np.array(base.A)
    A[: m // 4] *= skew
    rng = np.random.default_rng(seed + 1)
    xt = rng.standard_normal((n, rhs)).astype(np.float32)
    Aj = jnp.asarray(A)
    bj = jnp.asarray(A @ xt)
    op = CsrOp.from_dense(Aj)
    workers = workers or len(jax.devices())
    mesh = make_host_mesh(workers)
    # Partition quality is a property of the matrix, not of this run's
    # device count: report the slab-mass imbalance at >= 4 slabs so a
    # single-device container run still records the contrast — but only
    # when that slab count divides m (the solver only requires workers to).
    stats_slabs = max(workers, 4)
    if m % stats_slabs:
        stats_slabs = workers
    rn = np.asarray(op.row_norms_sq())
    uniform = rn.sum() / stats_slabs
    labels_bal = pt.balanced_labels(op, stats_slabs)
    rp = pt.partition_permutation(labels_bal, stats_slabs)
    labels_cont = np.arange(m) // (m // stats_slabs)
    mass = {
        "contiguous": float(
            pt.slab_norm_mass(rn, np.arange(m), stats_slabs).max()
            / uniform),
        "balanced": float(
            pt.slab_norm_mass(rn, np.asarray(rp.perm), stats_slabs).max()
            / uniform),
    }
    # Cross-slab reach: how many stored nonzeros each assignment leaves
    # outside the owner slab — the wire-volume cost the norm-balanced
    # bin-packing is free to inflate, and the quantity a future
    # reach-aware packing would minimize jointly with the norm mass.
    cross = None
    if n % stats_slabs == 0:
        total_nnz = int(op.nnz_cost())
        cross = {
            "contiguous": pt.cross_slab_edges(op, labels_cont, stats_slabs),
            "balanced": pt.cross_slab_edges(op, labels_bal, stats_slabs),
            "total_nnz": total_nnz,
        }
        emit("bench_lsq_partitioned_rk", stats_slabs=stats_slabs,
             cross_edges_contiguous=cross["contiguous"],
             cross_edges_balanced=cross["balanced"], total_nnz=total_nnz)

    out = {"m": m, "n": n, "row_nnz": row_nnz, "rhs": rhs, "skew": skew,
           "workers": workers, "stats_slabs": stats_slabs, "rounds": rounds,
           "local_steps": local_steps, "beta": beta,
           "slab_mass_max_over_uniform": mass,
           "cross_slab_edges": cross}
    x0 = jnp.zeros((n, rhs))
    bn = float(jnp.linalg.norm(bj))
    for part in ("contiguous", "balanced"):
        t0 = time.perf_counter()
        res = solve_distributed(op, bj, x0, jnp.asarray(xt), action="rk",
                                key=jax.random.key(1), mesh=mesh,
                                rounds=rounds, local_steps=local_steps,
                                beta=beta, partition=part)
        jax.block_until_ready(res.x)
        wall = time.perf_counter() - t0
        rel = float(jnp.linalg.norm(bj - Aj @ res.x)) / bn
        r = np.linalg.norm(np.asarray(res.resid), axis=1)
        emit("bench_lsq_partitioned_rk", partition=part,
             slab_mass_max_over_uniform=f"{mass[part]:.2f}",
             relresid_first=f"{r[0] / bn:.3e}",
             relresid_last=f"{r[-1] / bn:.3e}", final_relresid=f"{rel:.3e}",
             wall_s=f"{wall:.2f}")
        out[part] = {"final_relresid": rel,
                     "relresid_first": float(r[0] / bn),
                     "relresid_last": float(r[-1] / bn), "wall_s": wall}
    return out


_OVERLAP_TAU_SCRIPT = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import CsrOp, random_sparse_spd, theory
from repro.core.engine import scheduled_tau, solve_distributed
from repro.launch.mesh import make_host_mesh

P, L, rounds = {workers}, {local_steps}, {rounds}
prob = random_sparse_spd({n}, row_nnz={row_nnz}, n_rhs={rhs}, seed={seed})
op = CsrOp.from_dense(prob.A)
x0 = jnp.zeros_like(prob.x_star)
mesh = make_host_mesh(P)
rho = float(theory.rho(prob.A))
out = {{"workers": P, "local_steps": L, "rounds": rounds, "rho": rho}}
for action, fused in (("gs", True), ("rk", True)):
    local_sampling = action == "rk"
    tau_lock = scheduled_tau(P, L, local_sampling=local_sampling)
    res = {{}}
    for overlap in (False, True):
        r = solve_distributed(op, prob.b, x0, prob.x_star, action=action,
                              key=jax.random.key(1), mesh=mesh,
                              rounds=rounds, local_steps=L, beta={beta},
                              fused=fused, overlap=overlap)
        jax.block_until_ready(r.x)
        rec = {{"tau_scheduled": int(r.tau),
               "err_first": float(r.err_sq[0].max()),
               "err_last": float(r.err_sq[-1].max()),
               "beta_opt": theory.beta_opt(rho, int(r.tau))}}
        if overlap:
            lag = np.asarray(r.lag)
            # measured staleness: in-flight payload + lockstep interleave
            rec["lag_trace_head"] = lag[:4].tolist()
            rec["lag_steady"] = int(lag[1:].max()) if rounds > 1 else 0
            rec["tau_empirical"] = int(lag.max()) + tau_lock
            rec["bound_holds"] = rec["tau_empirical"] <= int(r.tau)
            rec["beta_opt_empirical"] = theory.beta_opt(
                rho, rec["tau_empirical"])
            rec["nu_tau_at_beta"] = theory.nu_tau(rho, rec["tau_empirical"],
                                                  {beta})
        res["overlap" if overlap else "lockstep"] = rec
    out[action] = res
print("OVERLAP_TAU_JSON " + json.dumps(out))
"""


def run_overlap_tau(n: int = 256, row_nnz: int = 8, rhs: int = 4,
                    rounds: int = 30, local_steps: int = 8,
                    beta: float = 0.9, seed: int = 2, workers: int = 4):
    """Scheduled vs measured staleness for the overlapped-sync variants
    (ISSUE 6 tentpole): runs sparse GS / sparse RK lockstep and overlapped
    on a forced-``workers``-device host mesh (fresh interpreter — XLA's
    device count is fixed at import) and reports the per-round lag trace,
    the empirical tau it implies, and the theory quantities
    (``beta_opt``, ``nu_tau``) at both the scheduled bound and the
    measured staleness.  The scheduled bound must dominate the measured
    trace — that is the contract the overlap term of ``scheduled_tau``
    encodes.
    """
    script = ("import os\n"
              f'os.environ["XLA_FLAGS"] = '
              f'"--xla_force_host_platform_device_count={workers}"\n'
              + _OVERLAP_TAU_SCRIPT.format(
                  workers=workers, local_steps=local_steps, rounds=rounds,
                  n=n, row_nnz=row_nnz, rhs=rhs, seed=seed, beta=beta))
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"overlap-tau subprocess failed:\n"
                           f"{proc.stderr[-3000:]}")
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("OVERLAP_TAU_JSON "))
    out = json.loads(line[len("OVERLAP_TAU_JSON "):])
    for action in ("gs", "rk"):
        ov, lk = out[action]["overlap"], out[action]["lockstep"]
        if not ov["bound_holds"]:
            raise RuntimeError(
                f"measured tau {ov['tau_empirical']} exceeds scheduled "
                f"bound {ov['tau_scheduled']} for {action}")
        emit("bench_lsq_overlap_tau", action=action, workers=workers,
             local_steps=local_steps, tau_lockstep=lk["tau_scheduled"],
             tau_overlap=ov["tau_scheduled"],
             tau_empirical=ov["tau_empirical"],
             lag_steady=ov["lag_steady"],
             beta_opt_scheduled=f"{ov['beta_opt']:.4f}",
             beta_opt_empirical=f"{ov['beta_opt_empirical']:.4f}",
             nu_tau_at_beta=f"{ov['nu_tau_at_beta']:.4f}",
             err_last_lockstep=f"{lk['err_last']:.3e}",
             err_last_overlap=f"{ov['err_last']:.3e}")
    return out


_PRECISION_SCRIPT = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import CsrOp, random_sparse_lsq
from repro.core.engine import solve_distributed
from repro.launch.mesh import make_host_mesh

P, L, rounds = {workers}, {local_steps}, {rounds}
prob = random_sparse_lsq({m}, {n}, row_nnz={row_nnz}, n_rhs={rhs},
                         seed={seed})
op = CsrOp.from_dense(prob.A)
x0 = jnp.zeros_like(prob.x_star)
mesh = make_host_mesh(P)
tol = {tol}
out = {{"workers": P, "local_steps": L, "rounds": rounds, "tol": tol}}
for compress in ("none", "bf16", "int8_ef"):
    r = solve_distributed(op, prob.b, x0, prob.x_star, action="rk",
                          key=jax.random.key(1), mesh=mesh, rounds=rounds,
                          local_steps=L, beta={beta}, sync="psum",
                          compress=compress)
    err = np.asarray(r.err_sq).max(axis=1)
    hit = np.nonzero(err <= tol * err[0])[0]
    out[compress] = {{
        "bytes_per_round": float(r.bytes_per_round),
        "err_first": float(err[0]), "err_last": float(err[-1]),
        "rounds_to_tol": int(hit[0]) + 1 if hit.size else 0,
    }}
print("PRECISION_JSON " + json.dumps(out))
"""


def run_precision(m: int = 512, n: int = 256, row_nnz: int = 6, rhs: int = 2,
                  rounds: int = 60, local_steps: int = 16, beta: float = 1.0,
                  tol: float = 0.05, seed: int = 3, workers: int = 4,
                  sweeps: int = 8):
    """The precision trade-off, measured (ISSUE 7 tentpole).

    Wire: sparse-RK delta psum on a forced-``workers``-device mesh with
    ``compress`` ∈ {none, bf16, int8_ef} — per-mode bytes-per-round (the
    engine's analytic payload model), rounds to reach ``tol`` × the
    round-1 error, and the round inflation vs the exact f32 wire (the
    acceptance gate: int8+EF within 1.3×).  Storage: the same design
    solved sequentially with f32 vs bf16 coefficient panels, reporting
    sweeps to the low-accuracy target.  Theory: the perturbed-rate
    prediction from ``theory.iteration_inflation`` — storage rounding and
    wire quantization are RELATIVE perturbations (error proportional to
    the step, not the iterate), so the per-step contraction moves from
    ``c`` to ``c + eps*(1-c)`` and the predicted inflation stays finite.
    """
    script = ("import os\n"
              f'os.environ["XLA_FLAGS"] = '
              f'"--xla_force_host_platform_device_count={workers}"\n'
              + _PRECISION_SCRIPT.format(
                  workers=workers, local_steps=local_steps, rounds=rounds,
                  m=m, n=n, row_nnz=row_nnz, rhs=rhs, seed=seed, beta=beta,
                  tol=tol))
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"precision subprocess failed:\n"
                           f"{proc.stderr[-3000:]}")
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("PRECISION_JSON "))
    out = json.loads(line[len("PRECISION_JSON "):])
    base = out["none"]["rounds_to_tol"]
    if base == 0:
        raise RuntimeError(f"f32 wire never reached tol {tol}")
    for c in ("none", "bf16", "int8_ef"):
        rec = out[c]
        if rec["rounds_to_tol"] == 0:
            raise RuntimeError(f"compress={c} never reached tol {tol}")
        rec["round_inflation_vs_f32"] = rec["rounds_to_tol"] / base
        rec["bytes_to_tol"] = rec["bytes_per_round"] * rec["rounds_to_tol"]
        emit("bench_lsq_precision", compress=c,
             bytes_per_round=f"{rec['bytes_per_round']:.0f}",
             rounds_to_tol=rec["rounds_to_tol"],
             round_inflation=f"{rec['round_inflation_vs_f32']:.2f}",
             bytes_to_tol=f"{rec['bytes_to_tol']:.0f}",
             err_last=f"{rec['err_last']:.3e}")
    if out["int8_ef"]["round_inflation_vs_f32"] > 1.3:
        raise RuntimeError(
            f"int8+EF round inflation "
            f"{out['int8_ef']['round_inflation_vs_f32']:.2f} exceeds the "
            f"1.3x acceptance bound")

    # storage: f32 vs bf16 coefficient panels, sequential RK, equal work
    prob = random_sparse_lsq(m, n, row_nnz=row_nnz, n_rhs=rhs, seed=seed)
    bn = float(jnp.linalg.norm(prob.b))
    floor = float(jnp.linalg.norm(prob.b - prob.A @ prob.x_star)) / bn
    storage = {}
    for dt in ("float32", "bfloat16"):
        r = solve(prob, key=jax.random.key(1), format="csr",
                  storage_dtype=dt,
                  schedule=Schedule(num_iters=sweeps * m, record_every=m))
        rel = np.linalg.norm(np.asarray(r.resid), axis=1) / bn
        hits = _first_at(rel, (1e-1,), floor)
        storage[dt] = {"final_relresid": float(rel[-1]),
                       "sweeps_to_1e1": hits[1e-1]}
        emit("bench_lsq_precision", storage_dtype=dt,
             final_relresid=f"{rel[-1]:.3e}", sweeps_to_1e1=hits[1e-1])
    out["storage"] = storage

    # theory: predicted inflation from the measured perturbation bounds
    f = float(theory.rk_factor(prob.A))
    A = np.asarray(prob.A)
    Ar = np.asarray(jnp.asarray(prob.A).astype(jnp.bfloat16)
                    .astype(jnp.float32))
    eps_bf16 = float(np.abs(A - Ar).max() / np.abs(A).max())
    eps_int8 = 1.0 / 254.0            # half a quantization step, relative
    c = float(np.sqrt(f))
    pred = {
        "exact_factor": f,
        "eps_bf16_storage": eps_bf16,
        "eps_int8_wire": eps_int8,
        "inflation_bf16": theory.iteration_inflation(f, eps_bf16 * (1 - c)),
        "inflation_int8": theory.iteration_inflation(f, eps_int8 * (1 - c)),
    }
    out["theory"] = pred
    emit("bench_lsq_precision", exact_factor=f"{f:.6f}",
         predicted_inflation_bf16=f"{pred['inflation_bf16']:.3f}",
         predicted_inflation_int8=f"{pred['inflation_int8']:.3f}")
    return out


if __name__ == "__main__":
    payload = {
        "lsq": run(),
        "banded_rk": run_banded_rk(),
        "csr_rk": run_csr_rk(),
        "partitioned_rk": run_partitioned_rk(),
        "overlap_tau": run_overlap_tau(),
        "precision": run_precision(),
    }
    write_json("lsq", payload)
