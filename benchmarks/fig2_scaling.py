"""Paper Figure 2 analogue: scaling of Asynchronous Randomized Gauss-Seidel
with worker count (10 sweeps wall time, speedup vs 1 worker), against CG.

Worker counts require separate processes (the XLA host-device count is fixed
at first init), so each point runs in a subprocess with
--xla_force_host_platform_device_count=<P>.  On this container the devices
share one physical core, so *wall-clock* speedups are not observable — we
report the per-worker iteration counts and the communication rounds (the
quantities that scale), plus wall time for completeness."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_WORKER_SCRIPT = textwrap.dedent("""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(P)d"
import jax, jax.numpy as jnp
from repro.core import parallel_rgs_solve, random_sparse_spd, theory
from repro.launch.mesh import make_host_mesh

P = %(P)d; n = %(n)d; sweeps = %(sweeps)d
prob = random_sparse_spd(n, row_nnz=16, offdiag=0.95, n_rhs=4, seed=0)
mesh = make_host_mesh(P)
local = n // P
rho = float(theory.rho(prob.A))
tau = (P - 1) * local
beta = theory.beta_opt(rho, tau)
x0 = jnp.zeros_like(prob.x_star)
# warmup (compile)
r = parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(0),
                       mesh=mesh, rounds=1, local_steps=local, beta=beta)
jax.block_until_ready(r.x)
t0 = time.time()
r = parallel_rgs_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(0),
                       mesh=mesh, rounds=sweeps, local_steps=local, beta=beta)
jax.block_until_ready(r.x)
dt = time.time() - t0
resid = float(jnp.linalg.norm(r.resid[-1]) / jnp.linalg.norm(prob.b))
print(json.dumps(dict(P=P, tau=tau, beta=beta, wall_s=dt, resid=resid,
                      iters_per_worker=sweeps * local, sync_rounds=sweeps)))
""")


def run(n: int = 1024, sweeps: int = 10, workers=(1, 2, 4, 8)):
    results = []
    for P in workers:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("JAX_PLATFORMS", None)
        out = subprocess.run(
            [sys.executable, "-c", _WORKER_SCRIPT % dict(P=P, n=n, sweeps=sweeps)],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if out.returncode != 0:
            emit("fig2_scaling", P=P, error=out.stderr.strip()[-200:])
            continue
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        results.append(rec)
        emit("fig2_scaling", P=rec["P"], tau=rec["tau"],
             beta=f"{rec['beta']:.3f}", wall_s=f"{rec['wall_s']:.2f}",
             resid_10sweeps=f"{rec['resid']:.3e}",
             iters_per_worker=rec["iters_per_worker"],
             sync_rounds=rec["sync_rounds"])
    if results:
        base = results[0]
        for rec in results:
            emit("fig2_scaling_derived", P=rec["P"],
                 work_speedup=f"{base['iters_per_worker']/rec['iters_per_worker']:.2f}",
                 resid_ratio_vs_P1=f"{rec['resid']/max(base['resid'],1e-30):.2f}")
    return results


if __name__ == "__main__":
    run()
