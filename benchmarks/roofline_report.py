"""Aggregate the dry-run artifacts (experiments/dryrun/*.json) into the
§Dry-run / §Roofline tables.  Pure post-processing — no compilation."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load(out_dir: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def run(out_dir: str = "experiments/dryrun", mesh: str = "16x16"):
    recs = load(out_dir)
    if not recs:
        emit("roofline", error="no dry-run artifacts; run "
             "`python -m repro.launch.dryrun` first")
        return
    n_pass = n_fail = n_skip = 0
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        tag = f"{r['arch']}/{r['shape']}"
        if r.get("skip"):
            n_skip += 1
            emit("roofline", cell=tag, status="SKIP")
            continue
        if not r.get("ok"):
            n_fail += 1
            emit("roofline", cell=tag, status="FAIL")
            continue
        n_pass += 1
        emit("roofline", cell=tag, status="PASS",
             bottleneck=r["bottleneck"],
             t_comp=f"{r['t_comp_s']:.3e}", t_mem=f"{r['t_mem_s']:.3e}",
             t_coll=f"{r['t_coll_s']:.3e}",
             useful_ratio=f"{r['useful_flop_ratio']:.3f}",
             roofline_frac=f"{r['roofline_fraction']:.4f}",
             bytes_per_dev=f"{r['bytes_per_device']:.3e}")
    emit("roofline_summary", mesh=mesh, passed=n_pass, failed=n_fail,
         skipped=n_skip)


if __name__ == "__main__":
    run()
