"""Serving-layer benchmark: queries/sec + p50/p99 latency under a
synthetic open-loop load, persisted as ``BENCH_serve.json``.

This is a new BENCH axis beyond per-kernel wall time: the quantity the
serving layer exists to improve is request throughput at bounded tail
latency, and the quantity that proves continuous batching works is the
ratio against a one-request-at-a-time baseline (``max_batch=1``, same
request stream, same tolerances).  Both runs replay the identical
deterministic arrival plan, and because the service's pick stream is
fixed per problem and RHS columns are independent, each request reaches
tolerance in the SAME number of record chunks in both modes — equal
convergence, so the speedup is pure batching, not slack accuracy.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax  # noqa: E402

from benchmarks.common import emit, write_json  # noqa: E402
from repro.core import random_sparse_spd  # noqa: E402
from repro.serve import (  # noqa: E402
    RHS_BUCKETS, SolverService, bucket_rhs, open_loop_load)


def warm_buckets(args, serial: bool) -> tuple:
    """Every RHS bucket the mode can encounter, for pre-compilation.

    Warmup happens at registration, BEFORE the measured window opens —
    the steady-state numbers must measure the warm executable cache, not
    first-touch compilation (same discipline as ``common.timed``).
    Serial batches carry one request; batched batches anything up to
    ``max_batch`` requests of the widest shape.
    """
    caps = {bucket_rhs(w) for w in args.rhs_widths}
    if not serial:
        cap = min(args.max_batch, args.requests) * max(args.rhs_widths)
        caps |= {b for b in RHS_BUCKETS if b <= cap} | {bucket_rhs(cap)}
    return tuple(sorted(caps))


def run_mode(prob, *, serial: bool, args):
    svc = SolverService(
        num_iters=args.max_iters, record_every=args.record_every,
        max_batch=1 if serial else args.max_batch,
        batch_window_s=0.0 if serial else args.batch_window_ms * 1e-3)
    svc.register("bench", prob.A, action="gs", format=args.format,
                 seed=args.seed, warmup_buckets=warm_buckets(args, serial))
    with svc:
        report = open_loop_load(
            svc, "bench", requests=args.requests, rate_hz=args.rate,
            rhs_widths=tuple(args.rhs_widths), rtol=args.rtol,
            seed=args.seed)
    mode = "serial" if serial else "batched"
    emit(f"serve_{mode}", qps=round(report.qps, 2),
         p50_ms=round(report.p50_ms, 2), p99_ms=round(report.p99_ms, 2),
         converged=report.converged, batches=svc.stats.batches,
         chunk_launches=svc.stats.chunk_launches)
    return {
        "qps": report.qps,
        "p50_ms": report.p50_ms,
        "p99_ms": report.p99_ms,
        "mean_ms": report.mean_ms,
        "makespan_s": report.makespan_s,
        "requests": report.requests,
        "converged": report.converged,
        "rounds_per_request": report.rounds_per_request,
        "batches": svc.stats.batches,
        "chunk_launches": svc.stats.chunk_launches,
        "batch_widths": svc.stats.batch_widths,
        "executor_cache": svc.executors.stats(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--row-nnz", type=int, default=8)
    ap.add_argument("--format", choices=("dense", "ell", "csr"),
                    default="csr")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--rhs-widths", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--rtol", type=float, default=1e-3)
    ap.add_argument("--max-iters", type=int, default=4096)
    ap.add_argument("--record-every", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--batch-window-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args(argv)

    prob = random_sparse_spd(args.n, row_nnz=args.row_nnz, n_rhs=1,
                             seed=args.seed)
    batched = run_mode(prob, serial=False, args=args)
    serial = run_mode(prob, serial=True, args=args)

    equal_convergence = (
        batched["converged"] == serial["converged"]
        and batched["rounds_per_request"] == serial["rounds_per_request"])
    payload = {
        "config": {
            "n": args.n, "row_nnz": args.row_nnz, "format": args.format,
            "requests": args.requests, "rate_hz": args.rate,
            "rhs_widths": args.rhs_widths, "rtol": args.rtol,
            "max_iters": args.max_iters, "record_every": args.record_every,
            "max_batch": args.max_batch,
            "batch_window_ms": args.batch_window_ms, "seed": args.seed,
            "backend": jax.default_backend(),
        },
        "batched": batched,
        "serial": serial,
        "speedup_qps": batched["qps"] / serial["qps"],
        "equal_convergence": equal_convergence,
    }
    emit("serve_summary", speedup_qps=round(payload["speedup_qps"], 2),
         equal_convergence=equal_convergence)
    if not args.no_write:
        write_json("serve", payload)
    return payload


if __name__ == "__main__":
    main()
