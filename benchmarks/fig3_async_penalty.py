"""Paper Figure 3 analogue: relative residual after 10 sweeps — synchronous
RGS vs asynchronous RGS at increasing staleness, with min/max over trials
(the paper runs 5 extra trials at 64 threads and reports the spread).

The paper's claim to reproduce: the asynchronous residual is slightly worse
but the same order of magnitude, and the spread across schedules is small.
Both read models are measured; the fixed direction stream mirrors the
paper's Random123 trick (same d_j across all variants)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import async_rgs_solve, random_sparse_spd, rgs_solve, theory


def run(n: int = 1024, sweeps: int = 10, taus=(4, 16, 64), trials: int = 5):
    prob = random_sparse_spd(n, row_nnz=16, offdiag=0.95, n_rhs=4, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    b_norm = float(jnp.linalg.norm(prob.b))
    iters = sweeps * n
    key = jax.random.key(42)          # fixed direction stream for ALL variants

    sync = rgs_solve(prob.A, prob.b, x0, prob.x_star, key=key, num_iters=iters)
    sync_r = float(jnp.linalg.norm(sync.resid[-1]) / b_norm)
    emit("fig3_async_penalty", variant="sync", tau=0,
         resid=f"{sync_r:.4e}")

    rho = float(theory.rho(prob.A))
    rho2 = float(theory.rho2(prob.A))
    for tau in taus:
        for model in ("consistent", "inconsistent"):
            beta = 1.0
            if model == "inconsistent" or 2 * rho * tau >= 1:
                beta = (theory.beta_opt_inconsistent(rho2, tau)
                        if model == "inconsistent" else theory.beta_opt(rho, tau))
            rs = []
            for t in range(trials):
                res = async_rgs_solve(
                    prob.A, prob.b, x0, prob.x_star, key=key,
                    delay_key=jax.random.key(100 + t), num_iters=iters,
                    tau=tau, beta=beta, read_model=model,
                    delay_mode="uniform" if model == "consistent" else "fixed")
                rs.append(float(jnp.linalg.norm(res.resid[-1]) / b_norm))
            emit("fig3_async_penalty", variant=model, tau=tau,
                 beta=f"{beta:.3f}", resid_min=f"{min(rs):.4e}",
                 resid_max=f"{max(rs):.4e}",
                 penalty_vs_sync=f"{np.mean(rs)/sync_r:.2f}x")
    return sync_r


if __name__ == "__main__":
    run()
