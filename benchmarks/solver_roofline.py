"""Roofline dry-run for the paper's own solver at pod scale (the third
§Perf hillclimb cell — the one most representative of the paper's
technique).

Lowers `parallel_rgs_solve` (distributed asynchronous randomized block-GS,
shard_map over 256 workers) against ShapeDtypeStruct stand-ins on the
production 16x16 mesh, in a subprocess with 512 placeholder devices, and
extracts the same three roofline terms as the model cells.

Problem: reference-scenario n=131072, 64 RHS, coordinate blocks of 128 —
each local step is a (128, n) x (n, 64) MXU matmul against the stale
replica; one all-gather of the slab deltas per round (the paper's periodic
synchronization).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SCRIPT = textwrap.dedent("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax, jax.numpy as jnp
from repro.core.parallel_rgs import (parallel_rgs_solve, parallel_rgs_banded,
                                     parallel_rgs_halo)
from repro import roofline as RL
from repro.compat import cost_analysis, make_mesh

n = %(n)d; k = %(k)d; rounds = %(rounds)d; local_steps = %(local)d
block = %(block)d; bands = %(bands)d; layout = "%(layout)s"
dtype = jnp.%(dtype)s  # metrics flag: %(metrics)s
mesh = make_mesh((256,), ("workers",))
sds = jax.ShapeDtypeStruct
b = sds((n, k), dtype)
x0 = sds((n, k), dtype)
xs = sds((n, k), dtype)
key = jax.eval_shape(lambda: jax.random.key(0))
slab = n // 256

if layout == "dense":
    A = sds((n, n), dtype)
    def run(A, b, x0, xs, key):
        return parallel_rgs_solve(A, b, x0, xs, key=key, mesh=mesh,
                                  rounds=rounds, local_steps=local_steps,
                                  block=block, beta=0.9, unroll=True)
    # each step: (block x n x k) stale matmul + (block x slab x k) correction
    mf = 256 * rounds * local_steps * 2 * block * k * (n + slab)
elif layout == "banded":
    nb = n // block
    A = sds((nb, 2 * bands + 1, block, block), dtype)
    def run(A, b, x0, xs, key):
        return parallel_rgs_banded(A, b, x0, xs, key=key, mesh=mesh,
                                   rounds=rounds, local_steps=local_steps,
                                   block=block, bands=bands, beta=0.9,
                                   unroll=True, with_metrics=%(metrics)s)
    # each step touches (2*bands+1) block x block tiles
    mf = 256 * rounds * local_steps * 2 * (2 * bands + 1) * block * block * k
else:  # halo
    nb = n // block
    A = sds((nb, 2 * bands + 1, block, block), dtype)
    def run(A, b, x0, xs, key):
        return parallel_rgs_halo(A, b, x0, key=key, mesh=mesh,
                                 rounds=rounds, local_steps=local_steps,
                                 block=block, bands=bands, beta=0.9,
                                 unroll=True, with_metrics=%(metrics)s)
    mf = 256 * rounds * local_steps * 2 * (2 * bands + 1) * block * block * k

lowered = jax.jit(run).lower(A, b, x0, xs, key)
compiled = lowered.compile()
cost = cost_analysis(compiled)
hlo = compiled.as_text()
rl = RL.analyze(cost, hlo, chips=256, model_flops=mf)
mem = compiled.memory_analysis()
print(json.dumps(dict(
    flops=rl.flops, bytes=rl.mem_bytes, wire=rl.coll.wire_bytes,
    t_comp=rl.t_comp, t_mem=rl.t_mem, t_coll=rl.t_coll,
    bottleneck=rl.bottleneck, model_flops=mf,
    useful=rl.useful_ratio, frac=rl.roofline_fraction,
    coll={k2: v for k2, v in rl.coll.by_kind.items()},
    args=getattr(mem, "argument_size_in_bytes", None),
    temp=getattr(mem, "temp_size_in_bytes", None))))
""")


def run(n: int = 131072, k: int = 64, rounds: int = 4, local: int = 8,
        block: int = 128, tag: str = "baseline", layout: str = "dense",
        bands: int = 2, dtype: str = "float32", metrics: bool = True):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % dict(n=n, k=k, rounds=rounds, local=local, block=block,
                        bands=bands, layout=layout, dtype=dtype,
                        metrics=metrics)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if out.returncode != 0:
        emit("solver_roofline", tag=tag, error=out.stderr.strip()[-400:])
        return None
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    emit("solver_roofline", tag=tag, layout=layout, dtype=dtype, n=n, rhs=k,
         block=block, bands=bands, rounds=rounds, local_steps=local,
         t_comp=f"{rec['t_comp']:.3e}", t_mem=f"{rec['t_mem']:.3e}",
         t_coll=f"{rec['t_coll']:.3e}", bottleneck=rec["bottleneck"],
         useful_ratio=f"{rec['useful']:.3f}",
         roofline_frac=f"{rec['frac']:.4f}")
    return rec


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=131072)
    ap.add_argument("--rhs", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local", type=int, default=8)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--bands", type=int, default=2)
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "banded", "halo"])
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--no-metrics", action="store_true")
    ap.add_argument("--tag", default="baseline")
    a = ap.parse_args()
    run(a.n, a.rhs, a.rounds, a.local, a.block, a.tag, a.layout, a.bands,
        a.dtype, metrics=not a.no_metrics)
