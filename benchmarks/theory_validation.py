"""Theorem validation (the paper's analytical contribution, measured):

* Leventhal-Lewis rate (eq. 2) — measured E_m vs the bound;
* Thm 4.1(a) epoch factor under bounded-delay consistent reads;
* Sec. 5 step-size theory — nu_tau(beta) maximized at beta~;
* Thm 6.1 inconsistent-read convergence at omega-optimal beta.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (a_norm_sq, async_rgs_solve, random_sparse_spd,
                        rgs_solve, theory)


def run(n: int = 512, seeds: int = 8):
    prob = random_sparse_spd(n, row_nnz=8, offdiag=0.9, n_rhs=1, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    e0 = float(a_norm_sq(prob.A, -prob.x_star).max())
    lam_min, lam_max = float(prob.lam_min), float(prob.lam_max)
    kappa = float(prob.kappa)
    rho = float(theory.rho(prob.A))

    # (1) synchronous rate vs eq. (2)
    m = 4 * n
    errs = []
    for s in range(seeds):
        r = rgs_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(s),
                      num_iters=m, record_every=m)
        errs.append(float(r.err_sq[-1].max()))
    bound = float(theory.ll_bound(e0, m, lam_min, n))
    emit("theory_ll_rate", m=m, measured_mean=f"{np.mean(errs):.3e}",
         bound=f"{bound:.3e}", satisfied=int(np.mean(errs) <= 1.5 * bound))

    # (2) Thm 4.1(a) epoch factor
    tau = 8
    T0 = theory.epoch_len(lam_max, n)
    m = max(T0, n)
    factor = theory.thm41a_factor(rho, tau, kappa)
    errs = []
    for s in range(seeds):
        r = async_rgs_solve(prob.A, prob.b, x0, prob.x_star,
                            key=jax.random.key(10 + s),
                            delay_key=jax.random.key(50 + s),
                            num_iters=m, tau=tau, delay_mode="uniform")
        errs.append(float(r.err_sq[-1].max()))
    emit("theory_thm41a", tau=tau, epoch_iters=m, nu_tau=f"{theory.nu_tau(rho, tau):.4f}",
         factor_bound=f"{factor:.5f}", measured=f"{np.mean(errs)/e0:.5f}",
         satisfied=int(np.mean(errs) / e0 <= factor * 1.2))

    # (3) step-size sweep around beta~ (Sec. 5)
    beta_star = theory.beta_opt(rho, tau)
    m = 4 * n
    rows = []
    for beta in (0.25 * beta_star, 0.5 * beta_star, beta_star,
                 min(1.0, 1.5 * beta_star)):
        r = async_rgs_solve(prob.A, prob.b, x0, prob.x_star,
                            key=jax.random.key(3), delay_key=jax.random.key(4),
                            num_iters=m, tau=tau, beta=float(beta),
                            delay_mode="fixed")
        rows.append((float(beta), float(r.err_sq[-1].max()) / e0))
        emit("theory_stepsize", beta=f"{beta:.3f}",
             nu=f"{theory.nu_tau(rho, tau, float(beta)):.4f}",
             err_ratio=f"{rows[-1][1]:.3e}")
    emit("theory_stepsize", beta_opt=f"{beta_star:.3f}",
         best_measured_beta=f"{min(rows, key=lambda t: t[1])[0]:.3f}")

    # (4) Thm 6.1 inconsistent reads
    rho2 = float(theory.rho2(prob.A))
    beta_i = theory.beta_opt_inconsistent(rho2, tau)
    r = async_rgs_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(5),
                        delay_key=jax.random.key(6), num_iters=8 * n, tau=tau,
                        beta=beta_i, read_model="inconsistent")
    emit("theory_thm61", tau=tau, beta=f"{beta_i:.3f}",
         omega=f"{theory.omega_tau(rho2, tau, beta_i):.4f}",
         err_ratio_8n=f"{float(r.err_sq[-1].max())/e0:.3e}",
         converged=int(float(r.err_sq[-1].max()) < 0.05 * e0))


if __name__ == "__main__":
    run()
