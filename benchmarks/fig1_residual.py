"""Paper Figure 1 analogue: residual of Randomized Gauss-Seidel vs CG as the
iterations progress, on a reference-scenario matrix with multiple RHS
(equal O(nnz) work per RGS sweep / CG iteration).

Honest-reporting note (EXPERIMENTS.md quotes this): on our synthetic
reference-scenario matrices CG leads per sweep — consistent with the
paper's own caveat ("It is not the goal of this section to show that the
suggested algorithm converges faster than ... CG for all, or many,
matrices", Sec. 8).  The paper's wall-clock advantage on its social-media
matrix came from (a) that matrix's spectrum and (b) CG's per-iteration
synchronization cost: 2 blocking all-reduce inner products + 5 multi-RHS
vector ops per iteration, vs ZERO global synchronization inside an RGS
sweep.  We therefore report both the residual trajectories AND the
sync-point accounting that drives the paper's scalability argument."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cg_solve, random_sparse_spd, rgs_solve


def run(n: int = 2048, rhs: int = 8, sweeps: int = 10, seed: int = 0):
    prob = random_sparse_spd(n, row_nnz=16, offdiag=0.97, n_rhs=rhs, seed=seed)
    x0 = jnp.zeros_like(prob.x_star)
    b_norm = float(jnp.linalg.norm(prob.b))

    res = rgs_solve(prob.A, prob.b, x0, prob.x_star, key=jax.random.key(1),
                    num_iters=sweeps * n, record_every=n)
    cg = cg_solve(prob.A, prob.b, x0, prob.x_star, num_iters=sweeps)

    rgs_r = np.linalg.norm(np.asarray(res.resid), axis=1) / b_norm
    cg_r = np.linalg.norm(np.asarray(cg.resid), axis=1) / b_norm
    for s in range(sweeps):
        emit("fig1_residual", sweep=s + 1, rgs=f"{rgs_r[s]:.4e}",
             cg=f"{cg_r[s]:.4e}")
    # the paper's scalability accounting: synchronization points per unit of
    # O(nnz) work (1 sweep == 1 CG iteration) — the quantity that dominates
    # at high processor counts (paper Secs. 1, 8).
    emit("fig1_residual", summary=1, kappa=f"{float(prob.kappa):.1f}",
         rgs_first_sweep=f"{rgs_r[0]:.3e}", cg_first_iter=f"{cg_r[0]:.3e}",
         rgs_wins_early=int(rgs_r[0] < cg_r[0]),
         rgs_syncs_per_sweep=0, cg_syncs_per_iter=2,
         rgs_resid_monotone=int(bool(np.all(np.diff(rgs_r) < 0))),
         cg_resid_monotone=int(bool(np.all(np.diff(cg_r) < 0))))
    return rgs_r, cg_r


if __name__ == "__main__":
    run()
