"""§Perf hillclimb harness for train/serve cells: lower one (arch x shape)
cell on the single-pod mesh with RunConfig overrides, and report the three
roofline terms.  Each invocation is one hypothesis->measure iteration;
EXPERIMENTS.md §Perf quotes the emitted lines.

    python -m benchmarks.train_hillclimb --arch qwen2-1.5b --shape train_4k \\
        --set remat=dots --tag q1_remat_dots
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SCRIPT = textwrap.dedent("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch import dryrun as DR
from repro.configs import get_run_config
from repro.launch.mesh import make_production_mesh

overrides = json.loads(%(overrides)r)
orig = DR.get_run_config
def conv(cur, v):
    if isinstance(cur, bool):
        return str(v).lower() in ("1", "true", "yes")
    return type(cur)(v)
def patched(arch):
    base = orig(arch)
    return base.with_(**{k: conv(getattr(base, k), v)
                         for k, v in overrides.items()})
DR.get_run_config = patched
mesh = make_production_mesh(multi_pod=False)
rec = DR.lower_cell(%(arch)r, %(shape)r, mesh, multi_pod=False, unroll=True)
rec.pop("memory", None)
rec.pop("collectives", None)
print("HILLCLIMB_JSON:" + json.dumps(rec, default=str))
""")


def run(arch: str, shape: str, overrides: dict, tag: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT % dict(arch=arch, shape=shape,
                        overrides=json.dumps(overrides))],
        env=env, capture_output=True, text=True, timeout=3000,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    line = [l for l in out.stdout.splitlines() if l.startswith("HILLCLIMB_JSON:")]
    if out.returncode != 0 or not line:
        emit("train_hillclimb", tag=tag, error=(out.stderr or out.stdout)[-400:])
        return None
    rec = json.loads(line[-1][len("HILLCLIMB_JSON:"):])
    emit("train_hillclimb", tag=tag, arch=arch, shape=shape,
         overrides=json.dumps(overrides).replace(",", ";"),
         t_comp=f"{rec['t_comp_s']:.3e}", t_mem=f"{rec['t_mem_s']:.3e}",
         t_coll=f"{rec['t_coll_s']:.3e}", bottleneck=rec["bottleneck"],
         useful_ratio=f"{rec['useful_flop_ratio']:.3f}",
         roofline_frac=f"{rec['roofline_fraction']:.4f}",
         compile_s=rec["compile_s"])
    return rec


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--set", action="append", default=[],
                    help="key=value RunConfig override (repeatable)")
    ap.add_argument("--tag", default="iter")
    a = ap.parse_args()
    ov = {}
    for kv in a.set:
        k, v = kv.split("=", 1)
        ov[k] = v
    run(a.arch, a.shape, ov, a.tag)
