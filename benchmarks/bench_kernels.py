"""Kernel-layer microbenchmarks.

NOTE: this container executes Pallas in interpret mode on one CPU core, so
wall times here are NOT TPU predictions — the TPU-facing numbers live in the
dry-run roofline (§Roofline).  What this benchmark DOES establish on CPU:

* the blocked layout's arithmetic-intensity advantage, reported as modeled
  FLOPs-per-HBM-byte for bbmv (contiguous block-banded) vs spmv_ell
  (gather-based ELL) at equal nnz — the hardware-adaptation argument of
  DESIGN.md quantified structurally;
* the CSR matvec overhaul (PR 5): the sliced-ELL gather-accumulate kernel
  (``csr_sliced``, the ``CsrOp.matvec`` default) vs the retired one-hot
  segment-sum layout (``csr_segsum``), plus both on a half-empty matrix
  where the prefetch-predicated variant skips empty panels
  (``csr_skip_empty``);
* fused sweep kernels vs the per-step scan engine (the ``sweeps``
  section): whole GS/RK inner loops in one Pallas launch for the
  banded/CSR/ELL formats, parity-checked against the scan iterates;
* correctness spot checks: every layout row carries a ``check`` value
  (max abs deviation from the dense oracle) so a wrong kernel cannot hide
  behind a fast wall time.

Timing is min-of-``--repeats`` (one-sided noise), so BENCH_kernels.json
deltas between PRs are trustworthy.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--n 1024] [--repeats 3]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed, write_json
from repro.core import (BlockBandedOp, CsrOp, EllOp, block_banded_spd,
                        random_sparse_spd)
from repro.core.engine import solve_sequential
from repro.kernels import ops, ref
# The roofline terms come from repro.roofline — the same hardware model
# solver_roofline.py's dry-run analysis reads — so the two reports cannot
# drift apart.  The peaks are the TPU-v5e model; on CPU interpret mode the
# fractions are honest near-zeros and the provenance stamp says why.
from repro.roofline import HBM_BW, PEAK_FLOPS


def run(n: int = 1024, block: int = 128, bands: int = 1, k: int = 64,
        repeats: int = 3, storage_dtype=None, tuned: bool = False):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=0)
    bop = BlockBandedOp.from_dense(prob.A, block=block, bands=bands,
                                   storage_dtype=storage_dtype)
    width = int((np.asarray(prob.A) != 0).sum(1).max())
    width = -(-width // 8) * 8
    eop = EllOp.from_dense(prob.A, width=width, storage_dtype=storage_dtype)
    cop = CsrOp.from_dense(prob.A, storage_dtype=storage_dtype)
    # oracle convention (tests/test_operators.py): low-precision storage is
    # checked against the ROUNDED dense matrix, so `check` stays at kernel
    # roundoff for every storage dtype.
    A_ref = (prob.A if storage_dtype is None
             else prob.A.astype(storage_dtype).astype(jnp.float32))
    y_d = A_ref @ prob.x_star

    # Modeled arithmetic intensity on the A-stream (FLOPs per byte of matrix
    # read): blocked tiles amortize k RHS columns per element; ELL/CSR pay
    # the same matrix bytes plus a gathered row of x per nonzero
    # (uncoalesced).  csr_segsum additionally streams a row id per slot and
    # burns a dense one-hot MXU matmul per panel; csr_sliced (the matvec
    # default since PR 5) drops both — per-row windows make the segment sum
    # free — at the cost of per-row (not per-panel) padding.
    # Byte models are derived from the dtypes actually stored, so
    # ``--storage-dtype bfloat16`` (2-byte values, int16 gather indices)
    # shows up directly in the modeled AI; the iterate/RHS stream stays f32.
    ev, ec = eop.vals.dtype.itemsize, eop.cols.dtype.itemsize
    cv, ci = cop.data.dtype.itemsize, cop.indices.dtype.itemsize
    bbmv_bytes = bop.nnz_cost() * bop.A_bands.dtype.itemsize
    bbmv_flops = 2 * bop.nnz_cost() * k
    ell_bytes = eop.nnz_cost() * (ev + ec) + eop.nnz_cost() * k * 4
    ell_flops = 2 * eop.nnz_cost() * k
    csr_slots = cop.panel_width * (-(-n // cop.rows_per_panel))
    csr_bytes = csr_slots * (cv + ci + 4) + csr_slots * k * 4
    csr_flops = 2 * cop.nnz_cost() * k
    sl_slots = int(np.prod(cop.sliced_rows()[0].shape))
    sliced_bytes = sl_slots * (cv + ci) + sl_slots * k * 4
    sliced_flops = 2 * cop.nnz_cost() * k

    # Empty-panel-skip variant (scalar-prefetched per-panel nnz counts):
    # on a "patchy" matrix — half the row panels zeroed, the shape a
    # norm-balanced partition of a banded-structure matrix produces — the
    # predicated grid skips the gather + contraction of every empty panel,
    # so its modeled A-stream bytes shrink by the empty fraction.
    A_patchy = np.array(prob.A)
    R = cop.rows_per_panel
    for p in range(0, n // R, 2):
        A_patchy[p * R:(p + 1) * R] = 0.0
    Ap = jnp.asarray(A_patchy)
    pop = CsrOp.from_dense(Ap, storage_dtype=storage_dtype)
    pn = np.asarray(pop.panel_nnz())
    empty_frac = float((pn == 0).mean())
    x_p = prob.x_star
    y_p = (Ap if storage_dtype is None
           else Ap.astype(storage_dtype).astype(jnp.float32)) @ x_p
    pv, pi = pop.data.dtype.itemsize, pop.indices.dtype.itemsize
    patchy_slots = pop.panel_width * pn.size
    patchy_bytes = patchy_slots * (pv + pi + 4) + patchy_slots * k * 4
    patchy_flops = 2 * pop.nnz_cost() * k
    skip_slots = (int(pop.sliced_rows()[0].shape[1]) * pop.rows_per_panel
                  * int((pn > 0).sum()))
    skip_bytes = (skip_slots * (pv + pi) + skip_slots * k * 4 + pn.size * 4)
    skip_flops = 2 * pop.nnz_cost() * k

    # Every layout row: modeled AI, min-of-N wall time, a check value
    # against the dense oracle (uniform — a fast-but-wrong kernel fails
    # loudly here and in the CI smoke job), AND the roofline view of the
    # same byte/FLOP models: achieved GB/s on the modeled traffic plus the
    # fraction of the roofline-predicted time actually achieved
    # (max(bytes/HBM_BW, flops/PEAK_FLOPS) / wall — 1.0 means the kernel
    # runs at the hardware model's limiting term).
    layouts = {}
    for name, nbytes, flops, want, fn in (
        ("block_banded", bbmv_bytes, bbmv_flops, y_d,
         lambda: bop.matvec(prob.x_star)),
        ("ell_gather", ell_bytes, ell_flops, y_d,
         lambda: eop.matvec(prob.x_star)),
        ("csr_segsum", csr_bytes, csr_flops, y_d,
         lambda: cop.matvec_segsum(prob.x_star)),
        ("csr_sliced", sliced_bytes, sliced_flops, y_d,
         lambda: cop.matvec(prob.x_star, skip_empty=False)),
        ("csr_segsum_patchy", patchy_bytes, patchy_flops, y_p,
         lambda: pop.matvec_segsum(x_p)),
        ("csr_skip_empty", skip_bytes, skip_flops, y_p,
         lambda: pop.matvec(x_p, skip_empty=True)),
    ):
        ai = flops / nbytes
        check = float(jnp.abs(fn() - want).max())
        wall = timed(fn, iters=repeats, stat="min")
        gbps = nbytes / wall / 1e9
        t_roof = max(nbytes / HBM_BW, flops / PEAK_FLOPS)
        frac = t_roof / wall
        emit("bench_kernels", layout=name, ai_flops_per_byte=f"{ai:.1f}",
             wall_us=f"{wall*1e6:.0f}", gbps=f"{gbps:.2f}",
             roofline_frac=f"{frac:.4f}", check=f"{check:.2e}")
        layouts[name] = {"ai_flops_per_byte": ai, "wall_us": wall * 1e6,
                         "model_bytes": int(nbytes), "model_flops": int(flops),
                         "achieved_gbps": gbps, "roofline_frac": frac,
                         "check": check}
    layouts["csr_skip_empty"]["empty_panel_frac"] = empty_frac
    emit("bench_kernels", empty_panel_frac=f"{empty_frac:.2f}")
    tuned_rows = (run_tuned(layouts, cop, pop, prob.x_star, x_p,
                            repeats=repeats)
                  if tuned else None)

    # fused block-GS sweep kernel vs oracle (dense layout)
    nb = bop.nb
    blocks = jax.random.randint(jax.random.key(1), (nb,), 0, nb)
    x0 = jnp.zeros_like(prob.b)
    out = ops.block_gs_sweep(prob.A, prob.b, x0, blocks, block=block, beta=1.0)
    want = ref.block_gs_sweep_ref(prob.A, prob.b, x0, blocks, block=block,
                                  beta=1.0)
    check_block_gs = float(jnp.abs(out - want).max())
    sweep_wall = timed(lambda: ops.block_gs_sweep(prob.A, prob.b, x0, blocks,
                                                  block=block),
                       iters=repeats, stat="min")
    emit("bench_kernels", check_block_gs=f"{check_block_gs:.2e}",
         sweep_wall_us=f"{sweep_wall*1e6:.0f}")
    payload = {
        "n": n, "block": block, "bands": bands, "k": k, "repeats": repeats,
        "storage_dtype": storage_dtype,
        "check_block_gs": check_block_gs,
        "layouts": layouts, "sweep_wall_us": sweep_wall * 1e6,
        "sweeps": run_sweeps(repeats=repeats, n=min(n, 512)),
        "precision": run_precision(repeats=repeats, n=min(n, 512)),
    }
    if tuned_rows is not None:
        payload["tuned"] = tuned_rows
    return payload


#: layout row -> the variant family its operator's tuned dispatch chooses
#: among (None = the entry point has a single pinned kernel, so the tuned
#: path IS the default)
_TUNED_FAMILIES = {
    "csr_segsum": "csr_dense_panels",
    "csr_sliced": "csr_dense_panels",
    "csr_segsum_patchy": "csr_patchy",
    "csr_skip_empty": "csr_patchy",
    "block_banded": None,
    "ell_gather": None,
}


def run_tuned(layouts, cop, pop, x_d, x_p, *, repeats: int = 3):
    """The ``--tuned`` section: time the table-driven dispatch against the
    best hardcoded default on every recorded layout row.

    The tuned path is the operator's bare ``matvec`` — whatever variant
    the active ``TUNE_<backend>.json`` picks for this shape bucket — and
    ``best_default_us`` is the fastest forced-variant row of the same
    operator (for single-variant rows the tuned path is trivially the
    default).  ``ok`` grants a 1.25x noise slack: the tuned path launches
    one of the measured variants, so equality up to timer noise is the
    expected outcome and a miss means the table picked a loser."""
    from repro.tune import runtime as tune_runtime
    table = tune_runtime.active_table()
    tuned_fns = {"csr_dense_panels": (cop, x_d), "csr_patchy": (pop, x_p)}
    family_best = {
        fam: min(layouts[r]["wall_us"] for r, f in _TUNED_FAMILIES.items()
                 if f == fam)
        for fam in tuned_fns}
    out = {"table_loaded": table is not None,
           "table_backend": getattr(table, "backend", None)}
    for name, fam in _TUNED_FAMILIES.items():
        if fam is None:
            op_wall = layouts[name]["wall_us"]
            row = {"tuned_us": op_wall, "best_default_us": op_wall,
                   "variant": "single", "ok": True}
        else:
            op, x = tuned_fns[fam]
            wall = timed(lambda: op.matvec(x), iters=repeats,
                         stat="min") * 1e6
            best = family_best[fam]
            row = {"tuned_us": wall, "best_default_us": best,
                   "variant": tune_runtime.matvec_variant(op) or "(auto)",
                   "ok": wall <= best * 1.25}
        out[name] = row
        emit("bench_kernels_tuned", layout=name,
             tuned_us=f"{row['tuned_us']:.0f}",
             best_default_us=f"{row['best_default_us']:.0f}",
             variant=row["variant"], ok=row["ok"])
    return out


def run_precision(n: int = 512, k: int = 8, row_nnz: int = 16,
                  steps: int = 256, repeats: int = 3, seed: int = 0):
    """Per-dtype bytes-per-iteration rows for the CSR/ELL matvec + sweep.

    The quantity ``storage_dtype`` controls is the coefficient stream the
    kernels read each iteration — values plus gather indices (bf16 storage
    also narrows ELL/CSR column indices to int16 when ``n`` fits), while the
    iterate, RHS and accumulation stay f32.  For each format the row records
    the modeled matvec A-stream bytes, the per-row sweep-step bytes, the
    measured wall time, and a check against the ROUNDED dense oracle; the
    bf16 row adds the reduction vs f32 (the acceptance number: >= 40%).
    """
    prob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=k, seed=seed)
    width = int((np.asarray(prob.A) != 0).sum(1).max())
    makers = {
        "csr": lambda dt: CsrOp.from_dense(prob.A, storage_dtype=dt),
        "ell": lambda dt: EllOp.from_dense(prob.A, width=width,
                                           storage_dtype=dt),
    }
    out = {"n": n, "k": k, "row_nnz": row_nnz, "steps": steps}
    for fmt, make in makers.items():
        rows = {}
        for dt in ("float32", "bfloat16"):
            op = make(dt)
            if fmt == "csr":
                vsz = op.data.dtype.itemsize
                isz = op.indices.dtype.itemsize
                slots = int(np.prod(op.sliced_rows()[0].shape))
                row_slots = op.row_cap
            else:
                vsz = op.vals.dtype.itemsize
                isz = op.cols.dtype.itemsize
                slots = int(op.nnz_cost())
                row_slots = op.vals.shape[1]
            matvec_bytes = slots * (vsz + isz)
            sweep_step_bytes = row_slots * (vsz + isz)
            A_ref = prob.A.astype(dt).astype(jnp.float32)
            y_ref = A_ref @ prob.x_star
            check = float(jnp.abs(op.matvec(prob.x_star) - y_ref).max())
            mv_wall = timed(lambda: op.matvec(prob.x_star),
                            iters=repeats, stat="min")
            x0 = jnp.zeros_like(prob.b)
            sweep_wall = timed(
                lambda: solve_sequential(op, prob.b, x0, prob.x_star,
                                         action="gs", key=jax.random.key(2),
                                         num_iters=steps, record_every=steps,
                                         fused=True).x,
                iters=repeats, stat="min")
            rows[dt] = {"matvec_bytes": matvec_bytes,
                        "sweep_step_bytes": sweep_step_bytes,
                        "matvec_wall_us": mv_wall * 1e6,
                        "sweep_wall_us": sweep_wall * 1e6,
                        "vals_dtype": str(op.data.dtype if fmt == "csr"
                                          else op.vals.dtype),
                        "idx_dtype": str(op.indices.dtype if fmt == "csr"
                                         else op.cols.dtype),
                        "check": check}
            emit("bench_kernels_precision", fmt=fmt, dtype=dt,
                 matvec_bytes=matvec_bytes,
                 sweep_step_bytes=sweep_step_bytes,
                 matvec_us=f"{mv_wall*1e6:.0f}",
                 sweep_us=f"{sweep_wall*1e6:.0f}", check=f"{check:.2e}")
        f32, bf16 = rows["float32"], rows["bfloat16"]
        for key_ in ("matvec_bytes", "sweep_step_bytes"):
            red = 1.0 - bf16[key_] / f32[key_]
            bf16[f"{key_}_reduction_vs_f32"] = red
            emit("bench_kernels_precision", fmt=fmt,
                 **{f"{key_}_reduction": f"{red:.2f}"})
        out[fmt] = rows
    return out


def run_sweeps(n: int = 512, block: int = 64, bands: int = 1, k: int = 8,
               row_nnz: int = 16, steps: int = 256, repeats: int = 3,
               seed: int = 0):
    """Fused sweep kernels vs the per-step scan engine (PR 5 tentpole).

    Times one full inner loop (``steps`` sequential row/block updates +
    one metric record) through ``solve_sequential`` both ways for the
    banded GS action and the CSR/ELL GS and RK actions, and records the
    parity deviation (``check``; exact 0 expected for GS — identical
    update order — and roundoff for RK).  CPU-interpret caveat applies to
    the absolute numbers; what the section pins is the parity and the
    per-PR trajectory of both paths.
    """
    bprob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=seed)
    bop = BlockBandedOp.from_dense(bprob.A, block=block, bands=bands)
    sprob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=k, seed=seed + 1)
    ewidth = int((np.asarray(sprob.A) != 0).sum(1).max())
    cases = {
        "banded_gs": (bop, bprob, "gs"),
        "csr_gs": (CsrOp.from_dense(sprob.A), sprob, "gs"),
        "csr_rk": (CsrOp.from_dense(sprob.A), sprob, "rk"),
        "ell_gs": (EllOp.from_dense(sprob.A, width=ewidth), sprob, "gs"),
        "ell_rk": (EllOp.from_dense(sprob.A, width=ewidth), sprob, "rk"),
    }
    out = {"n": n, "block": block, "bands": bands, "k": k, "steps": steps}
    for name, (op, prob, action) in cases.items():
        x0 = jnp.zeros_like(prob.b)
        kw = dict(action=action, key=jax.random.key(2), num_iters=steps,
                  record_every=steps)

        def scan():
            return solve_sequential(op, prob.b, x0, prob.x_star, **kw).x

        def fused():
            return solve_sequential(op, prob.b, x0, prob.x_star, fused=True,
                                    **kw).x

        check = float(jnp.abs(scan() - fused()).max())
        scan_wall = timed(scan, iters=repeats, stat="min")
        fused_wall = timed(fused, iters=repeats, stat="min")
        emit("bench_kernels_sweeps", case=name, steps=steps,
             scan_us=f"{scan_wall*1e6:.0f}", fused_us=f"{fused_wall*1e6:.0f}",
             check=f"{check:.2e}")
        out[name] = {"scan_us": scan_wall * 1e6,
                     "fused_us": fused_wall * 1e6, "check": check}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--bands", type=int, default=1)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repetitions; wall times are min-of-N")
    ap.add_argument("--storage-dtype", choices=("float32", "bfloat16"),
                    default=None,
                    help="coefficient storage precision for the layout "
                         "section's operators (checks run against the "
                         "rounded dense oracle); the per-dtype `precision` "
                         "section always reports both")
    ap.add_argument("--no-write", action="store_true",
                    help="print records without persisting BENCH_kernels"
                         ".json (the CI smoke job runs a tiny shape)")
    ap.add_argument("--tuned", action="store_true",
                    help="also time the tuning-table-driven dispatch "
                         "(repro.tune) against the best hardcoded default "
                         "on every layout row (the `tuned` section)")
    args = ap.parse_args(argv)
    payload = run(n=args.n, block=args.block, bands=args.bands, k=args.k,
                  repeats=args.repeats, storage_dtype=args.storage_dtype,
                  tuned=args.tuned)
    if not args.no_write:
        write_json("kernels", payload)
    return payload


if __name__ == "__main__":
    main()
