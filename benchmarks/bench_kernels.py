"""Kernel-layer microbenchmarks.

NOTE: this container executes Pallas in interpret mode on one CPU core, so
wall times here are NOT TPU predictions — the TPU-facing numbers live in the
dry-run roofline (§Roofline).  What this benchmark DOES establish on CPU:

* the blocked layout's arithmetic-intensity advantage, reported as modeled
  FLOPs-per-HBM-byte for bbmv (contiguous block-banded) vs spmv_ell
  (gather-based ELL) at equal nnz — the hardware-adaptation argument of
  DESIGN.md quantified structurally;
* correctness-at-scale spot checks for both layouts and the fused
  block-GS sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import BlockBandedOp, EllOp, block_banded_spd
from repro.kernels import ops, ref


def run(n: int = 1024, block: int = 128, bands: int = 1, k: int = 64):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=0)
    bop = BlockBandedOp.from_dense(prob.A, block=block, bands=bands)
    width = int((np.asarray(prob.A) != 0).sum(1).max())
    width = -(-width // 8) * 8
    eop = EllOp.from_dense(prob.A, width=width)

    # operator-layer matvecs (Pallas kernels behind; interpret mode on CPU)
    y_b = bop.matvec(prob.x_star)
    y_e = eop.matvec(prob.x_star)
    y_d = prob.A @ prob.x_star
    emit("bench_kernels", check_bbmv=f"{float(jnp.abs(y_b-y_d).max()):.2e}",
         check_ell=f"{float(jnp.abs(y_e-y_d).max()):.2e}")

    # Modeled arithmetic intensity on the A-stream (FLOPs per byte of matrix
    # read): blocked tiles amortize k RHS columns per element; ELL pays the
    # same matrix bytes plus a gathered row of x per nonzero (uncoalesced).
    bbmv_bytes = bop.nnz_cost() * 4
    bbmv_flops = 2 * bop.nnz_cost() * k
    ell_bytes = eop.nnz_cost() * (4 + 4) + eop.nnz_cost() * k * 4
    ell_flops = 2 * eop.nnz_cost() * k
    emit("bench_kernels", layout="block_banded",
         ai_flops_per_byte=f"{bbmv_flops/bbmv_bytes:.1f}",
         wall_us=f"{timed(lambda: bop.matvec(prob.x_star))*1e6:.0f}")
    emit("bench_kernels", layout="ell_gather",
         ai_flops_per_byte=f"{ell_flops/ell_bytes:.1f}",
         wall_us=f"{timed(lambda: eop.matvec(prob.x_star))*1e6:.0f}")

    # fused sweep kernel vs oracle
    nb = bop.nb
    blocks = jax.random.randint(jax.random.key(1), (nb,), 0, nb)
    x0 = jnp.zeros_like(prob.b)
    out = ops.block_gs_sweep(prob.A, prob.b, x0, blocks, block=block, beta=1.0)
    want = ref.block_gs_sweep_ref(prob.A, prob.b, x0, blocks, block=block, beta=1.0)
    emit("bench_kernels", check_block_gs=f"{float(jnp.abs(out-want).max()):.2e}",
         sweep_wall_us=f"{timed(lambda: ops.block_gs_sweep(prob.A, prob.b, x0, blocks, block=block))*1e6:.0f}")


if __name__ == "__main__":
    run()
