"""Kernel-layer microbenchmarks.

NOTE: this container executes Pallas in interpret mode on one CPU core, so
wall times here are NOT TPU predictions — the TPU-facing numbers live in the
dry-run roofline (§Roofline).  What this benchmark DOES establish on CPU:

* the blocked layout's arithmetic-intensity advantage, reported as modeled
  FLOPs-per-HBM-byte for bbmv (contiguous block-banded) vs spmv_ell
  (gather-based ELL) at equal nnz — the hardware-adaptation argument of
  DESIGN.md quantified structurally;
* correctness-at-scale spot checks for both layouts and the fused
  block-GS sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed, write_json
from repro.core import BlockBandedOp, CsrOp, EllOp, block_banded_spd
from repro.kernels import ops, ref


def run(n: int = 1024, block: int = 128, bands: int = 1, k: int = 64):
    prob = block_banded_spd(n, block=block, bands=bands, n_rhs=k, seed=0)
    bop = BlockBandedOp.from_dense(prob.A, block=block, bands=bands)
    width = int((np.asarray(prob.A) != 0).sum(1).max())
    width = -(-width // 8) * 8
    eop = EllOp.from_dense(prob.A, width=width)
    cop = CsrOp.from_dense(prob.A)

    # operator-layer matvecs (Pallas kernels behind; interpret mode on CPU)
    y_b = bop.matvec(prob.x_star)
    y_e = eop.matvec(prob.x_star)
    y_c = cop.matvec(prob.x_star)
    y_d = prob.A @ prob.x_star
    check_bbmv = float(jnp.abs(y_b - y_d).max())
    check_ell = float(jnp.abs(y_e - y_d).max())
    check_csr = float(jnp.abs(y_c - y_d).max())
    emit("bench_kernels", check_bbmv=f"{check_bbmv:.2e}",
         check_ell=f"{check_ell:.2e}", check_csr=f"{check_csr:.2e}")

    # Modeled arithmetic intensity on the A-stream (FLOPs per byte of matrix
    # read): blocked tiles amortize k RHS columns per element; ELL/CSR pay
    # the same matrix bytes plus a gathered row of x per nonzero
    # (uncoalesced); CSR additionally streams a row id per slot but its
    # segment sum runs as a one-hot MXU matmul (kernels/spmv_csr.py).
    bbmv_bytes = bop.nnz_cost() * 4
    bbmv_flops = 2 * bop.nnz_cost() * k
    ell_bytes = eop.nnz_cost() * (4 + 4) + eop.nnz_cost() * k * 4
    ell_flops = 2 * eop.nnz_cost() * k
    csr_slots = cop.panel_width * (-(-n // cop.rows_per_panel))
    csr_bytes = csr_slots * (4 + 4 + 4) + csr_slots * k * 4
    csr_flops = 2 * cop.nnz_cost() * k

    # Empty-panel-skip variant (scalar-prefetched per-panel nnz counts):
    # on a "patchy" matrix — half the row panels zeroed, the shape a
    # norm-balanced partition of a banded-structure matrix produces — the
    # predicated grid skips the gather + one-hot matmul of every empty
    # panel, so its modeled A-stream bytes shrink by the empty fraction.
    A_patchy = np.array(prob.A)
    R = cop.rows_per_panel
    for p in range(0, n // R, 2):
        A_patchy[p * R:(p + 1) * R] = 0.0
    pop = CsrOp.from_dense(jnp.asarray(A_patchy))
    pn = np.asarray(pop.panel_nnz())
    empty_frac = float((pn == 0).mean())
    x_p = prob.x_star
    check_skip = float(jnp.abs(pop.matvec(x_p, skip_empty=True)
                               - jnp.asarray(A_patchy) @ x_p).max())
    patchy_slots = pop.panel_width * pn.size
    patchy_bytes = patchy_slots * (4 + 4 + 4) + patchy_slots * k * 4
    patchy_flops = 2 * pop.nnz_cost() * k
    skip_slots = pop.panel_width * int((pn > 0).sum())
    skip_bytes = (skip_slots * (4 + 4 + 4) + skip_slots * k * 4
                  + pn.size * 4)
    skip_flops = 2 * pop.nnz_cost() * k

    layouts = {}
    for name, ai, fn in (
        ("block_banded", bbmv_flops / bbmv_bytes,
         lambda: bop.matvec(prob.x_star)),
        ("ell_gather", ell_flops / ell_bytes,
         lambda: eop.matvec(prob.x_star)),
        ("csr_segsum", csr_flops / csr_bytes,
         lambda: cop.matvec(prob.x_star)),
        ("csr_segsum_patchy", patchy_flops / patchy_bytes,
         lambda: pop.matvec(x_p)),
        ("csr_skip_empty", skip_flops / skip_bytes,
         lambda: pop.matvec(x_p, skip_empty=True)),
    ):
        wall = timed(fn)
        emit("bench_kernels", layout=name, ai_flops_per_byte=f"{ai:.1f}",
             wall_us=f"{wall*1e6:.0f}")
        layouts[name] = {"ai_flops_per_byte": ai, "wall_us": wall * 1e6}
    layouts["csr_skip_empty"].update(empty_panel_frac=empty_frac,
                                     check=check_skip)
    emit("bench_kernels", empty_panel_frac=f"{empty_frac:.2f}",
         check_skip=f"{check_skip:.2e}")

    # fused sweep kernel vs oracle
    nb = bop.nb
    blocks = jax.random.randint(jax.random.key(1), (nb,), 0, nb)
    x0 = jnp.zeros_like(prob.b)
    out = ops.block_gs_sweep(prob.A, prob.b, x0, blocks, block=block, beta=1.0)
    want = ref.block_gs_sweep_ref(prob.A, prob.b, x0, blocks, block=block, beta=1.0)
    check_block_gs = float(jnp.abs(out - want).max())
    sweep_wall = timed(lambda: ops.block_gs_sweep(prob.A, prob.b, x0, blocks,
                                                  block=block))
    emit("bench_kernels", check_block_gs=f"{check_block_gs:.2e}",
         sweep_wall_us=f"{sweep_wall*1e6:.0f}")
    return {
        "n": n, "block": block, "bands": bands, "k": k,
        "check_bbmv": check_bbmv, "check_ell": check_ell,
        "check_csr": check_csr, "check_block_gs": check_block_gs,
        "layouts": layouts, "sweep_wall_us": sweep_wall * 1e6,
    }


if __name__ == "__main__":
    write_json("kernels", run())
