"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.  ``python -m benchmarks.make_tables > /tmp/tables.md``
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TiB"


def fmt_t(t):
    return f"{t*1e3:.2f}ms" if t < 1 else f"{t:.2f}s"


def load(out_dir="experiments/dryrun", probe_dir="experiments/probe"):
    by_mesh = defaultdict(dict)
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        d = json.load(open(f))
        by_mesh[d["mesh"]][(d["arch"], d["shape"])] = d
    # fallback: rolled-scan probe artifacts for cells whose unrolled compile
    # had not landed yet (flagged "(rolled)" — per-layer FLOPs undercounted)
    for f in sorted(glob.glob(os.path.join(probe_dir, "*.json"))):
        d = json.load(open(f))
        key = (d["arch"], d["shape"])
        if key not in by_mesh[d["mesh"]]:
            d["cost_basis"] = "rolled"
            by_mesh[d["mesh"]][key] = d
    return by_mesh


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(recs):
    print("| arch | shape | status | params (total/active) | bytes/dev "
          "(args+temp) | FLOPs/dev | wire bytes/dev | compile |")
    print("|---|---|---|---|---|---|---|---|")
    archs = sorted({a for a, _ in recs})
    for a in archs:
        for s in SHAPE_ORDER:
            d = recs.get((a, s))
            if d is None:
                continue
            if d.get("skip"):
                print(f"| {a} | {s} | SKIP (full attention) | | | | | |")
                continue
            if not d.get("ok"):
                print(f"| {a} | {s} | **FAIL** | | | | | |")
                continue
            mem = d.get("memory", {})
            args = mem.get("argument_size_in_bytes")
            temp = mem.get("temp_size_in_bytes")
            tot = f"{d['params_total']/1e9:.2f}B/{d['params_active']/1e9:.2f}B"
            s = s + (" ⁽ʳ⁾" if d.get("cost_basis") == "rolled" else "")
            print(f"| {a} | {s} | PASS | {tot} "
                  f"| {fmt_bytes(args)}+{fmt_bytes(temp)} "
                  f"| {d['flops_per_device']:.2e} "
                  f"| {d['collective_wire_bytes_per_device']:.2e} "
                  f"| {d['compile_s']:.0f}s |")


def roofline_table(recs):
    print("| arch | shape | T_comp | T_mem | T_coll | bottleneck "
          "| useful/HLO FLOPs | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    archs = sorted({a for a, _ in recs})
    for a in archs:
        for s in SHAPE_ORDER:
            d = recs.get((a, s))
            if d is None or d.get("skip") or not d.get("ok"):
                continue
            s = s + (" ⁽ʳ⁾" if d.get("cost_basis") == "rolled" else "")
            print(f"| {a} | {s} | {fmt_t(d['t_comp_s'])} | {fmt_t(d['t_mem_s'])} "
                  f"| {fmt_t(d['t_coll_s'])} | {d['bottleneck']} "
                  f"| {d['useful_flop_ratio']:.3f} "
                  f"| {d['roofline_fraction']:.4f} |")


def main():
    by_mesh = load()
    for mesh in ("16x16", "2x16x16"):
        recs = by_mesh.get(mesh, {})
        n_ok = sum(1 for d in recs.values() if d.get("ok"))
        n_skip = sum(1 for d in recs.values() if d.get("skip"))
        n_fail = len(recs) - n_ok - n_skip
        print(f"\n## Dry-run — mesh {mesh} "
              f"({n_ok} pass / {n_skip} skip / {n_fail} fail)\n")
        dryrun_table(recs)
    print("\n## Roofline — single pod (16x16, scan unrolled for cost "
          "fidelity)\n")
    roofline_table(by_mesh.get("16x16", {}))


if __name__ == "__main__":
    main()
