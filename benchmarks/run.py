"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (+ the roofline aggregation).  Output is
CSV-ish lines ``benchmark,key=value,...`` — EXPERIMENTS.md quotes them.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    ap.add_argument("--fast", action="store_true", help="smaller problem sizes")
    args = ap.parse_args()

    from benchmarks import (bench_kernels, fig1_residual, fig2_scaling,
                            fig3_async_penalty, theory_validation)

    jobs = [
        ("fig1_residual", lambda: fig1_residual.run(
            n=1024 if args.fast else 2048)),
        ("fig2_scaling", lambda: fig2_scaling.run(
            n=512 if args.fast else 1024, workers=(1, 2, 4) if args.fast
            else (1, 2, 4, 8))),
        ("fig3_async_penalty", lambda: fig3_async_penalty.run(
            n=512 if args.fast else 1024,
            taus=(4, 16) if args.fast else (4, 16, 64),
            trials=3 if args.fast else 5)),
        ("theory_validation", lambda: theory_validation.run(
            n=256 if args.fast else 512, seeds=4 if args.fast else 8)),
        ("bench_kernels", lambda: bench_kernels.run(
            n=512 if args.fast else 1024)),
    ]
    for name, fn in jobs:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running
            print(f"{name},error={type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
