"""Least-squares launcher — the paper's Sec. 7 algorithm as a CLI, on the
unified API.

``python -m repro.launch.lsq --m 4096 --n 512 --rhs 8 --workers 8 --sweeps 6``
builds an overdetermined regression system and solves it four ways through
``repro.core.solve(problem, schedule=...)``:
(a) sequential randomized Kaczmarz on the rows of A (no normal equations),
(b) the bounded-delay asynchronous variant with the theory step size
    (``Schedule(tau=...)`` routes to the engine's ring-buffer simulator),
(c) the distributed variant (shard_map over row slabs),
(d) CG on the normal equations A^T A x = A^T b — the baseline that squares
the condition number and pays two blocking all-reduces per iteration.

Work accounting: one RK "sweep" = m row updates = O(mn) flops, the same as
one CG-on-normal-equations iteration (two A matvecs), so per-sweep residual
comparisons are equal-work comparisons.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (Schedule, cg_solve, random_lsq, random_sparse_lsq,
                        solve, theory, to_unit_diagonal)
from repro.core.engine import (COMPRESS_MODES, PARTITIONS, scheduled_tau,
                               supported_syncs)
from repro.core.operators import STORAGE_DTYPES
from repro.launch.mesh import make_host_mesh
from repro.launch.solve import add_fused_flag

#: operator class names this CLI can build (--format dense/csr); the
#: --rk-sync choices are derived from the dispatch table narrowed to these
_CLI_FORMATS = ("DenseOp", "CsrOp")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=4096)
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--rhs", type=int, default=8)
    ap.add_argument("--noise", type=float, default=0.01)
    ap.add_argument("--col-scale", type=float, default=0.5,
                    help="exponential column-scale skew (0 = isotropic)")
    ap.add_argument("--format", choices=("dense", "csr"), default="dense",
                    help="operator format; csr additionally switches the "
                         "design to the sparse reference scenario and the "
                         "distributed pass to per-worker local sampling")
    ap.add_argument("--row-nnz", type=int, default=16,
                    help="nonzeros per row of the sparse design "
                         "(--format csr)")
    ap.add_argument("--sweeps", type=int, default=6)
    ap.add_argument("--tau", type=int, default=32,
                    help="delay bound for the async simulator")
    ap.add_argument("--rk-sync",
                    choices=("auto", *supported_syncs("rk", _CLI_FORMATS)),
                    default="auto",
                    help="distributed RK delta sync: a2a = two-phase "
                         "exchange over the column-slab neighbor graph "
                         "(csr format; bitwise-identical to psum, falls "
                         "back when the graph is dense)")
    ap.add_argument("--partition", choices=PARTITIONS,
                    default="contiguous",
                    help="distributed slab assignment: 'balanced' bin-packs "
                         "rows by norm mass and nnz into the P slabs via a "
                         "row permutation (csr format), restoring the "
                         "global Strohmer-Vershynin row law under "
                         "per-worker local sampling")
    add_fused_flag(ap, "csr format: the whole record chunk in one "
                       "launch, iterate VMEM-resident")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered delta sync for the distributed "
                         "pass: install round r-1's deltas while sweeping "
                         "round r (csr format; dense falls back to lockstep "
                         "with a warning), at the cost of one extra round "
                         "of scheduled staleness")
    ap.add_argument("--storage-dtype", choices=STORAGE_DTYPES,
                    default=None,
                    help="precision the operator's coefficients are stored "
                         "in (row norms, iterate and accumulation stay "
                         "f32); default keeps the input dtype bitwise")
    ap.add_argument("--compress", choices=COMPRESS_MODES,
                    default="none",
                    help="wire format of the distributed RK delta sync "
                         "(csr format, psum wire; a2a falls back to psum "
                         "with a warning under compression)")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--local-steps", type=int, default=0,
                    help="updates between synchronizations (0 -> m/workers)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.format != "csr":
        if args.rk_sync == "a2a":
            ap.error("--rk-sync a2a needs --format csr")
        if args.partition == "balanced":
            ap.error("--partition balanced needs --format csr")
        if args.compress != "none":
            ap.error("--compress needs --format csr (the dense delta psum "
                     "has no compressed wire)")

    if args.format == "csr":
        prob = random_sparse_lsq(args.m, args.n, row_nnz=args.row_nnz,
                                 n_rhs=args.rhs, noise=args.noise,
                                 seed=args.seed)
    else:
        prob = random_lsq(args.m, args.n, n_rhs=args.rhs, noise=args.noise,
                          col_scale=args.col_scale, seed=args.seed)
    m, n = prob.shape
    bn = float(jnp.linalg.norm(prob.b))
    # residual at the LSQ optimum: the floor every solver is chasing
    floor = float(jnp.linalg.norm(prob.b - prob.A @ prob.x_star)) / bn
    print(f"[lsq] m={m} n={n} rhs={args.rhs} kappa(A)={float(prob.kappa):.1f} "
          f"kappa(A^T A)={float(prob.kappa)**2:.1f} optimum relresid={floor:.3e}")

    iters = args.sweeps * m
    t0 = time.time()
    res = solve(prob, key=jax.random.key(1), format=args.format,
                storage_dtype=args.storage_dtype,
                schedule=Schedule(num_iters=iters, record_every=m,
                                  fused=args.fused))
    jax.block_until_ready(res.x)
    print(f"  seq RK     : {args.sweeps} sweeps, fused={args.fused} "
          f"relresid {float(jnp.linalg.norm(res.resid[-1]))/bn:.3e} "
          f"({time.time()-t0:.1f}s)")

    rho_rk = float(theory.rk_rho(prob.A))
    beta = theory.beta_opt_rk(rho_rk, args.tau)
    t0 = time.time()
    ares = solve(prob, key=jax.random.key(1), delay_key=jax.random.key(2),
                 beta=beta, format=args.format,
                 storage_dtype=args.storage_dtype,
                 schedule=Schedule(num_iters=iters, tau=args.tau,
                                   record_every=m))
    jax.block_until_ready(ares.x)
    print(f"  async RK   : tau={args.tau} beta~={beta:.3f} relresid "
          f"{float(jnp.linalg.norm(ares.resid[-1]))/bn:.3e} "
          f"({time.time()-t0:.1f}s)")

    workers = args.workers or len(jax.devices())
    mesh = make_host_mesh(workers)
    local_steps = args.local_steps or max(1, m // workers)
    # csr runs per-worker local sampling: every worker's step is a useful
    # update, so a round applies workers*local_steps row actions (the
    # equal-work accounting) and the staleness bound follows suit.
    local_sampling = args.format == "csr"
    upd_per_round = local_steps * (workers if local_sampling else 1)
    rounds = max(1, iters // upd_per_round)
    ptau = scheduled_tau(workers, local_steps, shared_stream=True,
                         local_sampling=local_sampling,
                         overlap=args.overlap)
    pbeta = theory.beta_opt_rk(rho_rk, ptau)
    t0 = time.time()
    pres = solve(prob, key=jax.random.key(1), mesh=mesh, beta=pbeta,
                 format=args.format, sync=args.rk_sync,
                 storage_dtype=args.storage_dtype,
                 schedule=Schedule(rounds=rounds, local_steps=local_steps,
                                   partition=args.partition,
                                   fused=args.fused, overlap=args.overlap,
                                   compress=args.compress))
    jax.block_until_ready(pres.x)
    sampling = "local" if args.format == "csr" else "global-stream"
    print(f"  par RK     : P={workers} tau={ptau} beta~={pbeta:.3f} "
          f"sampling={sampling} sync={args.rk_sync} "
          f"partition={args.partition} overlap={args.overlap} "
          f"compress={args.compress} "
          f"({pres.bytes_per_round:.0f} B/round) "
          f"{rounds} rounds, relresid "
          f"{float(jnp.linalg.norm(pres.resid[-1]))/bn:.3e} "
          f"({time.time()-t0:.1f}s)")
    if pres.lag is not None:
        lag = jnp.asarray(pres.lag)
        tau_lock = scheduled_tau(workers, local_steps, shared_stream=True,
                                 local_sampling=local_sampling)
        print(f"  staleness  : measured lag max={int(lag.max())} "
              f"(round 1: {int(lag[0])}) -> empirical tau "
              f"{int(lag.max()) + tau_lock} <= scheduled bound {ptau}")

    # Baseline: CG on the Jacobi-rescaled normal equations (Sec. 2.3) —
    # kappa is still squared relative to A, and each iteration pays two
    # blocking all-reduces.
    x0 = jnp.zeros_like(prob.x_star)
    An, dn = to_unit_diagonal(prob.A.T @ prob.A)
    bn_eq = dn[:, None] * (prob.A.T @ prob.b)
    t0 = time.time()
    cres = cg_solve(An, bn_eq, x0, prob.x_star / dn[:, None],
                    num_iters=args.sweeps)
    jax.block_until_ready(cres.x)
    x_cg = dn[:, None] * cres.x
    print(f"  CG (A^T A) : {args.sweeps} iters, relresid "
          f"{float(jnp.linalg.norm(prob.b - prob.A @ x_cg))/bn:.3e} "
          f"({time.time()-t0:.1f}s)")

    f_sync = float(theory.rk_factor(prob.A))
    f_async = float(theory.async_rk_factor(prob.A, args.tau, beta,
                                           rho_rk=rho_rk))
    print(f"  theory     : rho_rk={rho_rk:.4f} per-iter factor "
          f"sync={f_sync:.6f} async(tau={args.tau})={f_async:.6f}")


if __name__ == "__main__":
    main()
