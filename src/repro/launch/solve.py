"""Solver launcher — the paper's algorithm as a CLI, on the unified API.

``python -m repro.launch.solve --n 4096 --rhs 8 --workers 8 --sweeps 10``
builds a reference-scenario SPD system and solves it through
``repro.core.solve(problem, format=..., schedule=...)``:
(a) sequential randomized Gauss-Seidel, (b) the distributed asynchronous
variant (shard_map over a worker mesh), (c) CG — printing residual
trajectories, the paper's theoretical rate factors, and the chosen step
size beta~.  ``--format ell`` runs the sequential pass through the ELL
operator (Θ(nnz) row reads) instead of dense rows.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (Schedule, cg_solve, random_sparse_spd, solve, theory)
from repro.core.engine import (COMPRESS_MODES, PARTITIONS, scheduled_tau,
                               supported_syncs)
from repro.core.operators import STORAGE_DTYPES
from repro.launch.mesh import make_host_mesh

#: operator class names this CLI can build (--format dense/ell/csr); the
#: --sync choices are derived from the dispatch table narrowed to these
_CLI_FORMATS = ("DenseOp", "EllOp", "CsrOp")

#: the --format flag values, shared with the serve launcher so the two
#: CLIs' choices cannot drift
FORMAT_CHOICES = ("dense", "ell", "csr")


def add_fused_flag(ap: argparse.ArgumentParser, detail: str) -> None:
    """The tri-state ``--fused`` flag every launcher shares: absent ->
    False (the scan engine, today's default), bare ``--fused`` -> True
    (forced, warns where no fused kernel exists), ``--fused auto`` ->
    the tuning table's measured fused-vs-scan winner per strategy row
    (``repro.tune``; missing entries run the scan, silently)."""

    def value(s: str):
        if s != "auto":
            raise argparse.ArgumentTypeError(
                f"--fused takes no value or 'auto' (got {s!r})")
        return s

    ap.add_argument("--fused", nargs="?", const=True, default=False,
                    type=value, metavar="auto",
                    help="run inner loops as fused Pallas sweep kernels "
                         f"({detail}); bare --fused forces it (falls back "
                         "to the per-step scan with a warning where no "
                         "sweep kernel exists), '--fused auto' runs the "
                         "tuning table's measured winner per strategy row")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--rhs", type=int, default=8)
    ap.add_argument("--row-nnz", type=int, default=16)
    ap.add_argument("--offdiag", type=float, default=0.9)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--format", choices=FORMAT_CHOICES,
                    default="dense",
                    help="operator format (sequential AND distributed)")
    ap.add_argument("--ell-width", type=int, default=64)
    ap.add_argument("--sync",
                    choices=("auto", *supported_syncs("gs", _CLI_FORMATS)),
                    default="auto",
                    help="distributed sync strategy (a2a = sparsity-derived "
                         "neighbor all-to-all, CSR/ELL formats; the halo "
                         "strategy belongs to the banded format, which this "
                         "CLI does not build)")
    ap.add_argument("--partition", choices=PARTITIONS,
                    default="contiguous",
                    help="distributed slab assignment: 'balanced' bin-packs "
                         "rows by norm mass and nnz into the P slabs via a "
                         "symmetric row permutation (CSR/ELL formats)")
    add_fused_flag(ap, "iterate VMEM-resident, picks scalar-prefetched, "
                       "where the action x format has one")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered sync: each round installs the "
                         "PREVIOUS round's exchange while sweeping, hiding "
                         "sync latency behind local work at the cost of one "
                         "extra round of scheduled staleness (sparse/halo "
                         "strategies; others fall back to lockstep with a "
                         "warning)")
    ap.add_argument("--storage-dtype", choices=STORAGE_DTYPES,
                    default=None,
                    help="precision the operator's coefficients are stored "
                         "in (row norms, iterate and accumulation stay "
                         "f32); default keeps the input dtype bitwise")
    ap.add_argument("--compress", choices=COMPRESS_MODES,
                    default="none",
                    help="wire format of the distributed sync payload; the "
                         "GS allgather/a2a exchanges are bitwise-pinned and "
                         "have no compressed wire, so a non-'none' value "
                         "falls back to the exact exchange with a warning "
                         "(the knob compresses the sparse-RK delta psum and "
                         "banded halo strategies)")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--local-steps", type=int, default=0,
                    help="async steps between synchronizations "
                         "(0 -> one sweep split evenly)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.sync == "a2a" and args.format == "dense":
        ap.error("--sync a2a needs a sparse format (--format csr or ell)")
    if args.partition == "balanced" and args.format == "dense":
        ap.error("--partition balanced needs a sparse format "
                 "(--format csr or ell)")

    prob = random_sparse_spd(args.n, row_nnz=args.row_nnz,
                             offdiag=args.offdiag, n_rhs=args.rhs,
                             seed=args.seed)
    if args.format == "ell":
        # ell_from_dense keeps only the width largest entries per row — a
        # too-small width silently solves a truncated system.  Widen to the
        # true max row occupancy so the ELL operator is exact.
        max_nnz = int((jnp.abs(prob.A) > 0).sum(axis=1).max())
        if args.ell_width < max_nnz:
            print(f"  [warn] --ell-width {args.ell_width} < max nnz/row "
                  f"{max_nnz}; widening to keep the operator exact")
            args.ell_width = max_nnz
    x0 = jnp.zeros_like(prob.x_star)
    rho = float(theory.rho(prob.A))
    n = prob.n
    print(f"[solve] n={n} rhs={args.rhs} kappa={float(prob.kappa):.1f} "
          f"rho={rho:.4f} format={args.format}")

    iters = args.sweeps * n
    t0 = time.time()
    res = solve(prob, key=jax.random.key(1), format=args.format,
                width=args.ell_width, storage_dtype=args.storage_dtype,
                schedule=Schedule(num_iters=iters, record_every=n,
                                  fused=args.fused))
    jax.block_until_ready(res.x)
    print(f"  sync RGS   : {args.sweeps} sweeps, fused={args.fused} "
          f"resid {float(res.resid[-1,0]):.3e} "
          f"({time.time()-t0:.1f}s)")

    workers = args.workers or len(jax.devices())
    mesh = make_host_mesh(workers)
    local_steps = args.local_steps or max(1, n // workers)
    tau = scheduled_tau(workers, local_steps, overlap=args.overlap)
    beta = theory.beta_opt(rho, tau)
    rounds = max(1, iters // (workers * local_steps))
    t0 = time.time()
    pres = solve(prob, key=jax.random.key(2), mesh=mesh, beta=beta,
                 format=args.format, width=args.ell_width, sync=args.sync,
                 storage_dtype=args.storage_dtype,
                 schedule=Schedule(rounds=rounds, local_steps=local_steps,
                                   partition=args.partition,
                                   fused=args.fused, overlap=args.overlap,
                                   compress=args.compress))
    jax.block_until_ready(pres.x)
    bpr = ("" if pres.bytes_per_round is None
           else f"({pres.bytes_per_round:.0f} B/round) ")
    print(f"  async RGS  : P={workers} tau={tau} beta~={beta:.3f} "
          f"format={args.format} sync={args.sync} "
          f"partition={args.partition} overlap={args.overlap} "
          f"compress={args.compress} {bpr}"
          f"{rounds} rounds, resid {float(pres.resid[-1,0]):.3e} "
          f"({time.time()-t0:.1f}s)")
    if pres.lag is not None:
        lag = jnp.asarray(pres.lag)
        tau_emp = int(lag.max()) + scheduled_tau(workers, local_steps)
        print(f"  staleness  : measured lag max={int(lag.max())} "
              f"(round 1: {int(lag[0])}) -> empirical tau {tau_emp} "
              f"<= scheduled bound {tau}")

    t0 = time.time()
    cres = cg_solve(prob.A, prob.b, x0, prob.x_star,
                    num_iters=args.sweeps)
    jax.block_until_ready(cres.x)
    print(f"  CG         : {args.sweeps} iters, resid {float(cres.resid[-1,0]):.3e} "
          f"({time.time()-t0:.1f}s)")
    nu = theory.nu_tau(rho, tau, beta)
    print(f"  theory     : nu_tau(beta~)={nu:.3f} "
          f"epoch factor <= {theory.thm41a_factor(rho, tau, float(prob.kappa), beta):.5f}")


if __name__ == "__main__":
    main()
