"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

CPU-scale runs use the reduced smoke configs (--smoke, default on CPU); the
production path builds the 16x16 / 2x16x16 mesh and shards via pjit exactly
as the dry-run proves.  The paper's bounded-staleness async-DP mode is
``--async-tau K`` (optim/async_update.py).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_run_config, get_smoke_config
from repro.launch.mesh import make_production_mesh
from repro.train import steps as ST
from repro.train.trainer import Trainer, make_data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (default on CPU)")
    ap.add_argument("--full", action="store_true",
                    help="force the full config + production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--async-tau", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--lr", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    on_cpu = jax.default_backend() == "cpu"
    use_smoke = args.smoke or (on_cpu and not args.full)
    cfg = get_smoke_config(args.arch) if use_smoke else get_config(args.arch)
    rcfg = get_run_config(args.arch).with_(
        total_steps=args.steps, warmup_steps=max(1, args.steps // 20),
        async_tau=args.async_tau, grad_compression=args.grad_compression,
        microbatches=args.microbatches,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        seed=args.seed,
        loss_chunk=min(512, args.seq_len),
        q_chunk=min(1024, args.seq_len))
    if args.lr:
        rcfg = rcfg.with_(learning_rate=args.lr)

    mesh = None if use_smoke else make_production_mesh(multi_pod=args.multi_pod)
    part = ST.make_partitioner(mesh, args.batch)
    data = make_data(cfg, args.seq_len, args.batch, seed=args.seed)
    trainer = Trainer(cfg=cfg, rcfg=rcfg, part=part, data=data)
    trainer.run(args.steps)
    return trainer


if __name__ == "__main__":
    main()
