"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).

Single pod: (16, 16) = 256 v5e chips, axes ("data", "model").
Two pods:   (2, 16, 16), axes ("pod", "data", "model") — the pod axis is
outer data parallelism over DCN.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(workers: int | None = None, axis: str = "workers"):
    """1-D mesh over the locally visible devices (solver benchmarks)."""
    n = workers or len(jax.devices())
    return _mesh((n,), (axis,))
