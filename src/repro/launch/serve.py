"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 64 --new 32``
runs the reduced config on CPU; --full uses the production mesh (the path
the decode dry-run cells compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_config, get_smoke_config
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train import steps as ST


def generate(cfg, params, part, prompts, new_tokens: int, *, greedy=True,
             capacity_len: int = 0, extra=None):
    """prompts: (B, P) int32 -> (B, P + new_tokens)."""
    B, P = prompts.shape
    capacity_len = capacity_len or (P + new_tokens)
    prefill = ST.make_prefill_step(cfg, part, capacity_len=capacity_len)
    batch = {"tokens": prompts}
    batch.update(extra or {})
    logits, cache = jax.jit(prefill)(params, batch)
    serve = jax.jit(ST.make_serve_step(
        cfg, part, ShapeConfig("gen", capacity_len, B, "decode")))
    out = [prompts]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        out.append(tok)
        if i == new_tokens - 1:
            break
        logits, cache = serve(params, cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    on_cpu = jax.default_backend() == "cpu"
    cfg = get_config(args.arch) if (args.full and not on_cpu) else get_smoke_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod) if args.full and not on_cpu else None
    part = ST.make_partitioner(mesh, args.batch)
    params, _ = T.init_params(cfg, jax.random.key(args.seed), part.sc)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)), jnp.int32)
    extra = {}
    if cfg.frontend == "audio":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_len, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision":
        extra["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    out = generate(cfg, params, part, prompts, args.new, extra=extra)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new} -> {out.shape} in {dt:.1f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print("first sequence tail:", np.asarray(out[0, -8:]))
    return out


if __name__ == "__main__":
    main()
