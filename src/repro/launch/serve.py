"""Solver-as-a-service launcher (DESIGN.md §8).

``python -m repro.launch.serve --n 256 --requests 16 --rate 50`` builds a
reference-scenario problem, registers it with a persistent
``SolverService``, and drives the service with a synthetic open-loop
request stream of mixed RHS widths — printing queries/sec, p50/p99
latency, convergence, and the continuous-batching counters (batches,
chunk launches, executor-cache hits).  ``--serial`` runs the same stream
one-request-per-batch (``max_batch=1``), the baseline the batched numbers
are compared against in ``benchmarks/bench_serve.py``.
"""
from __future__ import annotations

import argparse

from repro.core import random_sparse_lsq, random_sparse_spd
from repro.launch.solve import FORMAT_CHOICES, add_fused_flag
from repro.serve import SolverService, open_loop_load


def build_service(args) -> tuple[SolverService, str]:
    """A started-ready service with the CLI's problem registered."""
    svc = SolverService(
        num_iters=args.max_iters, record_every=args.record_every,
        max_batch=1 if args.serial else args.max_batch,
        batch_window_s=args.batch_window_ms * 1e-3, fused=args.fused)
    if args.action == "gs":
        prob = random_sparse_spd(args.n, row_nnz=args.row_nnz, n_rhs=1,
                                 seed=args.seed)
    else:
        prob = random_sparse_lsq(2 * args.n, args.n, row_nnz=args.row_nnz,
                                 n_rhs=1, seed=args.seed)
    svc.register("default", prob.A, action=args.action, format=args.format,
                 seed=args.seed, warmup_buckets=(1,) if args.warmup else ())
    return svc, "default"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--row-nnz", type=int, default=8)
    ap.add_argument("--action", choices=("gs", "rk"), default="gs",
                    help="gs = SPD coordinate action, rk = rectangular "
                         "Kaczmarz (the service batches either)")
    ap.add_argument("--format", choices=FORMAT_CHOICES, default="csr")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="open-loop arrival rate (requests/sec)")
    ap.add_argument("--rhs-widths", type=int, nargs="+", default=[1, 2, 4],
                    help="request RHS widths drawn uniformly (mixed shapes "
                         "exercise the bucketer)")
    ap.add_argument("--rtol", type=float, default=1e-3)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--max-iters", type=int, default=4096)
    ap.add_argument("--record-every", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--serial", action="store_true",
                    help="one-request-at-a-time baseline (max_batch=1)")
    add_fused_flag(ap, "the chunk executables the service keeps warm")
    ap.add_argument("--no-warmup", dest="warmup", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    svc, name = build_service(args)
    with svc:
        report = open_loop_load(
            svc, name, requests=args.requests, rate_hz=args.rate,
            rhs_widths=tuple(args.rhs_widths), rtol=args.rtol,
            seed=args.seed,
            deadline_s=(None if args.deadline_ms is None
                        else args.deadline_ms * 1e-3))

    mode = "serial" if args.serial else "batched"
    print(f"[serve] mode={mode} requests={report.requests} "
          f"converged={report.converged} qps={report.qps:.1f} "
          f"p50={report.p50_ms:.1f}ms p99={report.p99_ms:.1f}ms "
          f"makespan={report.makespan_s:.2f}s")
    print(f"[serve] batches={svc.stats.batches} "
          f"chunk_launches={svc.stats.chunk_launches} "
          f"deadline_expired={svc.stats.deadline_expired} "
          f"cache={svc.executors.stats()}")
    return {"report": report._asdict(), "stats": svc.stats,
            "cache": svc.executors.stats()}


if __name__ == "__main__":
    main()
