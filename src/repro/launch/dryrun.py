import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  This module is the multi-pod dry-run: for every
# (arch x input-shape x mesh) cell it lowers + compiles the real step
# function against ShapeDtypeStruct stand-ins (no allocation), proving the
# distribution config is coherent, and extracts memory/cost/collective
# numbers for EXPERIMENTS.md §Dry-run and §Roofline.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro import roofline as RL
from repro.configs import SHAPES, all_cells, cell as get_cell, get_config, get_run_config
from repro.launch.mesh import make_production_mesh
from repro.sharding import spec_tree_to_shardings
from repro.train import steps as ST


def active_param_counts(cfg, param_shapes) -> tuple[int, int]:
    """(total_params, active_params): MoE expert tensors count top_k(+shared)
    of num_experts toward the active path; the embedding table is excluded
    from both (its matmul FLOPs are added separately)."""
    total = active = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(param_shapes)
    E = cfg.moe.num_experts if cfg.moe else 0
    frac = (cfg.moe.top_k / E) if cfg.moe else 0.0
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        n = ST.np_prod(leaf.shape)
        if "embedding" in key or "unembed" in key:
            continue
        total += n
        if E and ("w_gate" in key or "w_up" in key or "w_down" in key) \
                and "shared" not in key and E in leaf.shape:
            active += int(n * frac)
        else:
            active += n
    return total, active


def model_flops(cfg, shape, param_shapes) -> float:
    from repro.models.transformer import padded_vocab
    from repro.sharding import ShardCtx
    total, active = active_param_counts(cfg, param_shapes)
    V, D = padded_vocab(cfg, ShardCtx()), cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens + 6.0 * tokens * D * V
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens + 2.0 * shape.global_batch * D * V
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch + 2.0 * shape.global_batch * D * V


def memory_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
               unroll: bool = True):
    """Build + lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    # scan_unroll: XLA's cost analysis counts a while-loop body once; the
    # single-pod (roofline) dry-run unrolls scan-over-layers so §Roofline
    # sees every layer's FLOPs.  The multi-pod pass only proves the pod axis
    # shards, so it keeps the scan (much faster compiles).
    rcfg = get_run_config(arch).with_(scan_unroll=unroll)
    shape = SHAPES[shape_name]
    part = ST.make_partitioner(mesh, shape.global_batch, fsdp=rcfg.fsdp,
                               pure_dp=rcfg.pure_dp)
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": shape.kind}
    t0 = time.time()

    if shape.kind == "train":
        step_fn, _ = ST.make_train_step(cfg, rcfg, part)
        state_shapes, sspecs = ST.abstract_train_state(cfg, rcfg, part)
        batch_shapes, bspecs = ST.input_specs(cfg, shape, part)
        in_sh = (spec_tree_to_shardings(mesh, sspecs),
                 spec_tree_to_shardings(mesh, bspecs))
        out_sh = (spec_tree_to_shardings(mesh, sspecs), None)
        lowered = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh
                          ).lower(state_shapes, batch_shapes)
        pshapes = state_shapes.params
    elif shape.kind == "prefill":
        prefill = ST.make_prefill_step(cfg, part, q_chunk=rcfg.q_chunk,
                                       unroll=unroll)
        state_shapes, sspecs = ST.abstract_train_state(cfg, rcfg, part)
        batch_shapes, bspecs = ST.input_specs(cfg, shape, part)
        in_sh = (spec_tree_to_shardings(mesh, sspecs.params),
                 spec_tree_to_shardings(mesh, bspecs))
        lowered = jax.jit(prefill, in_shardings=in_sh).lower(
            state_shapes.params, batch_shapes)
        pshapes = state_shapes.params
    else:  # decode
        serve = ST.make_serve_step(cfg, part, shape, unroll=unroll)
        state_shapes, sspecs = ST.abstract_train_state(cfg, rcfg, part)
        cache_shapes, cspecs = ST.abstract_cache(cfg, shape, part)
        batch_shapes, bspecs = ST.input_specs(cfg, shape, part)
        in_sh = (spec_tree_to_shardings(mesh, sspecs.params),
                 spec_tree_to_shardings(mesh, cspecs),
                 spec_tree_to_shardings(mesh, bspecs["tokens"]),
                 spec_tree_to_shardings(mesh, bspecs["length"]))
        lowered = jax.jit(serve, in_shardings=in_sh).lower(
            state_shapes.params, cache_shapes,
            batch_shapes["tokens"], batch_shapes["length"])
        pshapes = state_shapes.params

    record["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)

    cost = compat.cost_analysis(compiled)
    mem = memory_dict(compiled.memory_analysis())
    hlo = compiled.as_text()
    chips = 512 if multi_pod else 256
    mf = model_flops(cfg, shape, pshapes)
    rl = RL.analyze(cost, hlo, chips=chips, model_flops=mf)
    total, active = active_param_counts(cfg, pshapes)

    record.update({
        "params_total": total, "params_active": active,
        "flops_per_device": rl.flops,
        "bytes_per_device": rl.mem_bytes,
        "collective_wire_bytes_per_device": rl.coll.wire_bytes,
        "collectives": {k: {"count": c, "wire_bytes": b}
                        for k, (c, b) in rl.coll.by_kind.items()},
        "t_comp_s": rl.t_comp, "t_mem_s": rl.t_mem, "t_coll_s": rl.t_coll,
        "bottleneck": rl.bottleneck,
        "model_flops": mf,
        "useful_flop_ratio": rl.useful_ratio,
        "roofline_fraction": rl.roofline_fraction,
        "memory": mem,
        "ok": True,
    })
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep scan-over-layers rolled (fast compile; "
                         "per-layer FLOPs undercounted by cost_analysis)")
    args = ap.parse_args()

    cells = [c for c in all_cells()
             if (args.arch in ("all", c.arch))
             and (args.shape in ("all", c.shape.name))]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = n_skip = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mname = "2x16x16" if multi_pod else "16x16"
        for c in cells:
            tag = f"{c.arch}__{c.shape.name}__{mname}"
            path = os.path.join(args.out, tag + ".json")
            if not c.runnable:
                rec = {"arch": c.arch, "shape": c.shape.name, "mesh": mname,
                       "ok": False, "skip": c.skip_reason}
                n_skip += 1
            else:
                try:
                    rec = lower_cell(c.arch, c.shape.name, mesh,
                                     multi_pod=multi_pod,
                                     unroll=not (args.no_unroll or multi_pod))
                    n_ok += 1
                    print(f"PASS {tag}: lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s bottleneck={rec['bottleneck']} "
                          f"t=({rec['t_comp_s']:.3e},{rec['t_mem_s']:.3e},"
                          f"{rec['t_coll_s']:.3e})s", flush=True)
                except Exception as e:
                    rec = {"arch": c.arch, "shape": c.shape.name, "mesh": mname,
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    n_fail += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}",
                          flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            if c.runnable and args.verbose and rec.get("ok"):
                print(json.dumps(rec["memory"], indent=1))
    print(f"dry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(documented).", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
