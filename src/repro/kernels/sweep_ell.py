"""Fused GS/RK sweeps for the ELLPACK layout — the sibling of
``kernels/sweep_csr.py``, and the module ``EllOp.gs_sweep``/``rk_sweep``
route through (via ``kernels.ops``).

ELL *is* the padded-row form the sweep kernels consume (``EllOp.vals`` /
``EllOp.cols`` are per-row fixed-width value/column windows with global
column ids and zero-valued padding — exactly what ``CsrOp.padded_rows()``
reconstructs from the panel-aligned flat layout), so the sibling shares
the kernel bodies and exists to make the format pairing explicit: an
``EllOp`` sweep streams its stored windows directly, with no intermediate
view to build.
"""
from __future__ import annotations

import jax

from repro.kernels.sweep_csr import (sweep_rows_gs, sweep_rows_rk,
                                     sweep_rows_rk_delta)


def sweep_ell_gs(vals, cols, b, x, picks, *, beta: float = 1.0,
                 write_base=0, interpret: bool = False) -> jax.Array:
    """``sweep_rows_gs`` on ELL storage (vals/cols: (n, width))."""
    return sweep_rows_gs(vals, cols, b, x, picks, beta=beta,
                         write_base=write_base, interpret=interpret)


def sweep_ell_rk(vals, cols, b, rn, x, picks, *, beta: float = 1.0,
                 interpret: bool = False) -> jax.Array:
    """``sweep_rows_rk`` on ELL storage (vals/cols: (m, width))."""
    return sweep_rows_rk(vals, cols, b, rn, x, picks, beta=beta,
                         interpret=interpret)


def sweep_ell_rk_delta(vals, cols, b, rn, x, d, picks, *, beta: float = 1.0,
                       interpret: bool = False):
    """``sweep_rows_rk_delta`` on ELL storage — the distributed two-carry
    (replica, round-delta) Kaczmarz sweep."""
    return sweep_rows_rk_delta(vals, cols, b, rn, x, d, picks, beta=beta,
                               interpret=interpret)
