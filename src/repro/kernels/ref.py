"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_gs_sweep_ref(A, b, x, blocks, *, block: int, beta: float = 1.0):
    """Sequential randomized block-GS steps (same semantics as the kernel)."""
    def step(x, bi):
        rows = bi * block + jnp.arange(block)
        g = b[rows] - A[rows] @ x
        return x.at[rows].add(beta * g), None

    x, _ = jax.lax.scan(step, x, blocks)
    return x


def bbmv_ref(A_dense, x):
    """y = A @ x on the dense equivalent of the banded matrix."""
    return A_dense @ x


def spmv_ell_ref(vals, cols, x):
    """Values up-cast to f32 before contracting, matching the kernel's
    f32 accumulation (identity for f32 storage)."""
    n, width = vals.shape
    return jnp.einsum("nw,nwk->nk", vals.astype(jnp.float32),
                      x[cols]).astype(x.dtype)


def spmv_csr_ref(data, indices, row_id, x, *, m):
    """y = A @ x from flat CSR triples via a true segment sum.

    Padding slots carry data == 0 (and point at column 0 / row 0), so they
    contribute nothing regardless of where they scatter.  Values up-cast
    to f32 (identity for f32 storage) so low-precision operators still
    accumulate in f32.
    """
    contrib = data.astype(jnp.float32)[:, None] * x[indices]
    return jax.ops.segment_sum(contrib, row_id,
                               num_segments=m).astype(x.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-token GQA attention, full-precision softmax."""
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) / (D ** 0.5)
    mask = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(B, H, D).astype(q.dtype)
