"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_gs_sweep_ref(A, b, x, blocks, *, block: int, beta: float = 1.0):
    """Sequential randomized block-GS steps (same semantics as the kernel)."""
    def step(x, bi):
        rows = bi * block + jnp.arange(block)
        g = b[rows] - A[rows] @ x
        return x.at[rows].add(beta * g), None

    x, _ = jax.lax.scan(step, x, blocks)
    return x


def bbmv_ref(A_dense, x):
    """y = A @ x on the dense equivalent of the banded matrix."""
    return A_dense @ x


def spmv_ell_ref(vals, cols, x):
    """Values up-cast to f32 before contracting, matching the kernel's
    f32 accumulation (identity for f32 storage)."""
    n, width = vals.shape
    return jnp.einsum("nw,nwk->nk", vals.astype(jnp.float32),
                      x[cols]).astype(x.dtype)


def spmv_csr_ref(data, indices, row_id, x, *, m):
    """y = A @ x from flat CSR triples via a true segment sum.

    Padding slots carry data == 0 (and point at column 0 / row 0), so they
    contribute nothing regardless of where they scatter.  Values up-cast
    to f32 (identity for f32 storage) so low-precision operators still
    accumulate in f32.
    """
    contrib = data.astype(jnp.float32)[:, None] * x[indices]
    return jax.ops.segment_sum(contrib, row_id,
                               num_segments=m).astype(x.dtype)

