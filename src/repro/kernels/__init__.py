"""Pallas TPU kernels for the paper's compute hot spots.

block_gs          - randomized block Gauss-Seidel sweep (the paper's Alg. 1,
                    TPU-adapted: block granularity, VMEM-resident iterate)
bbmv              - block-banded SPD matvec (TPU-native sparse layout)
spmv_ell          - ELLPACK SpMV (GPU-style gather port, kept for contrast)

Use repro.kernels.ops for the jit'd wrappers and repro.kernels.ref for the
pure-jnp oracles the tests compare against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
