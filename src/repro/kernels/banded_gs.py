"""Pallas TPU kernel: banded randomized block Gauss-Seidel sweep — the
inner loop of the halo-exchange distributed solver (core/parallel_rgs.py,
§Perf s4-s6) as a single fused kernel.

Per grid step s (sequential on TPU):
    bi   = picks[s]                       # random local block-row (prefetched)
    g    = b[bi] - sum_d A_bands[bi, d] @ x[(bi + d)*block : ...]
    x[(bi + bands)*block : ...] += beta * g

``x`` is the halo-padded window ((nb_local + 2*bands)*block, k) and stays
VMEM-resident across the whole sweep (BlockSpec maps the full array at every
step), so successive steps see each other's updates — sequential randomized
block GS, the tau = 0 best case of the paper's analysis.  The A-band panel
for the chosen row streams HBM->VMEM via the scalar-prefetch index map: the
per-step HBM traffic is exactly the (2*bands+1) tiles + nothing else, which
is what makes the solver's memory-roofline fraction in EXPERIMENTS.md §Perf
attainable (no score/convert spills — contrast the unfused jnp path).

Validity masking: border blocks whose band column falls outside the matrix
contribute zero (the tiles are zero-padded by ``pack_bands``), so no branch
is needed inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, b_ref, x_ref, o_ref, *, block: int, bands: int,
            beta: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = x_ref[...]

    bi = idx_ref[s]
    width = 2 * bands + 1
    acc = b_ref[...].astype(jnp.float32)              # (block, k)
    for d in range(width):
        xs = o_ref[pl.ds((bi + d) * block, block), :]
        acc -= jnp.dot(a_ref[0, d], xs, preferred_element_type=jnp.float32)
    rows = pl.ds((bi + bands) * block, block)
    o_ref[rows, :] = o_ref[rows, :] + beta * acc.astype(o_ref.dtype)


#: the sweep wrappers share one jit signature: geometry + step size static
_STATIC_ARGS = ("block", "bands", "beta", "interpret")


@functools.partial(jax.jit, static_argnames=_STATIC_ARGS)
def banded_gs_sweep(
    A_bands: jax.Array,
    b: jax.Array,
    xw: jax.Array,
    picks: jax.Array,
    *,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """Apply ``len(picks)`` banded block-GS steps; returns the updated window.

    A_bands: (nb_local, 2*bands+1, block, block) — zero-padded border tiles;
    b: (nb_local*block, k); xw: ((nb_local + 2*bands)*block, k) halo window;
    picks: (steps,) int32 local block-row ids in [0, nb_local).
    """
    nb_local, width = A_bands.shape[:2]
    n_local, k = b.shape
    assert width == 2 * bands + 1
    assert n_local == nb_local * block
    assert xw.shape[0] == n_local + 2 * bands * block
    steps = picks.shape[0]
    if steps == 0:
        return xw

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, width, block, block),
                         lambda s, idx: (idx[s], 0, 0, 0)),
            pl.BlockSpec((block, k), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec(xw.shape, lambda s, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec(xw.shape, lambda s, idx: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block=block, bands=bands, beta=beta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(xw.shape, xw.dtype),
        interpret=interpret,
    )(picks, A_bands, b, xw)


def _rk_kernel(idx_ref, gate_ref, a_ref, b_ref, rn_ref, x_ref, d_ref,
               xo_ref, do_ref, *, block: int, bands: int, beta: float):
    """One masked banded Kaczmarz panel step (grid step s, sequential).

    Carries TWO VMEM-resident vectors: the working window ``xo`` and the
    round's delta window ``do`` (what the distributed engine psums at round
    end).  ``gate_ref[s]`` is 1 when this worker owns the picked panel and
    0 otherwise — foreign picks perform the same reads but apply exact-zero
    updates, mirroring the scan strategy's masked arithmetic bit for bit.
    """
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        xo_ref[...] = x_ref[...]
        do_ref[...] = d_ref[...]

    bi = idx_ref[s]
    width = 2 * bands + 1
    acc = b_ref[...].astype(jnp.float32)              # (block, k)
    for d in range(width):
        xs = xo_ref[pl.ds((bi + d) * block, block), :]
        acc -= jnp.dot(a_ref[0, d], xs, preferred_element_type=jnp.float32)
    g = acc.astype(xo_ref.dtype)
    betam = jnp.where(gate_ref[s] > 0, beta, 0.0)
    gn = (betam * g / rn_ref[0][:, None]).astype(jnp.float32)
    for d in range(width):
        contrib = jnp.dot(a_ref[0, d].T, gn,
                          preferred_element_type=jnp.float32)
        contrib = contrib.astype(xo_ref.dtype)
        rows = pl.ds((bi + d) * block, block)
        xo_ref[rows, :] = xo_ref[rows, :] + contrib
        do_ref[rows, :] = do_ref[rows, :] + contrib


@functools.partial(jax.jit, static_argnames=_STATIC_ARGS)
def banded_rk_sweep(
    A_bands: jax.Array,
    b: jax.Array,
    rn: jax.Array,
    xw: jax.Array,
    dw: jax.Array,
    picks: jax.Array,
    gates: jax.Array,
    *,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Apply ``len(picks)`` masked banded Kaczmarz panel steps in one
    launch; returns the updated (window, delta-window) pair.

    The RK extension of ``banded_gs_sweep``: the residual read is the same
    Θ(width) tile sweep, but the update is the damped Cimmino-within-panel
    action ``x += beta * A_B^T diag(1/||a_i||²) (b - A x)_B``, whose writes
    reach ``bands`` block columns either side of the panel — all inside the
    halo-padded window, which (with the delta) stays VMEM-resident for the
    whole sweep.

    A_bands: (nb_local, 2*bands+1, block, block) — border tiles zero-padded
    (``pack_bands_local``); b: (nb_local*block, k); rn: (nb_local, block)
    squared row norms (zero rows pre-guarded to 1 by the caller);
    xw/dw: ((nb_local + 2*bands)*block, k); picks: (steps,) int32 local
    block-row ids in [0, nb_local); gates: (steps,) int32 ownership mask.
    """
    nb_local, width = A_bands.shape[:2]
    n_local, k = b.shape
    assert width == 2 * bands + 1
    assert n_local == nb_local * block
    assert xw.shape == dw.shape == (n_local + 2 * bands * block, k)
    assert rn.shape == (nb_local, block)
    steps = picks.shape[0]
    if steps == 0:
        return xw, dw

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, width, block, block),
                         lambda s, idx, gate: (idx[s], 0, 0, 0)),
            pl.BlockSpec((block, k), lambda s, idx, gate: (idx[s], 0)),
            pl.BlockSpec((1, block), lambda s, idx, gate: (idx[s], 0)),
            pl.BlockSpec(xw.shape, lambda s, idx, gate: (0, 0)),
            pl.BlockSpec(dw.shape, lambda s, idx, gate: (0, 0)),
        ],
        out_specs=(pl.BlockSpec(xw.shape, lambda s, idx, gate: (0, 0)),
                   pl.BlockSpec(dw.shape, lambda s, idx, gate: (0, 0))),
    )
    return pl.pallas_call(
        functools.partial(_rk_kernel, block=block, bands=bands, beta=beta),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(xw.shape, xw.dtype),
                   jax.ShapeDtypeStruct(dw.shape, dw.dtype)),
        interpret=interpret,
    )(picks.astype(jnp.int32), gates.astype(jnp.int32), A_bands, b, rn, xw,
      dw)


def pack_bands_local(A_bands_global: jax.Array, lo_block: int, nb_local: int,
                     nb: int, bands: int) -> jax.Array:
    """Slice a worker's rows out of global band tiles, zeroing tiles whose
    column block falls outside [0, nb) (border validity baked into data)."""
    tiles = A_bands_global[lo_block:lo_block + nb_local]
    width = 2 * bands + 1
    out = []
    for bi in range(nb_local):
        row = []
        for d in range(width):
            cb = lo_block + bi + d - bands
            t = tiles[bi, d]
            row.append(t if 0 <= cb < nb else jnp.zeros_like(t))
        out.append(jnp.stack(row))
    return jnp.stack(out)
