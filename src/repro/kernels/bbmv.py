"""Pallas TPU kernel: block-banded SPD matvec / multi-RHS matmul.

The TPU-native sparse format argued for in DESIGN.md: nonzeros live in
dense (block x block) tiles on a band, stored contiguously as
``A_bands[nb, 2*bands+1, block, block]``.  HBM->VMEM streams are fully
contiguous (no gathers — contrast kernels/spmv_ell.py, the GPU-style port),
and every tile feeds the MXU directly.  Used for residual computation
``r = b - A x`` in CG / convergence monitoring on the blocked path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, o_ref, *, bands: int, block: int, nb: int):
    i = pl.program_id(0)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for d in range(2 * bands + 1):
        j = i + d - bands
        valid = jnp.logical_and(j >= 0, j < nb)
        jc = jnp.clip(j, 0, nb - 1)
        xs = x_ref[pl.ds(jc * block, block), :]
        tile = a_ref[0, d]
        acc += jnp.where(
            valid,
            jnp.dot(tile, xs, preferred_element_type=jnp.float32),
            0.0,
        )
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bands", "block", "interpret"))
def bbmv(
    A_bands: jax.Array,
    x: jax.Array,
    *,
    bands: int,
    block: int,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x for block-banded A.

    A_bands: (nb, 2*bands+1, block, block); x: (n, k) with n = nb*block.
    """
    nb = A_bands.shape[0]
    n, k = x.shape
    assert n == nb * block and A_bands.shape[1] == 2 * bands + 1

    return pl.pallas_call(
        functools.partial(_kernel, bands=bands, block=block, nb=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(
                (1, 2 * bands + 1, block, block), lambda i: (i, 0, 0, 0)
            ),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(A_bands, x)


def dense_to_bands(A: jax.Array, *, bands: int, block: int) -> jax.Array:
    """Pack the block band of dense A into (nb, 2*bands+1, block, block)."""
    n = A.shape[0]
    nb = n // block
    At = A.reshape(nb, block, nb, block).transpose(0, 2, 1, 3)  # (nb, nb, bl, bl)
    out = jnp.zeros((nb, 2 * bands + 1, block, block), A.dtype)
    for d in range(2 * bands + 1):
        off = d - bands
        for i in range(nb):
            j = i + off
            if 0 <= j < nb:
                out = out.at[i, d].set(At[i, j])
    return out
