"""Pallas TPU kernel: flash-decode attention (one query token, long KV).

The serving hot spot for the decode_32k / long_500k shapes: a single new
token attends to an S-long KV cache.  The op is purely HBM-bandwidth-bound
(read S*KV*D*2 bytes of cache per token), so the kernel's job is to stream
the cache through VMEM exactly once with an online-softmax accumulator.

GQA-aware: H query heads grouped onto KV heads (G = H // KV); the score
contraction is a (G x D x S-chunk) matmul per KV head.  The chunked
online-softmax (m, l, acc) carries across the sequential S grid dimension
in VMEM scratch — the same math that lets repro.models shard the cache over
mesh axes and merge partial results with log-sum-exp weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc, m_s, l_s, *, chunk, kv, g, d):
    s = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].reshape(kv, g, d).astype(jnp.float32)  # (KV, G, D)
    ks = k_ref[0].astype(jnp.float32)                   # (chunk, KV, D)
    vs = v_ref[0].astype(jnp.float32)                   # (chunk, KV, D)

    scores = jnp.einsum("hgd,shd->hgs", q, ks) / (d ** 0.5)  # (KV, G, chunk)
    # Mask positions beyond the valid cache length.
    pos = s * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, chunk), 2)
    scores = jnp.where(pos < len_ref[0], scores, -jnp.inf)

    m_prev, l_prev = m_s[...], l_s[...]                 # (KV, G)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    # exp(-inf - -inf) guard: where m_new is -inf the whole chunk is masked.
    alpha = jnp.where(jnp.isinf(m_new), 0.0, jnp.exp(m_prev - m_new))
    p = jnp.exp(scores - m_new[..., None])
    p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc[...] = acc[...] * alpha[..., None] + jnp.einsum("hgs,shd->hgd", p, vs)
    m_s[...] = m_new
    l_s[...] = l_new

    @pl.when(s == n_chunks - 1)
    def _finish():
        out = acc[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(kv * g, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention against a KV cache.

    q: (B, H, D); k_cache/v_cache: (B, S, KV, D); lengths: (B,) valid sizes.
    Returns (B, H, D).
    """
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    assert H % KV == 0 and S % chunk == 0
    G = H // KV

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, S // chunk),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, chunk, KV, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, chunk, KV, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G, D), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, kv=KV, g=G, d=D),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
