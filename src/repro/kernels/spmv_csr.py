"""Pallas kernel: CSR SpMV via per-panel segment sums.

General compressed-sparse-row is the format the paper's reference scenario
(unstructured sparsity, C1..C2 nonzeros per row) actually ships in.  The
TPU-shaped layout here is *panel-aligned* CSR (see core.operators.CsrOp):
nonzeros stay in row-major CSR order but each panel of ``rows_per_panel``
consecutive rows is padded to a fixed nnz budget ``panel_width``, so the
flat ``data``/``indices``/``row_id`` arrays reshape to
``(num_panels, panel_width)`` and stream HBM->VMEM contiguously.

Within a kernel invocation the segment sum over a panel's rows is expressed
as a one-hot matmul — ``onehot[(local_row, slot)] @ (data * x[cols])`` —
which runs on the MXU instead of a scatter unit the TPU does not have.
Padding slots carry ``data == 0`` so they contribute nothing wherever their
``row_id`` points.  Gathers of ``x`` rows are the unavoidable CSR cost (the
same cost spmv_ell pays); the contrast with the fully gather-free
block-banded layout is quantified in benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
            rows_per_panel: int, panel_width: int):
    i = pl.program_id(0)
    x = x_ref[...]                                   # (n, k) resident in VMEM
    vals = vals_ref[0]                               # (panel_width,)
    cols = cols_ref[0]
    rows = rows_ref[0]
    xr = jnp.take(x, cols, axis=0)                   # (panel_width, k) gather
    contrib = vals[:, None].astype(jnp.float32) * xr.astype(jnp.float32)
    # Segment-sum over the panel's rows as a one-hot MXU matmul.  Padding
    # slots carry vals == 0, so wherever their row_id lands they add 0.0.
    lrow = rows - i * rows_per_panel                 # local row of each slot
    sel = jax.lax.broadcasted_iota(jnp.int32, (rows_per_panel, panel_width), 0)
    onehot = (sel == lrow[None, :]).astype(jnp.float32)
    o_ref[...] = jnp.dot(onehot, contrib,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("m", "rows_per_panel", "panel_width", "interpret"))
def spmv_csr(
    data: jax.Array,
    indices: jax.Array,
    row_id: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panel_width: int,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x with A in panel-aligned CSR form (core.operators.CsrOp).

    data/indices/row_id: flat (>= num_panels * panel_width,) arrays — the
    trailing row-window slack beyond the last panel is ignored; x: (n, k).
    """
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    body = num_panels * panel_width
    assert data.shape[0] >= body, (data.shape, num_panels, panel_width)
    vals2 = data[:body].reshape(num_panels, panel_width)
    cols2 = indices[:body].reshape(num_panels, panel_width)
    rows2 = row_id[:body].reshape(num_panels, panel_width)

    y = pl.pallas_call(
        functools.partial(_kernel, rows_per_panel=rows_per_panel,
                          panel_width=panel_width),
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(vals2, cols2, rows2, x)
    return y[:m]
