"""Pallas kernel: CSR SpMV via per-panel segment sums.

General compressed-sparse-row is the format the paper's reference scenario
(unstructured sparsity, C1..C2 nonzeros per row) actually ships in.  The
TPU-shaped layout here is *panel-aligned* CSR (see core.operators.CsrOp):
nonzeros stay in row-major CSR order but each panel of ``rows_per_panel``
consecutive rows is padded to a fixed nnz budget ``panel_width``, so the
flat ``data``/``indices``/``row_id`` arrays reshape to
``(num_panels, panel_width)`` and stream HBM->VMEM contiguously.

Within a kernel invocation the segment sum over a panel's rows is expressed
as a one-hot matmul — ``onehot[(local_row, slot)] @ (data * x[cols])`` —
which runs on the MXU instead of a scatter unit the TPU does not have.
Padding slots carry ``data == 0`` so they contribute nothing wherever their
``row_id`` points.  Gathers of ``x`` rows are the unavoidable CSR cost (the
same cost spmv_ell pays); the contrast with the fully gather-free
block-banded layout is quantified in benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _panel_body(i, vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
                rows_per_panel: int, panel_width: int):
    """Shared per-panel segment-sum body (panel ``i`` of the grid)."""
    x = x_ref[...]                                   # (n, k) resident in VMEM
    vals = vals_ref[0]                               # (panel_width,)
    cols = cols_ref[0]
    rows = rows_ref[0]
    xr = jnp.take(x, cols, axis=0)                   # (panel_width, k) gather
    contrib = vals[:, None].astype(jnp.float32) * xr.astype(jnp.float32)
    # Segment-sum over the panel's rows as a one-hot MXU matmul.  Padding
    # slots carry vals == 0, so wherever their row_id lands they add 0.0.
    lrow = rows - i * rows_per_panel                 # local row of each slot
    sel = jax.lax.broadcasted_iota(jnp.int32, (rows_per_panel, panel_width), 0)
    onehot = (sel == lrow[None, :]).astype(jnp.float32)
    o_ref[...] = jnp.dot(onehot, contrib,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel(vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
            rows_per_panel: int, panel_width: int):
    _panel_body(pl.program_id(0), vals_ref, cols_ref, rows_ref, x_ref, o_ref,
                rows_per_panel=rows_per_panel, panel_width=panel_width)


def _kernel_skip(nnz_ref, vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
                 rows_per_panel: int, panel_width: int):
    """Predicated variant: panels with zero stored nonzeros skip the x
    gather and the one-hot matmul entirely and just zero their output
    rows.  ``nnz_ref`` is the scalar-prefetched per-panel count stream, so
    the predicate is known before the panel's data streams in — the input
    index maps point empty panels back at panel 0 (already resident), so
    their HBM->VMEM traffic is skipped too."""
    i = pl.program_id(0)

    @pl.when(nnz_ref[i] > 0)
    def _compute():
        _panel_body(i, vals_ref, cols_ref, rows_ref, x_ref, o_ref,
                    rows_per_panel=rows_per_panel, panel_width=panel_width)

    @pl.when(nnz_ref[i] == 0)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(
    jax.jit,
    static_argnames=("m", "rows_per_panel", "panel_width", "interpret"))
def spmv_csr(
    data: jax.Array,
    indices: jax.Array,
    row_id: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panel_width: int,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x with A in panel-aligned CSR form (core.operators.CsrOp).

    data/indices/row_id: flat (>= num_panels * panel_width,) arrays — the
    trailing row-window slack beyond the last panel is ignored; x: (n, k).
    """
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    body = num_panels * panel_width
    assert data.shape[0] >= body, (data.shape, num_panels, panel_width)
    vals2 = data[:body].reshape(num_panels, panel_width)
    cols2 = indices[:body].reshape(num_panels, panel_width)
    rows2 = row_id[:body].reshape(num_panels, panel_width)

    y = pl.pallas_call(
        functools.partial(_kernel, rows_per_panel=rows_per_panel,
                          panel_width=panel_width),
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(vals2, cols2, rows2, x)
    return y[:m]


@functools.partial(
    jax.jit,
    static_argnames=("m", "rows_per_panel", "panel_width", "interpret"))
def spmv_csr_prefetch(
    data: jax.Array,
    indices: jax.Array,
    row_id: jax.Array,
    panel_nnz: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panel_width: int,
    interpret: bool = False,
) -> jax.Array:
    """``spmv_csr`` with empty-panel skipping via scalar prefetch.

    ``panel_nnz`` (num_panels,) int32 is prefetched ahead of the grid
    (``pltpu.PrefetchScalarGridSpec``), so both the input index maps and the
    kernel predicate see it before a panel's data moves: an empty panel —
    one whose ``rows_per_panel`` rows store no nonzeros, the common case
    after norm-balanced partitioning of banded-structure matrices or on
    degree-skewed graphs — costs neither the x gather nor the one-hot MXU
    matmul nor a fresh panel DMA (its index maps revisit panel 0).  Output
    rows of empty panels are written as zeros, so the result is bitwise the
    base kernel's.
    """
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    body = num_panels * panel_width
    assert data.shape[0] >= body, (data.shape, num_panels, panel_width)
    assert panel_nnz.shape == (num_panels,), (panel_nnz.shape, num_panels)
    vals2 = data[:body].reshape(num_panels, panel_width)
    cols2 = indices[:body].reshape(num_panels, panel_width)
    rows2 = row_id[:body].reshape(num_panels, panel_width)

    def panel_or_zero(i, nnz):
        return (jnp.where(nnz[i] > 0, i, 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((n, k), lambda i, nnz: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i, nnz: (i, 0)),
    )
    y = pl.pallas_call(
        functools.partial(_kernel_skip, rows_per_panel=rows_per_panel,
                          panel_width=panel_width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(panel_nnz.astype(jnp.int32), vals2, cols2, rows2, x)
    return y[:m]
