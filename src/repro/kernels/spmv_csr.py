"""Pallas kernels: CSR SpMV — sliced-ELL gather-accumulate (the default
``CsrOp.matvec`` path) and the legacy per-panel segment-sum contrast.

General compressed-sparse-row is the format the paper's reference scenario
(unstructured sparsity, C1..C2 nonzeros per row) actually ships in.  The
TPU-shaped layout is *panel-aligned* CSR (see core.operators.CsrOp):
nonzeros stay in row-major CSR order but each panel of ``rows_per_panel``
consecutive rows is padded to a fixed nnz budget ``panel_width``, so the
flat ``data``/``indices``/``row_id`` arrays reshape to
``(num_panels, panel_width)`` and stream HBM->VMEM contiguously.

Two matvec strategies over that storage:

* ``spmv_csr_sliced`` / ``spmv_csr_sliced_prefetch`` — the **default**
  (PR 5): the matvec reads the *sliced-ELL view* of the same nonzeros
  (``CsrOp.sliced_rows()``: per-row fixed-width value/column windows,
  panel-major), gathers each slot's x row and accumulates with a plain
  multiply-add contraction.  No one-hot matmul: the segment sum is free
  because every slot already sits in its own row of the output tile, so
  the per-panel flop count drops from Θ(rows_per_panel · panel_width · k)
  MXU work to the Θ(nnz · k) the nonzeros actually require.  The
  ``_prefetch`` variant folds in the PR-4 empty-panel predication
  (scalar-prefetched per-panel nnz counts; empty panels skip the gather
  and their input DMA is remapped to the resident panel 0).
* ``spmv_csr`` / ``spmv_csr_prefetch`` — the legacy segment-sum-as-
  one-hot-matmul kernels, kept as the measured contrast case
  (benchmarks/bench_kernels.py ``csr_segsum``): expressing the segment
  sum as ``onehot[(local_row, slot)] @ (data * x[cols])`` runs on the MXU
  but pays a dense (rows_per_panel, panel_width) matmul per panel —
  BENCH_kernels.json records it ~22x behind the block-banded layout at
  equal nnz, which is what motivated the sliced overhaul.

Padding slots carry ``data == 0`` so they contribute nothing in either
strategy.  Gathers of ``x`` rows are the unavoidable CSR cost (the same
cost spmv_ell pays); the contrast with the fully gather-free block-banded
layout is quantified in benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _panel_body(i, vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
                rows_per_panel: int, panel_width: int):
    """Shared per-panel segment-sum body (panel ``i`` of the grid)."""
    x = x_ref[...]                                   # (n, k) resident in VMEM
    vals = vals_ref[0]                               # (panel_width,)
    cols = cols_ref[0].astype(jnp.int32)             # widen compact indices
    rows = rows_ref[0]
    xr = jnp.take(x, cols, axis=0)                   # (panel_width, k) gather
    contrib = vals[:, None].astype(jnp.float32) * xr.astype(jnp.float32)
    # Segment-sum over the panel's rows as a one-hot MXU matmul.  Padding
    # slots carry vals == 0, so wherever their row_id lands they add 0.0.
    lrow = rows - i * rows_per_panel                 # local row of each slot
    sel = jax.lax.broadcasted_iota(jnp.int32, (rows_per_panel, panel_width), 0)
    onehot = (sel == lrow[None, :]).astype(jnp.float32)
    o_ref[...] = jnp.dot(onehot, contrib,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel(vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
            rows_per_panel: int, panel_width: int):
    _panel_body(pl.program_id(0), vals_ref, cols_ref, rows_ref, x_ref, o_ref,
                rows_per_panel=rows_per_panel, panel_width=panel_width)


def _kernel_skip(nnz_ref, vals_ref, cols_ref, rows_ref, x_ref, o_ref, *,
                 rows_per_panel: int, panel_width: int):
    """Predicated variant: panels with zero stored nonzeros skip the x
    gather and the one-hot matmul entirely and just zero their output
    rows.  ``nnz_ref`` is the scalar-prefetched per-panel count stream, so
    the predicate is known before the panel's data streams in — the input
    index maps point empty panels back at panel 0 (already resident), so
    their HBM->VMEM traffic is skipped too."""
    i = pl.program_id(0)

    @pl.when(nnz_ref[i] > 0)
    def _compute():
        _panel_body(i, vals_ref, cols_ref, rows_ref, x_ref, o_ref,
                    rows_per_panel=rows_per_panel, panel_width=panel_width)

    @pl.when(nnz_ref[i] == 0)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)


#: both matvec wrappers share one jit signature: panel geometry static
_STATIC_ARGS = ("m", "rows_per_panel", "panel_width", "interpret")


@functools.partial(jax.jit, static_argnames=_STATIC_ARGS)
def spmv_csr(
    data: jax.Array,
    indices: jax.Array,
    row_id: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panel_width: int,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x with A in panel-aligned CSR form (core.operators.CsrOp).

    data/indices/row_id: flat (>= num_panels * panel_width,) arrays — the
    trailing row-window slack beyond the last panel is ignored; x: (n, k).
    """
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    body = num_panels * panel_width
    assert data.shape[0] >= body, (data.shape, num_panels, panel_width)
    vals2 = data[:body].reshape(num_panels, panel_width)
    cols2 = indices[:body].reshape(num_panels, panel_width)
    rows2 = row_id[:body].reshape(num_panels, panel_width)

    y = pl.pallas_call(
        functools.partial(_kernel, rows_per_panel=rows_per_panel,
                          panel_width=panel_width),
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((1, panel_width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(vals2, cols2, rows2, x)
    return y[:m]


@functools.partial(jax.jit, static_argnames=_STATIC_ARGS)
def spmv_csr_prefetch(
    data: jax.Array,
    indices: jax.Array,
    row_id: jax.Array,
    panel_nnz: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panel_width: int,
    interpret: bool = False,
) -> jax.Array:
    """``spmv_csr`` with empty-panel skipping via scalar prefetch.

    ``panel_nnz`` (num_panels,) int32 is prefetched ahead of the grid
    (``pltpu.PrefetchScalarGridSpec``), so both the input index maps and the
    kernel predicate see it before a panel's data moves: an empty panel —
    one whose ``rows_per_panel`` rows store no nonzeros, the common case
    after norm-balanced partitioning of banded-structure matrices or on
    degree-skewed graphs — costs neither the x gather nor the one-hot MXU
    matmul nor a fresh panel DMA (its index maps revisit panel 0).  Output
    rows of empty panels are written as zeros, so the result is bitwise the
    base kernel's.
    """
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    body = num_panels * panel_width
    assert data.shape[0] >= body, (data.shape, num_panels, panel_width)
    assert panel_nnz.shape == (num_panels,), (panel_nnz.shape, num_panels)
    vals2 = data[:body].reshape(num_panels, panel_width)
    cols2 = indices[:body].reshape(num_panels, panel_width)
    rows2 = row_id[:body].reshape(num_panels, panel_width)

    def panel_or_zero(i, nnz):
        return (jnp.where(nnz[i] > 0, i, 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((1, panel_width), panel_or_zero),
            pl.BlockSpec((n, k), lambda i, nnz: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i, nnz: (i, 0)),
    )
    y = pl.pallas_call(
        functools.partial(_kernel_skip, rows_per_panel=rows_per_panel,
                          panel_width=panel_width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(panel_nnz.astype(jnp.int32), vals2, cols2, rows2, x)
    return y[:m]


# ---------------------------------------------------------------------------
# Sliced-ELL gather-accumulate kernels (the default CsrOp.matvec path)
# ---------------------------------------------------------------------------

def _sliced_body(vals_ref, cols_ref, x_ref, o_ref):
    """Gather-accumulate over a tile of per-row windows.

    Each output row is the contraction of its own value window with the
    gathered x rows — the segment sum is implicit in the layout (one window
    per output row), so no one-hot matmul and no scatter.  Padding slots
    carry value 0 and column 0, contributing exact zeros.
    """
    x = x_ref[...]                                   # (n, k) resident in VMEM
    vals = vals_ref[...]                             # (tile_rows, width)
    cols = cols_ref[...].astype(jnp.int32)           # widen compact indices
    xr = jnp.take(x, cols.reshape(-1), axis=0)       # (tile_rows*width, k)
    xr = xr.reshape(cols.shape + (x.shape[1],))
    o_ref[...] = jnp.einsum(
        "rw,rwk->rk", vals.astype(jnp.float32), xr.astype(jnp.float32)
    ).astype(o_ref.dtype)


def _sliced_kernel(vals_ref, cols_ref, x_ref, o_ref):
    _sliced_body(vals_ref, cols_ref, x_ref, o_ref)


def _sliced_kernel_skip(nnz_ref, vals_ref, cols_ref, x_ref, o_ref):
    """Predicated sliced kernel: panels with zero stored nonzeros skip the
    gather and the contraction, writing zero output rows; their input DMA
    is remapped to the already-resident panel 0 (see the index maps)."""
    i = pl.program_id(0)

    @pl.when(nnz_ref[i] > 0)
    def _compute():
        _sliced_body(vals_ref, cols_ref, x_ref, o_ref)

    @pl.when(nnz_ref[i] == 0)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(
    jax.jit,
    static_argnames=("m", "rows_per_panel", "panels_per_tile", "interpret"))
def spmv_csr_sliced(
    vals: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    panels_per_tile: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x from the sliced-ELL view (``CsrOp.sliced_rows()``).

    vals/cols: (num_panels * rows_per_panel, width) per-row windows with
    global column ids (padding slots: value 0, column 0); x: (n, k).
    ``panels_per_tile`` groups several panels per grid step (0 = auto: tile
    ~128 rows) — the dense-panel fast path with no predication.
    """
    mp, width = vals.shape
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    assert mp == num_panels * rows_per_panel, (mp, num_panels, rows_per_panel)
    G = panels_per_tile or max(1, 128 // rows_per_panel)
    num_tiles = -(-num_panels // G)
    tile_rows = G * rows_per_panel
    pad = num_tiles * tile_rows - mp
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        cols = jnp.pad(cols, ((0, pad), (0, 0)))

    y = pl.pallas_call(
        _sliced_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((tile_rows, width), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_rows, k), x.dtype),
        interpret=interpret,
    )(vals, cols, x)
    return y[:m]


@functools.partial(
    jax.jit, static_argnames=("m", "rows_per_panel", "interpret"))
def spmv_csr_sliced_prefetch(
    vals: jax.Array,
    cols: jax.Array,
    panel_nnz: jax.Array,
    x: jax.Array,
    *,
    m: int,
    rows_per_panel: int,
    interpret: bool = False,
) -> jax.Array:
    """``spmv_csr_sliced`` with empty-panel skipping via scalar prefetch.

    One grid step per panel (the skip granularity of ``panel_nnz``): the
    per-panel nnz counts are prefetched ahead of the grid, so both the
    input index maps and the kernel predicate see them before a panel's
    windows move — an empty panel costs neither the x gather nor the
    contraction nor a fresh window DMA (its index maps revisit panel 0).
    Output rows of empty panels are written as zeros, so the result is
    bitwise the unpredicated kernel's.
    """
    mp, width = vals.shape
    n, k = x.shape
    num_panels = -(-m // rows_per_panel)
    assert mp == num_panels * rows_per_panel, (mp, num_panels, rows_per_panel)
    assert panel_nnz.shape == (num_panels,), (panel_nnz.shape, num_panels)

    def panel_or_zero(i, nnz):
        return (jnp.where(nnz[i] > 0, i, 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_panels,),
        in_specs=[
            pl.BlockSpec((rows_per_panel, width), panel_or_zero),
            pl.BlockSpec((rows_per_panel, width), panel_or_zero),
            pl.BlockSpec((n, k), lambda i, nnz: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_panel, k), lambda i, nnz: (i, 0)),
    )
    y = pl.pallas_call(
        _sliced_kernel_skip,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_panels * rows_per_panel, k),
                                       x.dtype),
        interpret=interpret,
    )(panel_nnz.astype(jnp.int32), vals, cols, x)
    return y[:m]
