"""Pallas TPU kernel: randomized *block* Gauss-Seidel sweep.

TPU adaptation of the paper's Algorithm 1 (DESIGN.md §2).  The scalar
coordinate update `x_r += (b - A x)_r` cannot feed a 128x128 systolic array,
so the unit of randomization becomes an aligned coordinate block:

    for s in range(steps):           # grid dimension, sequential on TPU
        B = blocks[s]                # random block id (scalar-prefetched)
        g = b[B] - A[B, :] @ x       # (block, k) MXU matmul, A row-panel
                                     # streamed HBM->VMEM by the pipeline
        x[B] += beta * g             # in-VMEM update, visible to step s+1

`x` lives entirely in VMEM across the sweep (BlockSpec maps the whole array
at every grid step => no re-fetch), so successive steps see each other's
updates exactly like the shared-memory algorithm — within one core the
"asynchrony" disappears and we recover *sequential* randomized block GS,
which is the best case (tau = 0) of the paper's analysis.  Asynchrony
reappears across devices (see repro.core.parallel_rgs).

Multi-RHS (the paper's 51-column B, padded to a lane-friendly k) turns the
inner product into a matmul: arithmetic intensity rises from O(1) to O(k)
FLOPs/byte on the A-panel stream, which is what moves this kernel from
HBM-bound toward the MXU roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, b_ref, x_ref, o_ref, *, block: int, beta: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = x_ref[...]

    blk = idx_ref[s]
    g = b_ref[...] - jnp.dot(
        a_ref[...], o_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    rows = pl.ds(blk * block, block)
    o_ref[rows, :] = o_ref[rows, :] + beta * g


@functools.partial(
    jax.jit, static_argnames=("block", "beta", "interpret")
)
def block_gs_sweep(
    A: jax.Array,
    b: jax.Array,
    x: jax.Array,
    blocks: jax.Array,
    *,
    block: int = 128,
    beta: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """Apply ``len(blocks)`` randomized block-GS steps; returns updated x.

    A: (n, n); b, x: (n, k); blocks: (steps,) int32 block ids in [0, n/block).
    VMEM budget: x (n*k) + b panel + one (block, n) A panel — caller picks
    n, k, block so this fits ~16 MiB (e.g. n=8192, k=64, block=256 f32
    => 2 MiB + 8 MiB panel).
    """
    n, k = x.shape
    steps = blocks.shape[0]
    assert A.shape == (n, n) and b.shape == (n, k) and n % block == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((block, n), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((block, k), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, block=block, beta=beta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(blocks, A, b, x)
