"""Pallas TPU kernels: fused GS/RK sweeps over padded sparse rows.

The engine's sequential inner loop executes one row action per
``lax.scan`` step, round-tripping the whole iterate through HBM between
steps — per-update overhead the paper's cost model (per-nonzero, Sec. 4-5)
assumes away, and exactly what Chow et al.'s asynchronous-Richardson
argument says kills asynchronous methods in practice.  These kernels run an
*entire sweep* (``len(picks)`` sequential row updates) in a single Pallas
launch:

* the iterate ``x`` stays resident in VMEM across all steps (the BlockSpec
  maps the full array at every grid step, so nothing is re-fetched and
  step s+1 sees step s's update — sequential semantics, tau = 0);
* the pre-sampled pick sequence is **scalar-prefetched**, so the per-step
  row window (values, global column ids), b row, and row norm stream
  HBM->VMEM through prefetch-driven index maps — the per-step HBM traffic
  is exactly the picked row's Θ(width) window and nothing else.

The row storage is the *padded-row* form shared by ``CsrOp.padded_rows()``
and ``EllOp`` (``kernels/sweep_ell.py`` is the ELL-named sibling): per-row
fixed-width value/column windows with global column ids, padding slots
carrying value 0 / column 0 so they contribute exact zeros.

Actions (arithmetic transplanted from ``core.engine.solve_sequential`` —
the GS sweep is bitwise the scan engine's update order):

* GS  — ``gamma = b[r] - <A_r, x>``; ``x[base + r] += beta * gamma``;
* RK  — ``g = (b[r] - <A_r, x>) / ||A_r||²``; ``x[cols_r] += beta * A_r g``
  (the scatter runs as ``width`` sequential dynamic row updates — VMEM
  read-modify-writes, not an HBM scatter).

The GS **write base** is what lets the distributed local phases fuse: a
worker holds a *slab* of rows (local ids ``[0, slab)``) but updates a
full-length replica at global rows ``base + r``.  The base is a traced
scalar (``jax.lax.axis_index`` under shard_map), so it rides the scalar-
prefetch channel next to the pick stream rather than being baked into the
kernel.  ``base = 0`` recovers the sequential square-system sweep exactly.
The RK sibling ``sweep_rows_rk_delta`` needs no base — Kaczmarz writes
land at global *column* ids — but carries a second VMEM-resident output,
the round's delta window, so the distributed strategies can sync
``delta`` at round end (the ``banded_rk_sweep`` two-carry pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gs_kernel(idx_ref, base_ref, vals_ref, cols_ref, b_ref, x_ref, o_ref, *,
               beta: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = x_ref[...]

    r = base_ref[0] + idx_ref[s]                     # global write row
    vals = vals_ref[0].astype(jnp.float32)           # (width,) f32 accumulate
    cols = cols_ref[0].astype(jnp.int32)             # widen compact indices
    xg = jnp.take(o_ref[...], cols, axis=0)          # (width, k) gather
    gamma = b_ref[0] - jnp.einsum("w,wk->k", vals, xg)
    cur = o_ref[pl.ds(r, 1), :]
    o_ref[pl.ds(r, 1), :] = cur + beta * gamma[None, :]


def _rk_kernel(idx_ref, vals_ref, cols_ref, b_ref, rn_ref, x_ref, o_ref, *,
               beta: float, width: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = x_ref[...]

    vals = vals_ref[0].astype(jnp.float32)           # (width,) f32 accumulate
    cols = cols_ref[0].astype(jnp.int32)             # widen compact indices
    xg = jnp.take(o_ref[...], cols, axis=0)          # (width, k) gather
    g = (b_ref[0] - jnp.einsum("w,wk->k", vals, xg)) / rn_ref[0, 0]
    # Scatter A_r^T g back as `width` sequential single-row RMWs in VMEM.
    # Real columns of one row are distinct; padding slots (value 0) add
    # exact zeros wherever they land, so the result matches x.at[cols].add.
    for j in range(width):
        c = cols[j]
        cur = o_ref[pl.ds(c, 1), :]
        o_ref[pl.ds(c, 1), :] = cur + (beta * vals[j]) * g[None, :]


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def sweep_rows_gs(
    vals: jax.Array,
    cols: jax.Array,
    b: jax.Array,
    x: jax.Array,
    picks: jax.Array,
    *,
    beta: float = 1.0,
    write_base: jax.Array | int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Apply ``len(picks)`` sequential coordinate-GS row updates; returns x.

    vals/cols: (m, width) padded row windows (global column ids);
    b: (m, k); x: (n, k); picks: (steps,) int32 row ids in [0, m).

    ``write_base`` offsets every write: pick ``r`` updates row
    ``write_base + r`` of ``x`` (gathers stay at the stored global column
    ids).  This is the slab offset of the distributed local phases — a
    worker's rows are local ids but its replica is full-length — and may
    be a traced scalar (``axis_index`` under shard_map); the caller must
    keep ``write_base + r`` inside [0, n).  Default 0: the sequential
    square-system sweep, bitwise unchanged.
    """
    m, width = vals.shape
    n, k = x.shape
    assert b.shape[0] == m
    steps = picks.shape[0]
    if steps == 0:
        return x
    base = jnp.asarray(write_base, jnp.int32).reshape((1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, width), lambda s, idx, base: (idx[s], 0)),
            pl.BlockSpec((1, width), lambda s, idx, base: (idx[s], 0)),
            pl.BlockSpec((1, k), lambda s, idx, base: (idx[s], 0)),
            pl.BlockSpec((n, k), lambda s, idx, base: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda s, idx, base: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gs_kernel, beta=beta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(picks.astype(jnp.int32), base, vals, cols, b, x)


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def sweep_rows_rk(
    vals: jax.Array,
    cols: jax.Array,
    b: jax.Array,
    rn: jax.Array,
    x: jax.Array,
    picks: jax.Array,
    *,
    beta: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """Apply ``len(picks)`` sequential Kaczmarz row updates; returns x.

    vals/cols: (m, width) padded row windows; b: (m, k); rn: (m,) squared
    row norms (the caller's sampling distribution — passed in so the
    divisor matches the scan engine's bit-for-bit); x: (n, k);
    picks: (steps,) int32 row ids in [0, m).
    """
    m, width = vals.shape
    n, k = x.shape
    assert b.shape[0] == m and rn.shape == (m,)
    steps = picks.shape[0]
    if steps == 0:
        return x

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, width), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, width), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, k), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, 1), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_rk_kernel, beta=beta, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(picks.astype(jnp.int32), vals, cols, b, rn.reshape(m, 1), x)


def _rk_delta_kernel(idx_ref, vals_ref, cols_ref, b_ref, rn_ref, x_ref,
                     d_ref, xo_ref, do_ref, *, beta: float, width: int):
    """RK step over TWO VMEM-resident carries: the working replica ``xo``
    and the round's delta ``do`` (what the distributed engine syncs at
    round end) — the padded-row sibling of ``banded_gs._rk_kernel``."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        xo_ref[...] = x_ref[...]
        do_ref[...] = d_ref[...]

    vals = vals_ref[0].astype(jnp.float32)           # (width,) f32 accumulate
    cols = cols_ref[0].astype(jnp.int32)             # widen compact indices
    xg = jnp.take(xo_ref[...], cols, axis=0)         # (width, k) gather
    g = (b_ref[0] - jnp.einsum("w,wk->k", vals, xg)) / rn_ref[0, 0]
    for j in range(width):
        c = cols[j]
        contrib = (beta * vals[j]) * g[None, :]
        xo_ref[pl.ds(c, 1), :] = xo_ref[pl.ds(c, 1), :] + contrib
        do_ref[pl.ds(c, 1), :] = do_ref[pl.ds(c, 1), :] + contrib


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def sweep_rows_rk_delta(
    vals: jax.Array,
    cols: jax.Array,
    b: jax.Array,
    rn: jax.Array,
    x: jax.Array,
    d: jax.Array,
    picks: jax.Array,
    *,
    beta: float = 1.0,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Apply ``len(picks)`` sequential Kaczmarz row updates to the
    (replica, round-delta) pair in one launch; returns ``(x, d)``.

    The distributed form of ``sweep_rows_rk``: every update lands in both
    carries (both stay VMEM-resident across all steps), so the caller can
    psum / a2a-exchange the accumulated ``d`` at round end.  vals/cols:
    (m, width) padded row windows with global column ids — a worker's
    slab; no write base is needed because Kaczmarz writes land at the
    stored (global) column ids.  rn: (m,) squared row norms, zero rows
    pre-guarded by the caller.
    """
    m, width = vals.shape
    n, k = x.shape
    assert b.shape[0] == m and rn.shape == (m,)
    assert d.shape == (n, k)
    steps = picks.shape[0]
    if steps == 0:
        return x, d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, width), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, width), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, k), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((1, 1), lambda s, idx: (idx[s], 0)),
            pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
            pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((n, k), lambda s, idx: (0, 0)),
                   pl.BlockSpec((n, k), lambda s, idx: (0, 0))),
    )
    return pl.pallas_call(
        functools.partial(_rk_delta_kernel, beta=beta, width=width),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n, k), x.dtype),
                   jax.ShapeDtypeStruct((n, k), d.dtype)),
        interpret=interpret,
    )(picks.astype(jnp.int32), vals, cols, b, rn.reshape(m, 1), x, d)
