"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode; on TPU they
compile natively.  ``interpret=None`` auto-detects.  All wrappers fall back
to the pure-jnp reference implementation when shapes violate kernel tiling
constraints, so callers can use them unconditionally.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.banded_gs import (banded_gs_sweep as _banded_gs_sweep,
                                     banded_rk_sweep as _banded_rk_sweep)
from repro.kernels.bbmv import bbmv as _bbmv, dense_to_bands
from repro.kernels.block_gs import block_gs_sweep as _block_gs_sweep
from repro.kernels.spmv_csr import (
    spmv_csr as _spmv_csr,
    spmv_csr_prefetch as _spmv_csr_prefetch,
    spmv_csr_sliced as _spmv_csr_sliced,
    spmv_csr_sliced_prefetch as _spmv_csr_sliced_prefetch,
)
from repro.kernels.spmv_ell import spmv_ell as _spmv_ell
from repro.kernels.sweep_csr import (
    sweep_rows_gs as _sweep_rows_gs,
    sweep_rows_rk as _sweep_rows_rk,
    sweep_rows_rk_delta as _sweep_rows_rk_delta,
)
from repro.kernels.sweep_ell import (
    sweep_ell_gs as _sweep_ell_gs,
    sweep_ell_rk as _sweep_ell_rk,
    sweep_ell_rk_delta as _sweep_ell_rk_delta,
)


def interpret_default() -> bool:
    """Whether ``interpret=None`` resolves to interpret-mode execution on
    the current backend — the single source the benchmark provenance
    stamp and the tuning table's ``interpret_mode`` field both read, so
    interpret-mode timings can never masquerade as hardware numbers."""
    return jax.default_backend() == "cpu"


def _interp(interpret):
    if interpret is None:
        return interpret_default()
    return interpret


def block_gs_sweep(A, b, x, blocks, *, block=128, beta=1.0, interpret=None):
    if A.shape[0] % block != 0:
        return ref.block_gs_sweep_ref(A, b, x, blocks, block=block, beta=beta)
    return _block_gs_sweep(
        A, b, x, blocks, block=block, beta=beta, interpret=_interp(interpret)
    )


def bbmv(A_bands, x, *, bands, block, interpret=None):
    return _bbmv(A_bands, x, bands=bands, block=block, interpret=_interp(interpret))


def spmv_ell(vals, cols, x, *, tile=128, interpret=None):
    if vals.shape[0] % tile != 0:
        return ref.spmv_ell_ref(vals, cols, x)
    return _spmv_ell(vals, cols, x, tile=tile, interpret=_interp(interpret))


def spmv_csr(data, indices, row_id, x, *, m, rows_per_panel, panel_width,
             interpret=None):
    # No tiling-fallback branch: CsrOp.from_dense always allocates
    # num_panels * panel_width (+ row-window slack) slots, and the kernel
    # asserts that invariant itself.
    return _spmv_csr(data, indices, row_id, x, m=m,
                     rows_per_panel=rows_per_panel, panel_width=panel_width,
                     interpret=_interp(interpret))


def spmv_csr_prefetch(data, indices, row_id, panel_nnz, x, *, m,
                      rows_per_panel, panel_width, interpret=None):
    """Empty-panel-skipping spmv_csr (scalar-prefetched per-panel nnz)."""
    return _spmv_csr_prefetch(data, indices, row_id, panel_nnz, x, m=m,
                              rows_per_panel=rows_per_panel,
                              panel_width=panel_width,
                              interpret=_interp(interpret))


def spmv_csr_sliced(vals, cols, x, *, m, rows_per_panel, panels_per_tile=0,
                    interpret=None):
    """Gather-accumulate CSR matvec on the sliced-ELL view (the default
    ``CsrOp.matvec`` path; no empty-panel predication)."""
    return _spmv_csr_sliced(vals, cols, x, m=m, rows_per_panel=rows_per_panel,
                            panels_per_tile=panels_per_tile,
                            interpret=_interp(interpret))


def spmv_csr_sliced_prefetch(vals, cols, panel_nnz, x, *, m, rows_per_panel,
                             interpret=None):
    """Empty-panel-skipping ``spmv_csr_sliced`` (scalar-prefetched nnz)."""
    return _spmv_csr_sliced_prefetch(vals, cols, panel_nnz, x, m=m,
                                     rows_per_panel=rows_per_panel,
                                     interpret=_interp(interpret))


def banded_gs_sweep(A_bands, b, xw, picks, *, block, bands, beta=1.0,
                    interpret=None):
    """Fused banded block-GS sweep (halo-padded window stays VMEM-resident;
    picks scalar-prefetched)."""
    return _banded_gs_sweep(A_bands, b, xw, picks, block=block, bands=bands,
                            beta=beta, interpret=_interp(interpret))


def banded_rk_sweep(A_bands, b, rn, xw, dw, picks, gates, *, block, bands,
                    beta=1.0, interpret=None):
    """Fused masked banded Kaczmarz sweep over (window, delta) carries."""
    return _banded_rk_sweep(A_bands, b, rn, xw, dw, picks, gates, block=block,
                            bands=bands, beta=beta,
                            interpret=_interp(interpret))


def sweep_rows_gs(vals, cols, b, x, picks, *, beta=1.0, write_base=0,
                  interpret=None):
    """Fused coordinate-GS sweep over padded sparse rows (CSR/ELL).
    ``write_base`` offsets writes by a (possibly traced) slab offset —
    the distributed local phase's global row base."""
    return _sweep_rows_gs(vals, cols, b, x, picks, beta=beta,
                          write_base=write_base,
                          interpret=_interp(interpret))


def sweep_rows_rk(vals, cols, b, rn, x, picks, *, beta=1.0, interpret=None):
    """Fused Kaczmarz sweep over padded sparse rows (CSR/ELL)."""
    return _sweep_rows_rk(vals, cols, b, rn, x, picks, beta=beta,
                          interpret=_interp(interpret))


def sweep_rows_rk_delta(vals, cols, b, rn, x, d, picks, *, beta=1.0,
                        interpret=None):
    """Fused two-carry (replica, round-delta) Kaczmarz sweep over padded
    sparse rows — the distributed local phase of ``sparse_rk``."""
    return _sweep_rows_rk_delta(vals, cols, b, rn, x, d, picks, beta=beta,
                                interpret=_interp(interpret))


def sweep_ell_gs(vals, cols, b, x, picks, *, beta=1.0, write_base=0,
                 interpret=None):
    """Fused coordinate-GS sweep on ELL storage (kernels/sweep_ell.py)."""
    return _sweep_ell_gs(vals, cols, b, x, picks, beta=beta,
                         write_base=write_base, interpret=_interp(interpret))


def sweep_ell_rk(vals, cols, b, rn, x, picks, *, beta=1.0, interpret=None):
    """Fused Kaczmarz sweep on ELL storage (kernels/sweep_ell.py)."""
    return _sweep_ell_rk(vals, cols, b, rn, x, picks, beta=beta,
                         interpret=_interp(interpret))


def sweep_ell_rk_delta(vals, cols, b, rn, x, d, picks, *, beta=1.0,
                       interpret=None):
    """Fused two-carry Kaczmarz sweep on ELL storage."""
    return _sweep_ell_rk_delta(vals, cols, b, rn, x, d, picks, beta=beta,
                               interpret=_interp(interpret))


__all__ = [
    "banded_gs_sweep",
    "banded_rk_sweep",
    "bbmv",
    "block_gs_sweep",
    "dense_to_bands",
    "spmv_csr",
    "spmv_csr_prefetch",
    "spmv_csr_sliced",
    "spmv_csr_sliced_prefetch",
    "spmv_ell",
    "sweep_ell_gs",
    "sweep_ell_rk",
    "sweep_ell_rk_delta",
    "sweep_rows_gs",
    "sweep_rows_rk",
    "sweep_rows_rk_delta",
]
