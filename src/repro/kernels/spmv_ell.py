"""Pallas kernel: ELLPACK SpMV — the *GPU-style* port, kept for contrast.

This is what a mechanical port of the paper's CPU/GPU sparse access pattern
looks like on TPU: per-row column gathers (``x[cols[i, j]]``).  Gathers do
not stream and do not use the MXU; benchmarks/bench_kernels.py shows the
block-banded layout (kernels/bbmv.py) dominating it — quantifying the
hardware-adaptation argument of DESIGN.md instead of asserting it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, cols_ref, x_ref, o_ref, *, width: int):
    x = x_ref[...]  # (n, k) resident in VMEM
    vals = vals_ref[...]  # (tile, width)
    cols = cols_ref[...].astype(jnp.int32)  # (tile, width) widen compact ids
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(width):  # static unroll over ELL width
        xr = jnp.take(x, cols[:, j], axis=0)  # (tile, k) row gather
        acc += vals[:, j][:, None].astype(jnp.float32) * xr.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def spmv_ell(
    vals: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    *,
    tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x with A in fixed-width ELL form (see core.spd.ell_from_dense).

    vals/cols: (n, width); x: (n, k).
    """
    n, width = vals.shape
    k = x.shape[1]
    assert n % tile == 0

    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=interpret,
    )(vals, cols, x)
