"""Pure-jnp oracle for kernels/banded_gs.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def banded_gs_sweep_ref(A_bands, b, xw, picks, *, block: int, bands: int,
                        beta: float = 1.0):
    width = 2 * bands + 1

    def step(xw, bi):
        acc = jax.lax.dynamic_slice_in_dim(b, bi * block, block, 0).astype(jnp.float32)
        tiles = jax.lax.dynamic_slice_in_dim(A_bands, bi, 1, 0)[0]
        for d in range(width):
            xs = jax.lax.dynamic_slice_in_dim(xw, (bi + d) * block, block, 0)
            acc = acc - jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
        r0 = (bi + bands) * block
        cur = jax.lax.dynamic_slice_in_dim(xw, r0, block, 0)
        return jax.lax.dynamic_update_slice_in_dim(
            xw, cur + beta * acc.astype(xw.dtype), r0, 0), None

    xw, _ = jax.lax.scan(step, xw, picks)
    return xw
