"""Deterministic, shardable, resumable synthetic data pipeline.

Counter-based randomness (numpy Philox — the same random-access property the
paper gets from Random123 in Sec. 8): ``batch_at(step)`` is a pure function
of (seed, step), so

* restart-from-checkpoint reproduces the exact token stream (no state file
  beyond the step counter),
* any host can materialize exactly its shard of the global batch
  (``host_slice``), and
* elastic rescaling re-slices the same global stream.

The synthetic LM stream is a Zipf-ish unigram mix with short-range induced
structure (bigram copy task) so that a real model trains to a loss visibly
below log(vocab) — enough signal for the end-to-end examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs (audio frames / vision patches)
    frames: int = 0
    patches: int = 0
    d_model: int = 0


class SyntheticLM:
    """Random-access synthetic LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: (seed, step) is the 128-bit Philox key — O(1) random
        # access to any step (the paper's Random123 property, Sec. 8)
        return np.random.Generator(np.random.Philox(key=[self.cfg.seed, step]))

    def batch_at(self, step: int, *, lo: int = 0, hi: Optional[int] = None) -> dict:
        """Global batch (or the [lo:hi) slice of it) at ``step``."""
        c = self.cfg
        hi = c.global_batch if hi is None else hi
        rng = self._rng(step)
        v = c.vocab_size
        # Zipf-ish unigrams over the full vocab...
        base = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1)) % v
        # ...with a copy structure: with p=0.5, token t+1 repeats token t-1.
        copy = rng.random((c.global_batch, c.seq_len + 1)) < 0.5
        seq = base.copy()
        seq[:, 2:] = np.where(copy[:, 2:], seq[:, :-2], base[:, 2:])
        seq = seq[lo:hi].astype(np.int32)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if c.frames:
            batch["frames"] = rng.standard_normal(
                (hi - lo, c.frames, c.d_model), dtype=np.float32)
        if c.patches:
            batch["patches"] = rng.standard_normal(
                (hi - lo, c.patches, c.d_model), dtype=np.float32)
            # patch positions carry no next-token target
            batch["labels"][:, : c.patches] = -1
        return batch

    def host_slice(self, step: int, host_id: int, num_hosts: int) -> dict:
        per = self.cfg.global_batch // num_hosts
        return self.batch_at(step, lo=host_id * per, hi=(host_id + 1) * per)
