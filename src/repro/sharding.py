"""Sharding policy: logical placement rules -> PartitionSpecs.

Physical mesh axes (DESIGN.md §5):
  "pod"    outer data parallelism across pods (never shards weights)
  "data"   inner data parallelism; also the FSDP axis for weights
  "model"  tensor parallelism (column/row parallel, experts, vocab)

Two objects drive every placement decision:

* ``ShardCtx`` — static divisibility-aware rules used at *init* time to build
  the parameter PartitionSpec pytree.  A dimension is only sharded when the
  axis size divides it; otherwise it silently falls back to replication (the
  caller can inspect the produced spec).  ``ShardCtx(1, 1)`` (the default for
  CPU tests) replicates everything.

* ``Partitioner`` — runtime helper bound to a mesh that applies activation
  sharding constraints (``with_sharding_constraint``) and knows the dp/tp
  axis names.  A ``Partitioner(None)`` is a no-op so model code can call it
  unconditionally.

Per-arch attention parallelism (DESIGN.md §5): heads divisible by the model
axis -> Megatron TP; otherwise -> sequence parallelism (activations sharded
over S on the model axis, attention weights replicated on "model" but FSDP
on "data").  ``ShardCtx.attn_tp(cfg)`` makes that call.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    """Static parameter-placement rules."""

    tp: int = 1                       # size of the "model" axis
    dp: int = 1                       # size of the "data" axis (FSDP)
    fsdp: bool = True                 # shard weights over "data" too

    def col(self, dim: int) -> Optional[str]:
        """Tensor-parallel (column/row) placement for an out/in feature dim."""
        return "model" if self.tp > 1 and dim % self.tp == 0 else None

    def data(self, dim: int) -> Optional[str]:
        """FSDP placement for the complementary weight dim."""
        return "data" if self.fsdp and self.dp > 1 and dim % self.dp == 0 else None

    def dense_col(self, d_in: int, d_out: int) -> P:
        """(d_in, d_out) weight, column-parallel on d_out."""
        c = self.col(d_out)
        d = self.data(d_in)
        if c is None and d is None and self.fsdp and self.dp > 1:
            # keep at least FSDP on the out dim if the in dim doesn't divide
            return P(None, self.data(d_out))
        return P(d, c)

    def dense_row(self, d_in: int, d_out: int) -> P:
        """(d_in, d_out) weight, row-parallel on d_in."""
        return P(self.col(d_in), self.data(d_out))

    def replicated_fsdp(self, d_in: int) -> P:
        """No TP (e.g. head count not divisible): FSDP on dim 0 only."""
        return P(self.data(d_in), None)

    def vec(self, dim: int) -> P:
        """1-D bias/scale aligned with a column-parallel out dim."""
        return P(self.col(dim))

    def attn_tp(self, n_heads: int, n_kv: int) -> bool:
        """True -> Megatron TP attention; False -> sequence-parallel."""
        del n_kv  # KV replication is decided separately (kv_col)
        return self.tp == 1 or n_heads % self.tp == 0

    def kv_col(self, n_kv: int, head_dim: int) -> Optional[str]:
        return "model" if self.tp > 1 and n_kv % self.tp == 0 else None


@dataclass(frozen=True)
class Partitioner:
    """Runtime activation-sharding helper.  ``mesh=None`` -> no-op."""

    mesh: Optional[Mesh] = None
    dp_axes: tuple[str, ...] = ("data",)   # ("pod", "data") multi-pod
    tp_axis: str = "model"
    sc: ShardCtx = field(default_factory=ShardCtx)

    @property
    def dp(self):
        return tuple(self.dp_axes) if self.mesh is not None else None

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # -- common activation layouts ------------------------------------------
    def tokens(self, x):                       # (B, S)
        return self.constrain(x, P(self.dp, None))

    def hidden(self, x):                       # (B, S, D) TP region
        return self.constrain(x, P(self.dp, None, None))

    def hidden_sp(self, x):                    # (B, S, D) sequence-parallel region
        return self.constrain(x, P(self.dp, self.tp_axis, None))

    def heads(self, x, n_heads: int):          # (B, S, H, Hd)
        c = self.sc.col(n_heads) if self.sc.tp > 1 else None
        return self.constrain(x, P(self.dp, None, c, None))

    def ffn_hidden(self, x, f: int):           # (B, S, F) column-parallel
        return self.constrain(x, P(self.dp, None, self.sc.col(f)))

    def logits(self, x, vocab: int):           # (B, S, V) vocab-sharded
        return self.constrain(x, P(self.dp, None, self.sc.col(vocab)))


def named(mesh: Optional[Mesh], spec: P):
    """NamedSharding or None (for jit in_shardings on an inactive mesh)."""
    return None if mesh is None else NamedSharding(mesh, spec)


def spec_tree_to_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
