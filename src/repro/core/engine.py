"""The single solver engine behind the RGS/RK stack (DESIGN.md §4).

The paper's algorithms are one family: pick a random direction (coordinate,
aligned block, or row), compute the residual along it from a possibly-stale
iterate, apply a damped update, and synchronize periodically so the
staleness stays bounded by a *scheduled* tau.  Pre-refactor, that family
was six divergent hand-rolled loops; this module is the one implementation,
parameterized along three orthogonal axes:

* **action**  — what a local update does: ``"gs"`` (coordinate / block
  Gauss-Seidel on an SPD system) or ``"rk"`` (Kaczmarz row action on a
  square or rectangular system);
* **format**  — how the matrix is stored and read, via the operator layer
  (``repro.core.operators``: dense, block-banded, ELL);
* **schedule** — when updates become visible: sequential (tau = 0), the
  bounded-delay *simulator* of Secs. 4/6 (ring-buffer exact stale reads),
  or the distributed periodic-synchronization scheme of Thm 4.1(a) over a
  shard_map worker mesh, with the sync collective (all-gather vs neighbor
  halo exchange vs delta psum) chosen from the operator's halo width.

The legacy entry points (``rgs_solve``, ``block_gs_solve``,
``parallel_rgs_solve``/``_banded``/``_halo``, ``rk_solve``,
``parallel_rk_solve``, ``async_rgs_solve``, ``async_rk_solve``) are thin
wrappers over this engine and reproduce their pre-refactor iterates
bit-for-bit given the same PRNG keys — the update arithmetic below is
transplanted verbatim, operation order included, and the equivalence is
pinned by tests/test_engine_equivalence.py against frozen legacy copies.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.core.operators import (
    BlockBandedOp,
    CsrOp,
    DenseOp,
    EllOp,
    as_operator,
    banded_panel_residual,
    banded_panel_residual_window,
    banded_rows_matvec,
    banded_window_matvec,
)
from repro.optim import compression
from repro.tune import runtime as tune_runtime


# ---------------------------------------------------------------------------
# Capability vocabularies — the single source every validation error, CLI
# ``choices=``, and repro-lint's dispatch checker (DX4) read from.  The
# strategy-level capability sets (_FUSED_/_OVERLAP_/_COMPRESS_STRATEGIES)
# live next to _DISTRIBUTED_STRATEGIES below.
# ---------------------------------------------------------------------------

#: distributed slab-assignment policies understood by ``Schedule.partition``
PARTITIONS = ("contiguous", "balanced")

#: wire codecs understood by ``Schedule.compress`` (error-feedback int8 and
#: round-to-nearest bf16; "none" is the exact f32 wire)
COMPRESS_MODES = ("none", "bf16", "int8_ef")


def supported_syncs(action, formats=None):
    """Sync modes ``_DISTRIBUTED_STRATEGIES`` has a row for.

    ``formats`` (operator class names) narrows the answer to the formats a
    caller can actually build — launchers use this for ``choices=`` so the
    CLI surface can never drift from the dispatch table.
    """
    return tuple(sorted({
        s for (a, f, s) in _DISTRIBUTED_STRATEGIES
        if a == action and (formats is None or f in formats)}))


# ---------------------------------------------------------------------------
# Result types (re-exported by repro.core.rgs / repro.core.parallel_rgs)
# ---------------------------------------------------------------------------

class SolveResult(NamedTuple):
    x: jax.Array           # (n, k) final iterate
    err_sq: jax.Array      # (records, k) squared error at each record point
    resid: jax.Array       # (records, k) ||b - A x_m||_2 at each record point
    iters: jax.Array       # (records,) iteration index of each record


class ParallelSolveResult(NamedTuple):
    x: jax.Array        # (n, k)
    err_sq: jax.Array   # (rounds, k)
    resid: jax.Array    # (rounds, k)
    tau: int            # effective staleness bound of the schedule
    #: per-round measured exchange lag (overlap=True only, else None):
    #: lag[r] = max over workers of the foreign updates committed by the end
    #: of round r-1 that the worker's round-r reads do NOT see.  The
    #: empirical staleness of a run is ``max(lag) + scheduled_tau(...)``
    #: with ``overlap=False`` (the in-round term), which the schedule
    #: guarantees is <= ``scheduled_tau(..., overlap=True)`` == ``tau``.
    lag: jax.Array | None = None
    #: analytic per-round wire volume (bytes one worker contributes to the
    #: sync collective each round, averaged over workers for the
    #: participation-asymmetric a2a exchanges), computed host-side from the
    #: dispatched strategy, sync and compress mode — None outside
    #: ``solve_distributed``.  This is the model quantity the compressed
    #: syncs shrink; benchmarks report it next to iterations-to-tolerance.
    bytes_per_round: float | None = None


# ---------------------------------------------------------------------------
# Schedule layer
# ---------------------------------------------------------------------------

def scheduled_tau(num_workers: int, local_steps: int, *,
                  shared_stream: bool = False,
                  local_sampling: bool = False,
                  overlap: bool = False) -> int:
    """Staleness bound of the periodic-synchronization schedule.

    ``shared_stream=False`` (per-worker direction streams, the RGS scheme):
    a worker's read can miss every other worker's in-round updates, so
    tau = (P - 1) * local_steps — the paper's Thm 4.1(a) bound.

    ``shared_stream=True`` (one global i.i.d. pick stream partitioned by
    owner, the RK scheme): within a round a pick misses at most the other
    workers' *earlier* in-round updates, so tau = local_steps - 1 (and 0 at
    P = 1, where every pick is owned and nothing is ever stale).

    ``local_sampling=True`` (per-worker local sampling, the sparse-RK
    scheme): every worker's ``local_steps`` picks are useful updates, so
    the round's interleaved shared stream carries P * local_steps picks
    and the shared-stream bound applies to that length —
    tau = P * local_steps - 1.  This is the single source of truth for the
    rule; the engine, CLIs, and benchmarks all route through it.

    ``overlap=True`` (double-buffered sync, ``Schedule(overlap=True)``):
    round r's exchange is issued concurrently with round r+1's local
    sweep, so a worker's reads additionally miss the *previous round's*
    foreign updates — the bound grows by exactly that payload:

    * per-worker streams: the other P-1 workers' full previous round,
      + (P - 1) * local_steps;
    * shared stream: the whole previous round of the stream,
      + local_steps;
    * local sampling: the other workers' previous-round picks,
      + (P - 1) * local_steps.

    At P = 1 there is nothing in flight and the term is 0.
    """
    extra = 0
    if overlap and num_workers > 1:
        if local_sampling or not shared_stream:
            extra = (num_workers - 1) * local_steps
        else:
            extra = local_steps
    if local_sampling:
        shared_stream = True
        local_steps = num_workers * local_steps
    if shared_stream:
        return extra + (0 if num_workers == 1 else local_steps - 1)
    return extra + (num_workers - 1) * local_steps


class Schedule(NamedTuple):
    """Execution plan for ``solve``: exactly one of the three modes.

    * sequential:  ``num_iters`` > 0, ``tau`` == 0, ``rounds`` == 0
    * async sim:   ``num_iters`` > 0, ``tau``  > 0  (bounded-delay model)
    * distributed: ``rounds`` > 0 and ``local_steps`` > 0 (needs a mesh)

    ``partition`` picks the distributed slab assignment: ``"contiguous"``
    (rows in index order — the default, and the only choice for the
    dense/banded layouts) or ``"balanced"`` (norm/nnz-balanced
    non-contiguous assignment via a row permutation, ``core.partition``;
    CsrOp/EllOp only).

    ``fused`` runs the inner loop (the per-record chunk in sequential
    mode, the local phase of a distributed round) as a single fused Pallas
    sweep kernel — the iterate VMEM-resident across all steps, the pick
    stream scalar-prefetched — instead of a per-step ``lax.scan``.
    Action × format combinations without a sweep kernel fall back to the
    scan engine with a ``UserWarning``; supported combinations produce
    iterates matching the scan engine (GS bitwise, RK to roundoff).
    ``fused="auto"`` defers the pick to the active tuning table
    (``repro.tune``): the measured fused-vs-scan winner for this
    format × action × shape bucket on the current backend runs; with no
    table entry — or where the strategy has no fused kernel to pick —
    it resolves to the scan engine (today's default) with no warning,
    bitwise-unchanged.  Explicit booleans are never overridden.

    ``overlap`` (distributed only) double-buffers the sync: round r's
    halo / a2a / delta exchange is issued concurrently with round r+1's
    local sweep, so workers read one-round-staler remote slabs and the
    scheduled staleness grows by the quantified overlap term of
    ``scheduled_tau``.  Strategies without an overlapped variant fall
    back to lockstep rounds with a ``UserWarning`` (exact fallback).

    ``compress`` (distributed only) picks the wire format of the sync
    payload: ``"none"`` (f32, the default — bitwise-unchanged engine),
    ``"bf16"`` (payload rounded to bfloat16 on the wire), or
    ``"int8_ef"`` (int8 blocks + f32 scales via ``optim.compression``;
    the RK delta sync carries a per-worker error-feedback residual as
    loop state, flushed after the final round so the returned iterate
    contains every update).  Strategies without a compressed wire —
    everything but the RK delta psum and the banded halo exchange — fall
    back to the f32 payload with a ``UserWarning`` (exact fallback).
    """
    num_iters: int = 0
    rounds: int = 0
    local_steps: int = 0
    tau: int = 0
    record_every: int = 0
    partition: str = "contiguous"
    fused: bool | str = False
    overlap: bool = False
    compress: str = "none"

    @property
    def distributed(self) -> bool:
        return self.rounds > 0

    def validate(self) -> "Schedule":
        """Reject ambiguous mode mixtures with a message naming the fields.

        A Schedule carrying both ``num_iters > 0`` and ``rounds > 0`` has no
        single meaning (would it run 'num_iters' iterations, or 'rounds'
        synchronization rounds?), so it is an error rather than a silent
        choice.
        """
        if self.distributed and (self.num_iters > 0 or self.tau > 0):
            raise ValueError(
                "ambiguous Schedule: rounds/local_steps (distributed) "
                "cannot be combined with num_iters/tau (sequential / async "
                f"simulator) — got {self}")
        if self.distributed and self.local_steps <= 0:
            raise ValueError(
                f"a distributed Schedule needs local_steps > 0 (got {self})")
        if self.partition not in PARTITIONS:
            raise ValueError(
                f"unknown partition: {self.partition!r} (expected one of "
                f"{PARTITIONS})")
        if self.compress not in COMPRESS_MODES:
            raise ValueError(
                f"unknown compress: {self.compress!r} (expected one of "
                f"{COMPRESS_MODES})")
        if self.fused not in (False, True, "auto"):
            raise ValueError(
                f"unknown fused: {self.fused!r} (expected True, False or "
                f"'auto' — the tuning-table pick; got {self})")
        if not self.distributed:
            if self.num_iters <= 0:
                raise ValueError(
                    "a sequential/async Schedule needs num_iters > 0 "
                    f"(got {self})")
            if self.local_steps > 0:
                raise ValueError(
                    "local_steps without rounds is ambiguous — set rounds > 0 "
                    f"for distributed execution (got {self})")
            if self.partition != "contiguous":
                raise ValueError(
                    "partition='balanced' is a distributed-schedule option "
                    f"(slab assignment needs rounds/local_steps) — got {self}")
            if self.overlap:
                raise ValueError(
                    "overlap=True is a distributed-schedule option (the "
                    "double-buffered sync needs rounds/local_steps) — got "
                    f"{self}")
            if self.fused is True and self.tau > 0:
                # fused="auto" is fine here: the simulator has no fused
                # path for the table to pick, so auto resolves to the
                # per-step engine — nothing was forced, nothing to reject.
                raise ValueError(
                    "fused=True cannot run the bounded-delay simulator "
                    "(its ring-buffer stale reads are inherently per-step; "
                    "there is no fused sweep path to fall back from) — use "
                    f"fused=False or a different schedule mode; got {self}")
            if self.compress != "none":
                raise ValueError(
                    "compress is a distributed-schedule option (there is no "
                    "sync payload to compress without rounds/local_steps) — "
                    f"got {self}")
        return self

    def effective_tau(self, num_workers: int, *, shared_stream: bool = False,
                      local_sampling: bool = False) -> int:
        if self.distributed:
            return scheduled_tau(num_workers, self.local_steps,
                                 shared_stream=shared_stream,
                                 local_sampling=local_sampling,
                                 overlap=self.overlap)
        return self.tau


# ---------------------------------------------------------------------------
# Shared metrics/recording subsystem (replaces _record / _record_lsq /
# the inline banded metric blocks)
# ---------------------------------------------------------------------------

def record_metrics(op, b, x, x_star, *, norm: str):
    """(err_sq, resid) per RHS column.

    ``norm="A"``: ||x - x*||_A^2 (the SPD family's Lyapunov function);
    ``norm="euclid"``: ||x - x*||_2^2 (rectangular systems have no A-norm).
    ``resid`` is always ||b - A x||_2.

    ``x_star=None`` (a real workload: nobody knows the solution) yields
    NaN ``err_sq`` and the finite residual — the same convention the
    distributed strategies adopted in the PR-6 crash sweep.
    """
    if norm not in ("A", "euclid"):
        raise ValueError(norm)
    mv = getattr(op, "matvec_ref", op.matvec)
    if x_star is None:
        err = jnp.full((x.shape[1],), jnp.nan, jnp.float32)
    else:
        e = x - x_star
        if norm == "A":
            err = jnp.einsum("nk,nk->k", e, mv(e))
        else:
            err = jnp.einsum("nk,nk->k", e, e)
    return err, jnp.linalg.norm(b - mv(x), axis=0)


def resolve_record_every(num_iters: int, record_every: int) -> int:
    """The effective record-chunk length, validated in ONE place.

    ``record_every == 0`` means "record once, at the end".  The
    divisibility error used to exist in four near-identical copies across
    the sequential / fused / simulator bodies; the serving layer's
    deadline / early-exit logic reuses this same check for its chunk math,
    so the message can never drift between the library and the service.
    """
    rec = record_every or num_iters
    if num_iters % rec != 0:
        raise ValueError(
            f"num_iters ({num_iters}) must be divisible by record_every "
            f"({rec})")
    return rec


def draw_picks(op, action: str, key: jax.Array, num_iters: int, *,
               block: int = 1) -> jax.Array:
    """The sequential engine's direction stream, as one shared definition.

    GS picks are uniform over the action's direction count (block rows for
    ``BlockBandedOp``, coordinates at ``block == 1``, aligned panels
    otherwise); RK rows are sampled ∝ ||A_i||^2 via ``sample_rows``.  Both
    the one-shot sequential impls and the chunked batched entry
    (``solve_batched``) draw from here, which is what makes a chunked run
    bitwise-reproduce the one-shot pick stream.
    """
    if action == "gs":
        if isinstance(op, BlockBandedOp):
            hi = op.nb
        elif block == 1:
            hi = op.shape[0]
        else:
            hi = op.shape[0] // block
        return jax.random.randint(key, (num_iters,), 0, hi)
    if action == "rk":
        return sample_rows(key, op.row_norms_sq(), num_iters)
    raise ValueError(f"unknown action: {action!r}")


def sample_rows(key: jax.Array, rn: jax.Array, num: int) -> jax.Array:
    """``num`` i.i.d. row indices with P(i) ∝ rn_i (zero rows never picked).

    An all-zero ``rn`` (an empty shard after slab partitioning) would turn
    every logit into -inf and make ``categorical`` return garbage; the
    defined behavior here is *uniform* sampling instead — the callers guard
    the corresponding updates (zero rows make them no-ops), so distributed
    pick scheduling stays well-defined on degenerate slabs.
    """
    pos = rn > 0
    logits = jnp.where(pos, jnp.log(jnp.where(pos, rn, 1.0)), -jnp.inf)
    logits = jnp.where(jnp.any(pos), logits, jnp.zeros_like(logits))
    return jax.random.categorical(key, logits, shape=(num,))


# ---------------------------------------------------------------------------
# Sequential engine
# ---------------------------------------------------------------------------

def _fused_sweep_supported(op, action: str, block: int) -> bool:
    """Whether a fused sweep kernel exists for this action x format.

    The sweep layer covers the banded block-GS action (kernels/banded_gs),
    and the padded-row coordinate-GS / Kaczmarz actions for CsrOp / EllOp
    (kernels/sweep_csr, kernels/sweep_ell).  Dense formats and block > 1
    row-panel GS stay on the scan engine.
    """
    if action == "gs":
        if isinstance(op, BlockBandedOp):
            return True
        return block == 1 and isinstance(op, (CsrOp, EllOp))
    if action == "rk":
        return isinstance(op, (CsrOp, EllOp))
    return False


def _warn_fused_fallback(op, action, detail=""):
    warnings.warn(
        f"fused=True: no fused sweep kernel for action={action!r} x "
        f"{type(op).__name__}{detail}; falling back to the per-step scan "
        "engine", UserWarning, stacklevel=3)


def _warn_overlap_fallback(op, action, kind):
    warnings.warn(
        f"overlap=True: the {kind!r} strategy (action={action!r} x "
        f"{type(op).__name__}) has no overlapped-sync variant; running "
        "lockstep rounds (exact fallback — iterates unchanged)",
        UserWarning, stacklevel=3)


def _warn_compress_fallback(op, action, kind, compress):
    warnings.warn(
        f"compress={compress!r}: the {kind!r} strategy (action={action!r} x "
        f"{type(op).__name__}) has no compressed wire format; running the "
        "f32 payload (exact fallback — iterates unchanged)",
        UserWarning, stacklevel=3)


def solve_sequential(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    action: str,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    block: int = 1,
    record_every: int = 0,
    fused: bool | str = False,
) -> SolveResult:
    """Sequential randomized solve: one local-update step per iteration.

    action "gs":  coordinate (block=1) or aligned-block Gauss-Seidel on a
                  unit-diagonal SPD system; directions uniform.
    action "rk":  Kaczmarz row action; rows sampled ∝ ||A_i||^2.

    ``fused=True`` executes each record chunk as one fused Pallas sweep
    (the operator's ``gs_sweep``/``rk_sweep`` entry point: iterate
    VMEM-resident, picks scalar-prefetched) instead of a per-step
    ``lax.scan``; the pick stream and update arithmetic are shared, so
    iterates match the scan engine (GS bitwise, RK to roundoff).  Formats
    without a sweep kernel fall back to the scan with a ``UserWarning``.
    ``fused="auto"`` runs the tuning table's measured winner where a
    sweep kernel exists, the scan otherwise — silently, since nothing
    was forced (see ``Schedule``).
    """
    if fused == "auto":
        fused = (_fused_sweep_supported(op, action, block)
                 and tune_runtime.resolve_fused(fused, op, action))
    if fused:
        if _fused_sweep_supported(op, action, block):
            return _sequential_fused_impl(
                op, b, x0, x_star, action=action, key=key,
                num_iters=num_iters, beta=float(beta), block=block,
                record_every=record_every)
        _warn_fused_fallback(
            op, action, f" with block={block}" if block != 1 else "")
    return _sequential_scan_impl(
        op, b, x0, x_star, action=action, key=key, num_iters=num_iters,
        beta=beta, block=block, record_every=record_every)


@functools.partial(
    jax.jit,
    static_argnames=("action", "num_iters", "block", "record_every", "beta"))
def _sequential_fused_impl(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    action: str,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    block: int = 1,
    record_every: int = 0,
    picks: jax.Array | None = None,
) -> SolveResult:
    """Fused-sweep twin of ``_sequential_scan_impl``: identical pick
    streams and record points, but each record chunk runs as a single
    Pallas launch.  ``picks`` overrides the internally drawn direction
    stream (the chunked ``solve_batched`` entry feeds pre-drawn slices so
    a chunked run replays the one-shot stream bitwise).

    ``beta`` is DELIBERATELY static here (its scan twin traces it): the
    sweep kernels bake the step size into the kernel body as a
    compile-time constant — a scalar operand would ride the scalar-
    prefetch channel and change every kernel's signature for a value
    that is fixed for the lifetime of a solve.  The visible consequence
    is one recompilation per distinct ``beta``; solves sweep few betas
    (one, or theory.beta_opt per tau), so the cache stays small.  The
    contract is pinned by a compile-count test
    (tests/test_engine_overlap.py::test_fused_beta_static_recompiles).
    """
    rec = resolve_record_every(num_iters, record_every)

    if action == "gs":
        norm = "A"
        if picks is None:
            picks = draw_picks(op, action, key, num_iters, block=block)

        def sweep(x, ps):
            return op.gs_sweep(b, x, ps, beta=beta)
    elif action == "rk":
        norm = "euclid"
        rn = op.row_norms_sq()
        if picks is None:
            picks = draw_picks(op, action, key, num_iters, block=block)

        def sweep(x, ps):
            return op.rk_sweep(b, rn, x, ps, beta=beta)
    else:
        raise ValueError(f"unknown action: {action!r}")

    def chunk(x, ps):
        # The sweep entry points rebuild their loop-invariant operator
        # views (packed band tiles / padded row windows) per record chunk
        # — accepted: record chunks are few, the views are cheap relative
        # to a chunk's sweep, and keeping preparation inside the operator
        # method is what lets a new format plug in with one method.
        x = sweep(x, ps)
        return x, record_metrics(op, b, x, x_star, norm=norm)

    x, (errs, resids) = jax.lax.scan(chunk, x0, picks.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


@functools.partial(
    jax.jit, static_argnames=("action", "num_iters", "block", "record_every"))
def _sequential_scan_impl(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    action: str,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    block: int = 1,
    record_every: int = 0,
    picks: jax.Array | None = None,
) -> SolveResult:
    """The per-step scan engine (the pre-PR-5 ``solve_sequential`` body —
    the legacy bit-identity contract lives here; the pick draws now route
    through ``draw_picks``, same streams bitwise).  ``picks`` overrides
    the drawn stream — see ``_sequential_fused_impl``."""
    rec = resolve_record_every(num_iters, record_every)

    if action == "gs":
        norm = "A"
        if isinstance(op, BlockBandedOp):
            # Θ(nnz) block-GS on the banded format (new capability: the
            # sequential twin of the banded distributed path).
            bsz = op.block

            def step(x, bi):
                g = op.residual_panel(b, x, bi)
                cur = jax.lax.dynamic_slice_in_dim(x, bi * bsz, bsz, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    x, cur + beta * g, bi * bsz, 0), None
        elif block == 1:
            def step(x, r):
                gamma = b[r] - op.row_dot(r, x)
                return x.at[r].add(beta * gamma), None
        else:
            if not isinstance(op, (DenseOp, CsrOp)):
                raise NotImplementedError(
                    "block GS with block > 1 needs aligned row panels "
                    "(DenseOp/CsrOp) or BlockBandedOp")

            def step(x, bi):
                rows = bi * block + jnp.arange(block)
                Ab = op.row_panel(bi, block)
                gamma = b[rows] - Ab @ x
                return x.at[rows].add(beta * gamma), None
    elif action == "rk":
        if not isinstance(op, (DenseOp, EllOp, CsrOp)):
            raise NotImplementedError(
                "sequential RK needs per-row access (DenseOp/EllOp/CsrOp); "
                "the banded Kaczmarz path runs through solve_distributed")
        norm = "euclid"
        rn = op.row_norms_sq()

        def step(x, r):
            g = (b[r] - op.row_dot(r, x)) / rn[r]
            return op.rk_update(x, r, g, beta), None
    else:
        raise ValueError(f"unknown action: {action!r}")

    if picks is None:
        picks = draw_picks(op, action, key, num_iters, block=block)

    def chunk(x, ps):
        x, _ = jax.lax.scan(step, x, ps)
        return x, record_metrics(op, b, x, x_star, norm=norm)

    x, (errs, resids) = jax.lax.scan(chunk, x0, picks.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


# ---------------------------------------------------------------------------
# Bounded-delay asynchronous simulator (the paper's Secs. 4/6 read models)
# ---------------------------------------------------------------------------

def solve_async_sim(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    action: str,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    """Faithful simulator of delays bounded by ``tau`` (consistent and
    inconsistent reads), for both the coordinate ("gs") and row ("rk")
    actions.  Mechanics: a ring buffer of the last ``tau`` applied updates
    (direction index, applied amount); the stale read is reconstructed
    exactly via

        A_r x_stale = A_r x - sum_{t invisible} c_t * w(r, r_t)

    where the correction weight ``w`` is the coupling ``A[r, r_t]`` for the
    coordinate action and the row inner product ``<A_r, A_{r_t}>`` for the
    row action.  Delay schedules are drawn from ``delay_key``, independent
    of the direction key (Assumption A-4).

    Sparse operators are **densified exactly** (and a ``UserWarning`` is
    emitted): the ring-buffer correction needs arbitrary ``A[r, r_t]``
    couplings and row inner products, so the simulator — a research tool
    for delay models, not a performance path — runs Θ(n) reads per step
    regardless of the format's ``nnz_cost()``.  Use ``solve_distributed``
    for the sparse-aware execution of the same schedules.
    """
    if not isinstance(op, DenseOp):
        if not hasattr(op, "to_dense"):
            raise NotImplementedError(
                f"the async simulator needs a densifiable operator "
                f"(got {type(op).__name__})")
        # to_dense() reconstructs the stored values bit-for-bit, so the
        # simulated iterates are exact — only the cost model changes.
        warnings.warn(
            f"solve_async_sim densifies {type(op).__name__} exactly: the "
            "bounded-delay simulator ignores the format's nnz_cost() and "
            "pays dense Θ(n) row reads per step (it is a research tool for "
            "delay models, not a sparse performance path — use "
            "solve_distributed for sparse-aware execution)",
            UserWarning, stacklevel=2)
        op = DenseOp(op.to_dense())
    return _async_sim_impl(
        op, b, x0, x_star, action=action, key=key, delay_key=delay_key,
        num_iters=num_iters, tau=tau, beta=beta, read_model=read_model,
        delay_mode=delay_mode, miss_prob=miss_prob,
        record_every=record_every)


@functools.partial(
    jax.jit,
    static_argnames=("action", "num_iters", "tau", "record_every",
                     "read_model", "delay_mode"),
)
def _async_sim_impl(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    action: str,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    A = op.A
    k = b.shape[1]
    rec = resolve_record_every(num_iters, record_every)
    t_buf = max(tau, 1)

    if action == "gs":
        norm = "A"
        picks = jax.random.randint(key, (num_iters,), 0, A.shape[0])
    elif action == "rk":
        norm = "euclid"
        rn = op.row_norms_sq()
        picks = sample_rows(key, rn, num_iters)
    else:
        raise ValueError(f"unknown action: {action!r}")

    if read_model == "consistent":
        if delay_mode == "fixed":
            aux = jnp.full((num_iters,), tau, jnp.int32)
        elif delay_mode == "uniform":
            aux = jax.random.randint(delay_key, (num_iters,), 0, tau + 1)
        elif delay_mode == "cyclic":
            aux = (jnp.arange(num_iters) % (tau + 1)).astype(jnp.int32)
        else:
            raise ValueError(delay_mode)
    elif read_model == "inconsistent":
        aux = jax.random.bernoulli(delay_key, miss_prob, (num_iters, t_buf))
    else:
        raise ValueError(read_model)

    ring_r0 = jnp.zeros((t_buf,), jnp.int32)
    ring_c0 = jnp.zeros((t_buf, k), x0.dtype)
    offsets = jnp.arange(t_buf)

    def step(carry, inp):
        x, ring_r, ring_c, j = carry
        r, a = inp
        it_idx = j - 1 - offsets              # iteration indices, newest first
        valid = it_idx >= 0
        if read_model == "consistent":
            invisible = (offsets < a) & valid  # suffix of length s_j
        else:
            invisible = a & valid & (offsets < tau)  # subset of last tau
        slots = jnp.mod(it_idx, t_buf)
        rs = ring_r[slots]                     # (t_buf,)
        cs = ring_c[slots]                     # (t_buf, k) applied amounts
        if action == "gs":
            w = jnp.where(invisible, A[r, rs], 0.0)
            corr = w @ cs
            gamma = b[r] - A[r] @ x + corr
            applied = beta * gamma
            x = x.at[r].add(applied)
        else:
            w = jnp.where(invisible, A[rs] @ A[r], 0.0)
            corr = w @ cs
            gamma = (b[r] - A[r] @ x + corr) / rn[r]
            applied = beta * gamma
            x = x + A[r][:, None] * applied[None, :]
        ring_r = ring_r.at[jnp.mod(j, t_buf)].set(r)
        ring_c = ring_c.at[jnp.mod(j, t_buf)].set(applied)
        return (x, ring_r, ring_c, j + 1), None

    def chunk(carry, inp):
        carry, _ = jax.lax.scan(step, carry, inp)
        return carry, record_metrics(op, b, carry[0], x_star, norm=norm)

    inps = (picks.reshape(-1, rec), aux.reshape((-1, rec) + aux.shape[1:]))
    carry = (x0, ring_r0, ring_c0, jnp.array(0, jnp.int32))
    carry, (errs, resids) = jax.lax.scan(chunk, carry, inps)
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=carry[0], err_sq=errs, resid=resids, iters=iters)


# ---------------------------------------------------------------------------
# Distributed driver (shard_map): one skeleton, five action×format×sync
# strategies.  The update arithmetic of the four legacy strategies is
# transplanted verbatim — bit-identity, not mere closeness, is tested.
# ---------------------------------------------------------------------------

def solve_distributed(
    op,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array | None = None,
    *,
    action: str = "gs",
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 1,
    beta: float = 1.0,
    sync: str = "auto",
    partition: str = "contiguous",
    fused: bool | str = False,
    overlap: bool = False,
    compress: str = "none",
    unroll: bool = False,
    with_metrics: bool = True,
) -> ParallelSolveResult:
    """P-way asynchronous solve under the periodic-synchronization schedule.

    ``fused=True`` runs each round's local phase (the ``local_steps``
    sequential updates between synchronizations) as one fused Pallas sweep
    on the banded strategies — banded GS under both the all-gather and
    halo syncs (``kernels/banded_gs.banded_gs_sweep``, bitwise-identical
    iterates) and banded RK (``banded_rk_sweep``, the masked
    Cimmino-within-panel action over VMEM-resident window + delta
    carries) — and on the sparse strategies: sparse slab GS
    (``sweep_rows_gs`` with the slab's traced write base scalar-
    prefetched; bitwise-identical iterates) and sparse local-sampling RK
    (``sweep_rows_rk_delta``, the two-carry replica+delta sweep, iterates
    to roundoff).  Strategies without a fused local phase fall back to
    the per-step scan with a ``UserWarning``.

    ``overlap=True`` double-buffers the synchronization: round r's
    exchange payload (halo edges / slab rotations / round delta) is the
    one captured at the END of round r-1, so the collective has no data
    dependency on round r's sweep and XLA is free to run them
    concurrently — workers read remote state that is one round staler,
    and the scheduled tau grows by ``scheduled_tau``'s overlap term.
    Overlapped variants exist for the ``halo_gs``, ``sparse_gs`` and
    ``sparse_rk`` strategies (``_OVERLAP_STRATEGIES``); others fall back
    to lockstep rounds with a ``UserWarning`` (exact fallback).  The
    result's ``lag`` field then carries the measured per-round staleness
    trace (see ``ParallelSolveResult``).

    The sync collective is chosen from the operator's layout metadata when
    ``sync="auto"``: a finite halo (block-banded) means neighbor halo
    exchange suffices for the GS action; unstructured-but-sparse formats
    that answer slab-neighbor queries (CSR, ELL) get the neighbor
    all-to-all for both actions; unbounded reach (dense) needs an
    all-gather of slab deltas for GS and a delta psum for RK.

    ``sync="a2a"`` with the GS action exchanges each worker's slab only
    along the row-slab neighbor graph derived from the sparsity pattern
    (one masked ppermute rotation per distinct slab offset); when the graph
    is dense — every worker reads every slab — it falls back to the
    all-gather, which moves the same bytes with one collective.  With the
    RK action it replaces the dense delta psum with a two-phase exchange
    over the *column-slab* neighbor graph (reduce each column slab's deltas
    to its owner, then broadcast the sum back to the slab's readers),
    bitwise-identical to the psum; it falls back to the psum when the
    column graph is dense or the column count does not divide by P.

    ``partition="balanced"`` replaces the contiguous slab assignment with
    the norm/nnz-balanced row permutation of ``core.partition`` (CsrOp /
    EllOp): the operator, b (and, for the coordinate action, the iterate
    vectors) are permuted up front, every downstream slab is contiguous
    again, and the returned iterate is un-permuted.

    ``compress`` shrinks the sync payload on the wire (see ``Schedule``):
    the RK delta psum sends the round delta as bf16 or int8+error-feedback
    (``sparse_rk``; foreign replicas see compressed deltas, a worker's own
    updates stay exact, and the int8 residual is flushed after the last
    round so the returned iterate misses nothing), and the banded halo
    exchange sends its edge payloads quantized per round (``halo_gs``; the
    edges are *state*, re-sent fresh every round, so the error does not
    compound and no feedback term is needed — and the owned slab the
    worker returns is never compressed).  Strategies without a compressed
    wire fall back to f32 with a ``UserWarning``; ``sparse_rk`` under
    ``sync="a2a"`` falls back to the psum wire (with a warning), because
    the a2a exchange's bitwise-psum invariant cannot survive a lossy
    payload.  The analytic per-round wire volume of the dispatched
    combination is returned in ``ParallelSolveResult.bytes_per_round``.
    """
    num_workers = mesh.shape[axis]
    row_perm = None
    if partition == "balanced":
        from repro.core import partition as partition_lib
        op, b, x0, x_star, row_perm = partition_lib.apply_partition(
            op, b, x0, x_star, action=action, num_slabs=num_workers)
    elif partition != "contiguous":
        raise ValueError(
            f"unknown partition: {partition!r} (expected one of "
            f"{PARTITIONS})")

    if sync == "auto":
        if action == "rk":
            sync = "a2a" if hasattr(op, "slab_neighbors") else "psum"
        elif op.halo_width is not None:
            sync = "halo"
        elif hasattr(op, "slab_neighbors"):
            sync = "a2a"
        else:
            sync = "allgather"

    # Dispatch first, on the *requested* combination, so unsupported
    # action x format x sync asks fail with the enumerating error rather
    # than a wrong-layer message from the a2a precompute.
    kind = _DISTRIBUTED_STRATEGIES.get(
        (action, type(op).__name__, sync))
    if kind is None:
        supported = "\n  ".join(
            f"action={a!r} x format={f} x sync={s!r}"
            for (a, f, s) in sorted(_DISTRIBUTED_STRATEGIES))
        raise NotImplementedError(
            f"no distributed strategy for action={action!r}, "
            f"format={type(op).__name__}, sync={sync!r}; supported "
            f"combinations:\n  {supported}")
    if kind == "sparse_gs" and block != 1:
        raise NotImplementedError(
            f"distributed block GS with block={block} is not supported for "
            f"{type(op).__name__}; the sparse slab strategies run "
            "coordinate GS (block=1)")
    if fused == "auto":
        # Per-strategy-row resolution: the table's measured winner runs
        # where the strategy has a fused local phase; elsewhere auto
        # silently means scan — nothing was forced, so no warning (the
        # warning below is for an explicit fused=True that cannot be
        # honored).
        fused = (kind in _FUSED_STRATEGIES
                 and tune_runtime.resolve_fused(fused, op, action))
    if fused and kind not in _FUSED_STRATEGIES:
        _warn_fused_fallback(op, action, f" under the {kind!r} strategy")
        fused = False
    if overlap and kind not in _OVERLAP_STRATEGIES:
        _warn_overlap_fallback(op, action, kind)
        overlap = False
    if compress not in COMPRESS_MODES:
        raise ValueError(
            f"unknown compress: {compress!r} (expected one of "
            f"{COMPRESS_MODES})")
    if compress != "none" and kind not in _COMPRESS_STRATEGIES:
        _warn_compress_fallback(op, action, kind, compress)
        compress = "none"
    if compress != "none" and kind == "sparse_rk" and sync == "a2a":
        warnings.warn(
            f"compress={compress!r}: the a2a delta exchange is pinned "
            "bitwise to the psum reduction, which a lossy payload cannot "
            "preserve; running the compressed psum wire instead",
            UserWarning, stacklevel=2)
        sync = "psum"

    a2a_schedule, a2a_masks = (), None
    if sync == "a2a" and kind == "sparse_gs":
        need = op.slab_neighbors(num_workers)
        if num_workers > 1 and bool(need.all()):
            # Truly dense graph — every worker reads every slab: the masked
            # rotations would move exactly the all-gather's bytes over P-1
            # sequential collectives, so one all-gather wins.  (A graph
            # that merely covers all P-1 offsets with few pairs stays on
            # a2a: its perms only carry the needed sender->reader pairs.)
            # The strategy is unchanged — sparse_gs serves both syncs.
            sync = "allgather"
        else:
            # One rotation per distinct slab offset; each rotation's perm
            # only includes the (sender -> reader) pairs the sparsity
            # pattern demands, and masks[w, si] says whether worker w
            # accepts the slab arriving over rotation si.
            shifts = sorted({(w - v) % num_workers
                             for w in range(num_workers)
                             for v in range(num_workers)
                             if need[w, v] and w != v})
            a2a_schedule = tuple(
                (s, tuple((v, (v + s) % num_workers)
                          for v in range(num_workers)
                          if need[(v + s) % num_workers, v]))
                for s in shifts)
            a2a_masks = jnp.asarray(
                [[bool(need[w, (w - s) % num_workers]) for s in shifts]
                 for w in range(num_workers)]).reshape(num_workers,
                                                       len(shifts))
    elif sync == "a2a" and kind == "sparse_rk":
        # The RK delta sync runs over the COLUMN-slab neighbor graph:
        # need[w, c] says worker w's rows reference (read *and* write)
        # columns in slab c — the same matrix slab_neighbors() answers,
        # read column-wise.  Phase 1 reduces each column slab's deltas to
        # its owner (worker c owns column slab c) over one masked ppermute
        # rotation per shift; phase 2 broadcasts each owner's summed slab
        # back to its readers.  The owner accumulates contributions in
        # device order, which reproduces the psum's left-to-right
        # reduction bit-for-bit (pinned by test on the forced-4-device
        # host mesh).
        n_cols = op.shape[1]
        need = op.slab_neighbors(num_workers)
        if num_workers > 1 and (bool(need.all())
                                or n_cols % num_workers != 0):
            # Dense column graph: every rotation would carry every slab —
            # the single fused psum moves the same bytes with one
            # collective.  Indivisible column count: there is no equal
            # column-slab ownership to reduce onto.  Both fall back to the
            # delta psum, which is bitwise what a2a would have computed.
            sync = "psum"
        else:
            reduce_scheds = tuple(
                tuple((v, (v + s) % num_workers)
                      for v in range(num_workers)
                      if need[v, (v + s) % num_workers])
                for s in range(1, num_workers))
            bcast_scheds = tuple(
                tuple((c, (c + s) % num_workers)
                      for c in range(num_workers)
                      if need[(c + s) % num_workers, c] and
                      (c + s) % num_workers != c)
                for s in range(1, num_workers))
            a2a_schedule = (reduce_scheds, bcast_scheds)
            # accept masks for phase 2: masks[w, s-1] <=> worker w reads
            # column slab (w - s) mod P.
            a2a_masks = jnp.asarray(
                [[bool(need[w, (w - s) % num_workers])
                  for s in range(1, num_workers)]
                 for w in range(num_workers)]).reshape(
                     num_workers, max(num_workers - 1, 0))

    res = _distributed_impl(
        kind, op, b, x0, x_star, key, mesh=mesh, axis=axis, rounds=rounds,
        local_steps=local_steps, block=block, beta=beta, unroll=unroll,
        with_metrics=with_metrics, sync=sync, a2a_schedule=a2a_schedule,
        a2a_masks=a2a_masks, fused=fused, overlap=overlap, compress=compress)
    res = res._replace(bytes_per_round=_sync_bytes_per_round(
        kind, sync, compress, op=op, n=x0.shape[0], k=b.shape[1],
        num_workers=num_workers, a2a_schedule=a2a_schedule))
    if row_perm is not None and action == "gs":
        # Undo the symmetric permutation on the returned iterate (the "rk"
        # iterate lives in column space and was never permuted).
        res = res._replace(x=res.x[row_perm.inv])
    return res


#: action x format x sync -> strategy implementation.  The sparse strategies
#: are format-generic: any operator exposing ``padded_rows()`` (per-row
#: value/column windows with global column ids) slots in.
_DISTRIBUTED_STRATEGIES = {
    ("gs", "DenseOp", "allgather"): "dense_gs",
    ("gs", "BlockBandedOp", "allgather"): "banded_gs",
    ("gs", "BlockBandedOp", "halo"): "halo_gs",
    ("gs", "EllOp", "allgather"): "sparse_gs",
    ("gs", "EllOp", "a2a"): "sparse_gs",
    ("gs", "CsrOp", "allgather"): "sparse_gs",
    ("gs", "CsrOp", "a2a"): "sparse_gs",
    ("rk", "DenseOp", "psum"): "dense_rk",
    ("rk", "BlockBandedOp", "psum"): "banded_rk",
    ("rk", "EllOp", "psum"): "sparse_rk",
    ("rk", "EllOp", "a2a"): "sparse_rk",
    ("rk", "CsrOp", "psum"): "sparse_rk",
    ("rk", "CsrOp", "a2a"): "sparse_rk",
}

#: strategies whose local phase has a fused Pallas sweep.
_FUSED_STRATEGIES = frozenset(
    {"banded_gs", "halo_gs", "banded_rk", "sparse_gs", "sparse_rk"})

#: strategies with a double-buffered (overlapped) sync variant: the round-r
#: exchange payload is captured at the end of round r-1, so the collective
#: carries no data dependency on round r's local sweep.
_OVERLAP_STRATEGIES = frozenset({"halo_gs", "sparse_gs", "sparse_rk"})

#: strategies with a compressed wire format (``Schedule.compress``): the RK
#: delta psum and the banded halo exchange.  The slab all-gathers stay f32 —
#: a gathered slab IS the iterate (not an additive correction), so lossy
#: gathers would overwrite owned state with rounded values.
_COMPRESS_STRATEGIES = frozenset({"sparse_rk", "halo_gs"})


def _payload_bytes(size: int, compress: str) -> float:
    """Wire bytes of one ``size``-element f32 payload under a codec."""
    if compress == "bf16":
        return 2.0 * size
    if compress == "int8_ef":
        blocks = -(-size // compression.BLOCK)
        return float(blocks * compression.BLOCK + 4 * blocks)  # q + scales
    return 4.0 * size


def _sync_bytes_per_round(kind, sync, compress, *, op, n, k, num_workers,
                          a2a_schedule=()):
    """Analytic per-round sync payload: bytes ONE worker sends per round.

    Derived from the dispatch row, not measured — the model quantity the
    compressed wire formats shrink.  For the participation-asymmetric a2a
    exchanges the per-worker send count varies with the neighbor graph, so
    the total across workers is averaged over P.  At P = 1 every strategy
    skips its collective: 0 bytes.
    """
    if num_workers <= 1:
        return 0.0
    slab = n // num_workers
    if kind in ("dense_gs", "banded_gs"):
        return 4.0 * slab * k                      # all-gather of slab delta
    if kind == "halo_gs":
        halo = op.bands * op.block
        return 2.0 * _payload_bytes(halo * k, compress)   # two edges
    if kind == "sparse_gs":
        if sync == "a2a":
            sends = sum(len(pairs) for _, pairs in a2a_schedule)
            return 4.0 * slab * k * sends / num_workers
        return 4.0 * slab * k                      # all-gather of slab
    if kind in ("dense_rk", "banded_rk"):
        return _payload_bytes(n * k, compress)     # full-delta psum
    if kind == "sparse_rk":
        if sync == "a2a":
            reduce_scheds, bcast_scheds = a2a_schedule
            sends = (sum(len(p) for p in reduce_scheds)
                     + sum(len(p) for p in bcast_scheds))
            return 4.0 * slab * k * sends / num_workers
        return _payload_bytes(n * k, compress)     # full-delta psum
    raise ValueError(kind)  # pragma: no cover - guarded by dispatch


def _fused_band_tiles(op):
    """Zero-padded border tiles for the fused banded sweeps (one packing
    definition: ``BlockBandedOp.packed_band_tiles``)."""
    return op.packed_band_tiles()


@functools.partial(
    jax.jit,
    static_argnames=("kind", "mesh", "axis", "rounds", "local_steps", "block",
                     "beta", "unroll", "with_metrics", "sync",
                     "a2a_schedule", "fused", "overlap", "compress"),
)
def _distributed_impl(kind, op, b, x0, xs, key, *, mesh, axis, rounds,
                      local_steps, block, beta, unroll, with_metrics,
                      sync="allgather", a2a_schedule=(), a2a_masks=None,
                      fused=False, overlap=False, compress="none"):
    num_workers = mesh.shape[axis]
    k = b.shape[1]
    zero_m = (jnp.zeros((k,), jnp.float32), jnp.zeros((k,), jnp.float32))

    def local_scan(step, carry, picks):
        return jax.lax.scan(step, carry, picks,
                            unroll=local_steps if unroll else 1)

    def round_scan(body, carry, per_round):
        return jax.lax.scan(body, carry, per_round,
                            unroll=rounds if unroll else 1)

    tau = scheduled_tau(num_workers, local_steps,
                        shared_stream=kind.endswith("_rk"),
                        local_sampling=kind == "sparse_rk",
                        overlap=overlap)

    lag = None
    if kind == "dense_gs":
        x, errs, resids = _dense_gs(
            op.A, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, block=block, beta=beta,
            with_metrics=with_metrics, num_workers=num_workers,
            zero_m=zero_m, local_scan=local_scan, round_scan=round_scan)
    elif kind == "banded_gs":
        x, errs, resids = _banded_gs(
            op, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan, fused=fused)
    elif kind == "halo_gs":
        x, errs, resids, lag = _halo_gs(
            op, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan, fused=fused, overlap=overlap,
            compress=compress)
    elif kind == "dense_rk":
        x, errs, resids = _dense_rk(
            op.A, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan)
    elif kind == "banded_rk":
        x, errs, resids = _banded_rk(
            op, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan, fused=fused)
    elif kind == "sparse_gs":
        x, errs, resids, lag = _sparse_gs(
            op, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan, sync=sync, a2a_schedule=a2a_schedule,
            a2a_masks=a2a_masks, fused=fused, overlap=overlap)
    elif kind == "sparse_rk":
        x, errs, resids, lag = _sparse_rk(
            op, b, x0, xs, key, mesh=mesh, axis=axis, rounds=rounds,
            local_steps=local_steps, beta=beta, with_metrics=with_metrics,
            num_workers=num_workers, zero_m=zero_m, local_scan=local_scan,
            round_scan=round_scan, sync=sync, a2a_schedule=a2a_schedule,
            a2a_masks=a2a_masks, fused=fused, overlap=overlap,
            compress=compress)
    else:  # pragma: no cover - guarded by solve_distributed
        raise ValueError(kind)

    return ParallelSolveResult(x=x, err_sq=errs, resid=resids, tau=tau,
                               lag=lag)


def _dense_gs(A, b, x0, xs, key, *, mesh, axis, rounds, local_steps, block,
              beta, with_metrics, num_workers, zero_m, local_scan, round_scan):
    """Dense slab-partitioned block GS; all-gather of slab deltas.

    x* is consumed fully replicated — the pre-refactor implementation
    sharded it and re-all-gathered it every round inside the metric path,
    a pure-waste collective (ISSUE 2 satellite).  The metric values are
    bitwise unchanged (the gather reconstructed exactly this replica).
    """
    n = A.shape[0]
    slab = n // num_workers
    assert slab * num_workers == n and slab % block == 0
    round_keys = jax.random.split(key, rounds)

    def worker(A_sh, b_sh, xs_full, x0_full, keys):
        # A_sh: (slab, n), b_sh: (slab, k); xs_full/x0_full replicated.
        w = jax.lax.axis_index(axis)
        col0 = w * slab

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, slab // block)
            # Mark as device-varying: each worker accumulates its own deltas.
            delta = pvary(
                jnp.zeros((slab, b_sh.shape[1]), x.dtype), (axis,)
            )

            def step(delta, bi):
                rows = bi * block + jnp.arange(block)
                Ar = A_sh[rows]                          # (block, n)
                stale = Ar @ x                           # stale replica read
                # own-slab columns see the *fresh* local updates:
                own = jax.lax.dynamic_slice(Ar, (0, col0), (block, slab))
                g = b_sh[rows] - stale - own @ delta
                return delta.at[rows].add(beta * g), None

            delta, _ = local_scan(step, delta, picks)
            # Periodic synchronization (the paper's Thm 4.1(a) scheme).
            x2 = x + jax.lax.all_gather(delta, axis, axis=0, tiled=True)
            if not with_metrics:
                return x2, zero_m
            if xs_full is not None:
                e_local = (jax.lax.dynamic_slice_in_dim(x2, col0, slab, 0)
                           - jax.lax.dynamic_slice_in_dim(xs_full, col0,
                                                          slab, 0))
                err = jax.lax.psum(
                    jnp.einsum("sk,sk->k", e_local, A_sh @ (x2 - xs_full)),
                    axis)
            else:
                err = jnp.full((b_sh.shape[1],), jnp.nan, jnp.float32)
            r_local = b_sh - A_sh @ x2
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return x2, (err, jnp.sqrt(rsq))

        x, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                       keys)
        # Every worker's x is identical after the final all-gather, but the
        # VMA type system cannot prove it; return the owned slab (the honest
        # sharding) and let the out_spec reassemble the global vector.
        x_slab = jax.lax.dynamic_slice_in_dim(x, col0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(None, None), P(None, None),
                  P(None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    return mapped(A, b, xs, x0, round_keys)


def _banded_gs(op, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
               with_metrics, num_workers, zero_m, local_scan, round_scan,
               fused=False):
    """Block-banded slab GS; per-round all-gather of the owned slab.

    ``fused=True`` replaces the local-phase scan with one
    ``banded_gs_sweep`` launch per round: the worker's halo-padded window
    of the replica stays VMEM-resident across all ``local_steps`` updates,
    and border validity moves from the scan's ``where(valid, ...)`` masks
    into zero-padded tiles (``pack_bands_local``) — exact zeros either
    way, so the iterates are bitwise identical.
    """
    block, bands, nb = op.block, op.bands, op.nb
    n = b.shape[0]
    slab = n // num_workers
    nb_local = slab // block
    assert nb * block == n and nb_local * block == slab
    round_keys = jax.random.split(key, rounds)
    Ab = _fused_band_tiles(op) if fused else op.A_bands
    halo = bands * block

    def worker(Ab_sh, b_sh, keys, x0_full, xs_full):
        # Ab_sh: (nb_local, width, block, block); b_sh: (slab, k).
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)
            xw = x   # working replica: own rows fresh, remote rows stale

            def step(xw, bi):
                g = banded_panel_residual(Ab_sh, b_sh, xw, bi,
                                          w * nb_local + bi, nb, block, bands)
                rows0 = row0 + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, rows0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, rows0, 0), None

            if fused:
                from repro.kernels import ops
                xpad = jnp.pad(xw, ((halo, halo), (0, 0)))
                win = jax.lax.dynamic_slice_in_dim(
                    xpad, row0, slab + 2 * halo, 0)
                win = ops.banded_gs_sweep(Ab_sh, b_sh, win, picks,
                                          block=block, bands=bands,
                                          beta=beta)
                own = jax.lax.dynamic_slice_in_dim(win, halo, slab, 0)
            else:
                xw, _ = local_scan(step, xw, picks)
                own = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
            x2 = jax.lax.all_gather(own, axis, axis=0, tiled=True)
            if not with_metrics:
                return x2, zero_m
            # metrics (slab-local residual psum)
            r_local = b_sh - banded_rows_matvec(Ab_sh, x2, w, nb, nb_local,
                                                block, bands)
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            if xs_full is not None:
                e_own = own - jax.lax.dynamic_slice_in_dim(xs_full, row0, slab, 0)
                esq = jax.lax.psum(
                    jnp.einsum("sk,sk->k", e_own,
                               -r_local + (b_sh - banded_rows_matvec(
                                   Ab_sh, xs_full, w, nb, nb_local, block,
                                   bands))),
                    axis)
            else:
                esq = jnp.full((b_sh.shape[1],), jnp.nan, jnp.float32)
            return x2, (esq, jnp.sqrt(rsq))

        x, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                       keys)
        x_slab = jax.lax.dynamic_slice_in_dim(x, row0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(None),
                  P(None, None), P(None, None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    return mapped(Ab, b, round_keys, x0, xs)


def _halo_gs(op, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
             with_metrics, num_workers, zero_m, local_scan, round_scan,
             fused=False, overlap=False, compress="none"):
    """Block-banded slab GS; neighbor halo exchange instead of all-gather.

    Iterates are IDENTICAL to the all-gather strategy — the gathered entries
    outside the halo were never read.  ``err_sq`` is the A-norm error when
    ``xs`` is provided (computed slab-locally from the halo window) and NaN
    otherwise — pre-refactor this slot silently carried the squared
    residual (ISSUE 2 satellite).

    ``fused=True`` hands the halo-padded window — already exactly the
    sweep kernel's working-set shape — to one ``banded_gs_sweep`` launch
    per round in place of the local-phase scan (bitwise-identical
    iterates; border validity baked into zero-padded tiles).

    ``overlap=True`` double-buffers the halo exchange: the edges installed
    during round r are the ones CAPTURED at the end of round r-1 (carried
    through the round scan), so the two ppermutes have no data dependency
    on round r's sweep and XLA can run them concurrently — the halos a
    sweep reads are one round staler, and staleness counters measure the
    resulting lag (see ``ParallelSolveResult.lag``).

    ``compress`` quantizes the edge payloads on the wire (bf16 round, or
    absolute int8 blocks + scales).  No error feedback: an edge is *state*
    — the neighbor's current boundary rows, re-sent fresh every round — so
    per-round quantization error never accumulates across rounds the way a
    compressed additive delta would.  Only the halo copies are perturbed;
    the owned slab each worker returns is never compressed, and the
    metrics exchange of ``xs`` stays exact so the recorded A-norm error
    measures the true trajectory of the compressed run.
    """
    block, bands, nb = op.block, op.bands, op.nb
    n, k = b.shape
    slab = n // num_workers
    nb_local = slab // block
    halo = bands * block
    assert halo <= slab, "halo exchange needs bands*block <= slab"
    round_keys = jax.random.split(key, rounds)
    Ab = _fused_band_tiles(op) if fused else op.A_bands
    down = [(i, i + 1) for i in range(num_workers - 1)]
    up = [(i + 1, i) for i in range(num_workers - 1)]
    have_xs = xs is not None

    def worker(Ab_sh, b_sh, x0_sh, keys, *maybe_xs):
        w = jax.lax.axis_index(axis)

        def wire_edge(e):
            # What the compressed wire does to an outgoing edge.  Applied
            # sender-side before the ppermute so the collective carries the
            # narrow payload; identity under compress="none".
            if compress == "bf16":
                return compression.bf16_roundtrip_array(e)
            if compress == "int8_ef":
                return compression.roundtrip_array(e)
            return e

        def install(xw, lo_edge, hi_edge, *, codec=True):
            # lo/hi_edge: my top/bottom owned rows -> neighbors' halos.
            if codec:
                lo_edge, hi_edge = wire_edge(lo_edge), wire_edge(hi_edge)
            from_prev = jax.lax.ppermute(hi_edge, axis, down)   # w-1's bottom
            from_next = jax.lax.ppermute(lo_edge, axis, up)     # w+1's top
            xw = jax.lax.dynamic_update_slice_in_dim(xw, from_prev, 0, 0)
            return jax.lax.dynamic_update_slice_in_dim(
                xw, from_next, halo + slab, 0)

        def exchange(xw, *, codec=True):
            own = jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0)
            return install(xw, own[:halo], own[-halo:], codec=codec)

        if have_xs:
            # Metrics-only exchange: x* halos travel exact so the recorded
            # error norm is not itself perturbed by the codec.
            xs_w = exchange(jnp.pad(maybe_xs[0], ((halo, halo), (0, 0))),
                            codec=False)

        def local_phase(xw, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)

            def step(xw, bi):
                g = banded_panel_residual_window(
                    Ab_sh, b_sh, xw, bi, w * nb_local + bi, nb, slab, block,
                    bands)
                r0 = halo + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, r0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, r0, 0), None

            if fused:
                from repro.kernels import ops
                return ops.banded_gs_sweep(Ab_sh, b_sh, xw, picks,
                                           block=block, bands=bands,
                                           beta=beta)
            xw, _ = local_scan(step, xw, picks)
            return xw

        def metrics(xw):
            if not with_metrics:
                return zero_m
            # Vectorized residual: vmap the per-panel window residual, then
            # accumulate the per-panel squared sums LEFT-TO-RIGHT via scan —
            # bitwise the old Python loop's grouping (a fused jnp.sum would
            # reassociate), with O(1) trace size instead of O(nb_local).
            r_all = jax.vmap(
                lambda bi: banded_panel_residual_window(
                    Ab_sh, b_sh, xw, bi, w * nb_local + bi, nb, slab, block,
                    bands).astype(jnp.float32))(jnp.arange(nb_local))
            part = jnp.einsum("nbk,nbk->nk", r_all, r_all)
            resid2, _ = jax.lax.scan(
                lambda acc, p: (acc + p, None),
                jnp.zeros((k,), jnp.float32), part)
            rsq = jax.lax.psum(resid2, axis)
            if have_xs:
                # A-norm error from the window: e^T A e = sum over owned
                # rows of e_own * (A e)_own, psum'd across workers.
                ew = xw - xs_w
                Ae_own = banded_window_matvec(Ab_sh, ew, w, nb, nb_local,
                                              block, bands)
                e_own = jax.lax.dynamic_slice_in_dim(ew, halo, slab, 0)
                esq = jax.lax.psum(
                    jnp.einsum("sk,sk->k", e_own, Ae_own), axis)
            else:
                esq = jnp.full((k,), jnp.nan, jnp.float32)
            return esq, jnp.sqrt(rsq)

        xw0 = jnp.pad(x0_sh, ((halo, halo), (0, 0)))
        xw0 = exchange(xw0)

        if overlap:
            foreign = jnp.arange(num_workers) != w

            def round_body(carry, rkey):
                xw, lo_prev, hi_prev, cnt, seen = carry
                # cnt carried in == updates committed by the end of the
                # previous round == the count of the in-flight payload, so
                # one all_gather serves both the payload's bookkeeping and
                # the lag measurement.
                cnt_all = jax.lax.all_gather(cnt, axis)
                lag = jax.lax.pmax(
                    jnp.sum(jnp.where(foreign, cnt_all - seen, 0)), axis)
                seen = jnp.where(foreign, cnt_all, seen)
                cnt = cnt + local_steps
                xw = local_phase(xw, rkey)          # halos one round stale
                xw = install(xw, lo_prev, hi_prev)  # in-flight edges land
                own = jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0)
                return ((xw, own[:halo], own[-halo:], cnt, seen),
                        (metrics(xw), lag))

            own0 = jax.lax.dynamic_slice_in_dim(xw0, halo, slab, 0)
            cnt0 = pvary(jnp.zeros((), jnp.int32), (axis,))
            seen0 = pvary(jnp.zeros((num_workers,), jnp.int32), (axis,))
            carry0 = (xw0, own0[:halo], own0[-halo:], cnt0, seen0)
            (xw, *_), ((errs, resids), lags) = round_scan(
                round_body, carry0, keys)
            return (jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0),
                    errs, resids, lags)

        def round_body(xw, rkey):
            xw = local_phase(xw, rkey)
            xw = exchange(xw)
            return xw, metrics(xw)

        xw, (errs, resids) = round_scan(round_body, xw0, keys)
        return jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0), errs, resids

    in_specs = [P(axis, None, None, None), P(axis, None), P(axis, None),
                P(None)]
    args = [Ab, b, x0, round_keys]
    if have_xs:
        in_specs.append(P(axis, None))
        args.append(xs)
    out_specs = [P(axis, None), P(None, None), P(None, None)]
    if overlap:
        out_specs.append(P(None))
    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=tuple(out_specs),
    )
    out = mapped(*args)
    return out if overlap else out + (None,)


def _dense_rk(A, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
              with_metrics, num_workers, zero_m, local_scan, round_scan):
    """Row-slab Kaczmarz; one global i.i.d. pick stream, delta psum sync."""
    m = A.shape[0]
    slab = m // num_workers
    assert slab * num_workers == m, (
        f"worker count ({num_workers}) must divide the row count ({m})")
    rn = jnp.einsum("mn,mn->m", A, A)
    picks = sample_rows(key, rn, rounds * local_steps).reshape(
        rounds, local_steps)

    def worker(A_sh, b_sh, rn_sh, x0_full, xs_full, picks):
        # A_sh: (slab, n); b_sh: (slab, k); rn_sh: (slab,); x0/xs replicated.
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def round_body(xw, picks_r):
            delta = pvary(jnp.zeros_like(xw), (axis,))

            def step(carry, p):
                xw, delta = carry
                li = p - row0
                mine = (li >= 0) & (li < slab)
                lic = jnp.clip(li, 0, slab - 1)
                Ar = A_sh[lic]                               # (n,)
                g = (b_sh[lic] - Ar @ xw) / rn_sh[lic]       # (k,)
                # Arithmetic mirrors the sequential step exactly
                # (bit-identity at P=1): scalar coefficient times row outer
                # product.
                upd = jnp.where(mine, beta, 0.0) * Ar[:, None] * g[None, :]
                return (xw + upd, delta + upd), None

            (xw, delta), _ = local_scan(step, (xw, delta), picks_r)
            if num_workers > 1:
                # Periodic synchronization: pull in the other workers'
                # updates.  Skipped entirely at P=1 — it would be a bitwise
                # no-op in exact arithmetic, but XLA folds the single-device
                # psum away and reassociates xw + (delta - delta), costing
                # an ulp per round and breaking the exact-degeneracy
                # guarantee the consistency tests rely on.
                xw = xw + (jax.lax.psum(delta, axis) - delta)
            if not with_metrics:
                return xw, zero_m
            # xw is a full replica, so the error is local; residual rows are
            # sharded, so the squared norm needs a psum.
            if xs_full is not None:
                err = jnp.einsum("nk,nk->k", xw - xs_full, xw - xs_full)
            else:
                err = jnp.full((b_sh.shape[1],), jnp.nan, jnp.float32)
            r_local = b_sh - A_sh @ xw
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return xw, (err, jnp.sqrt(rsq))

        xw, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                        picks)
        return xw, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(None, None),
                  P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
    )
    return mapped(A, b, rn, x0, xs, picks)


def _banded_rk(op, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
               with_metrics, num_workers, zero_m, local_scan, round_scan,
               fused=False):
    """Block-banded Kaczmarz — the new point in the action×format grid.

    The row panel of a random block-row is sampled ∝ its squared Frobenius
    norm (the block extension of Strohmer-Vershynin sampling); the update is
    the damped simultaneous-row (Cimmino-within-panel) action

        x += beta * A_B^T diag(1/||a_i||^2) (b - A x)_B

    whose writes reach only ``bands`` block columns either side of the
    panel — the same finite reach the banded GS strategies exploit.  Like
    the dense RK strategy, the pick stream is global (one i.i.d. sequence
    partitioned by owner), each worker carries its own updates fresh within
    a round, and synchronization is a delta psum with scheduled staleness
    ``local_steps - 1``.

    ``fused=True`` runs the local phase as one ``banded_rk_sweep`` launch
    per round: the worker's halo-padded windows of the replica AND of the
    round delta stay VMEM-resident across all steps, the global pick
    stream is pre-localized (clipped local id + ownership gate, both
    scalar-prefetched), and foreign picks apply the same exact-zero
    updates the scan's masked arithmetic does.
    """
    block, bands, nb = op.block, op.bands, op.nb
    width = op.width
    n = b.shape[0]
    slab = n // num_workers
    nb_local = slab // block
    halo = bands * block
    assert nb * block == n and nb_local * block == slab
    rn = op.row_norms_sq()                                  # (nb, block)
    panel_w = jnp.sum(rn, axis=1)                           # (nb,) — raw
    # norms: a zero panel must keep sampling weight 0 (log 0 = -inf).
    rn = jnp.where(rn > 0, rn, 1.0)                         # divisor guard only
    picks = sample_rows(key, panel_w, rounds * local_steps).reshape(
        rounds, local_steps)
    Ab = _fused_band_tiles(op) if fused else op.A_bands

    def worker(Ab_sh, b_sh, rn_sh, x0_full, xs_full, picks):
        # Ab_sh: (nb_local, width, block, block); rn_sh: (nb_local, block).
        w = jax.lax.axis_index(axis)

        def add_at(v, off, contrib):
            cur = jax.lax.dynamic_slice_in_dim(v, off, block, 0)
            return jax.lax.dynamic_update_slice_in_dim(v, cur + contrib, off, 0)

        def apply_panel(xw, delta, tiles, gb, upd):
            """Scatter A_B^T upd into the band columns of both carries,
            computing each (block, block) @ (block, k) contribution once."""
            for d in range(width):
                cb = gb + d - bands
                cbc = jnp.clip(cb, 0, nb - 1)
                valid = (cb >= 0) & (cb < nb)
                contrib = jnp.dot(tiles[d].T, upd,
                                  preferred_element_type=jnp.float32)
                contrib = jnp.where(valid, contrib, 0.0).astype(xw.dtype)
                xw = add_at(xw, cbc * block, contrib)
                delta = add_at(delta, cbc * block, contrib)
            return xw, delta

        def round_body(xw, picks_r):
            delta = pvary(jnp.zeros_like(xw), (axis,))

            def step(carry, p):
                xw, delta = carry
                li = p - w * nb_local
                mine = (li >= 0) & (li < nb_local)
                lic = jnp.clip(li, 0, nb_local - 1)
                gb = w * nb_local + lic
                g = banded_panel_residual(Ab_sh, b_sh, xw, lic, gb, nb,
                                          block, bands)          # (block, k)
                rnp = jax.lax.dynamic_slice_in_dim(rn_sh, lic, 1, 0)[0]
                gn = (jnp.where(mine, beta, 0.0) * g
                      / rnp[:, None]).astype(jnp.float32)
                tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, lic, 1, 0)[0]
                return apply_panel(xw, delta, tiles, gb, gn), None

            if fused:
                from repro.kernels import ops
                li = picks_r - w * nb_local
                mine = (li >= 0) & (li < nb_local)
                lic = jnp.clip(li, 0, nb_local - 1)
                row0 = w * slab
                xpad = jnp.pad(xw, ((halo, halo), (0, 0)))
                dpad = jnp.pad(delta, ((halo, halo), (0, 0)))
                xwin = jax.lax.dynamic_slice_in_dim(
                    xpad, row0, slab + 2 * halo, 0)
                dwin = jax.lax.dynamic_slice_in_dim(
                    dpad, row0, slab + 2 * halo, 0)
                xwin, dwin = ops.banded_rk_sweep(
                    Ab_sh, b_sh, rn_sh, xwin, dwin, lic,
                    mine.astype(jnp.int32), block=block, bands=bands,
                    beta=beta)
                xpad = jax.lax.dynamic_update_slice_in_dim(
                    xpad, xwin, row0, 0)
                dpad = jax.lax.dynamic_update_slice_in_dim(
                    dpad, dwin, row0, 0)
                xw = xpad[halo:halo + n]
                delta = dpad[halo:halo + n]
            else:
                (xw, delta), _ = local_scan(step, (xw, delta), picks_r)
            if num_workers > 1:
                xw = xw + (jax.lax.psum(delta, axis) - delta)
            if not with_metrics:
                return xw, zero_m
            r_local = b_sh - banded_rows_matvec(Ab_sh, xw, w, nb, nb_local,
                                                block, bands)
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            if xs_full is not None:
                err = jnp.einsum("nk,nk->k", xw - xs_full, xw - xs_full)
            else:
                err = jnp.full((b_sh.shape[1],), jnp.nan, jnp.float32)
            return xw, (err, jnp.sqrt(rsq))

        xw, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                        picks)
        return xw, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(axis, None),
                  P(None, None), P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
    )
    return mapped(Ab, b, rn, x0, xs, picks)


def _sparse_gs(op, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
               with_metrics, num_workers, zero_m, local_scan, round_scan,
               sync, a2a_schedule, a2a_masks, fused=False, overlap=False):
    """Row-sparse slab GS (CsrOp / EllOp) — the format-generic strategy.

    Each worker owns a slab of rows in padded-row form (fixed-width
    value/column windows with *global* column ids — ``op.padded_rows()``),
    keeps a full-length working replica whose own slab is fresh within a
    round, and refreshes at round end either by all-gather or by the
    sparsity-derived neighbor all-to-all (``sync="a2a"``): one masked
    ppermute rotation per distinct slab offset in the neighbor graph,
    sending a worker's slab only to the workers whose rows actually read
    it.  Iterates are IDENTICAL to the all-gather strategy — the slabs a2a
    leaves stale are never read.

    ``fused=True`` runs the local phase as one ``sweep_rows_gs`` launch
    per round: the replica stays VMEM-resident across all ``local_steps``
    updates and the slab offset rides the scalar-prefetch channel as the
    kernel's write base (it is traced — ``axis_index`` under shard_map).
    The arithmetic is the scan step's, so iterates are bitwise identical.

    ``overlap=True`` exchanges the own slab captured at the END of round
    r-1 (carried through the round scan) while round r's sweep runs on
    remote slabs that are one round staler; the a2a rotations never write
    the own slab, and the all-gather path splices the fresh own rows back
    over the stale gather.  Staleness counters measure the per-round lag.
    """
    n, k = b.shape
    if n % num_workers:
        raise ValueError(
            f"worker count ({num_workers}) must divide the row count ({n})")
    slab = n // num_workers
    vals, cols = op.padded_rows()
    round_keys = jax.random.split(key, rounds)
    if a2a_masks is None:
        a2a_masks = jnp.zeros((num_workers, len(a2a_schedule)), bool)

    def worker(vals_sh, cols_sh, b_sh, masks_sh, keys, x0_full, xs_full):
        # vals_sh/cols_sh: (slab, width); b_sh: (slab, k); x0/xs replicated.
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def refresh(xw, own_prev=None):
            """own_prev=None: lockstep (exchange this round's own slab);
            otherwise install the in-flight previous-round payload."""
            own = (jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
                   if own_prev is None else own_prev)
            if sync == "allgather":
                x2 = jax.lax.all_gather(own, axis, axis=0, tiled=True)
                if own_prev is None:
                    return x2
                fresh = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
                return jax.lax.dynamic_update_slice_in_dim(x2, fresh, row0, 0)
            # a2a rotations only ever write remote slabs (shift != 0), so
            # the fresh own slab survives either way.
            for si, (shift, perm) in enumerate(a2a_schedule):
                recv = jax.lax.ppermute(own, axis, perm)
                src0 = ((w - shift) % num_workers) * slab
                cur = jax.lax.dynamic_slice_in_dim(xw, src0, slab, 0)
                upd = jnp.where(masks_sh[0, si], recv, cur)
                xw = jax.lax.dynamic_update_slice_in_dim(xw, upd, src0, 0)
            return xw

        def local_phase(xw, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, slab)
            if fused:
                from repro.kernels import ops
                return ops.sweep_rows_gs(vals_sh, cols_sh, b_sh, xw, picks,
                                         beta=beta, write_base=row0)

            def step(xw, li):
                g = b_sh[li] - jnp.einsum("w,wk->k", vals_sh[li],
                                          xw[cols_sh[li]])
                return xw.at[row0 + li].add(beta * g), None

            xw, _ = local_scan(step, xw, picks)
            return xw

        def metrics(xw):
            if not with_metrics:
                return zero_m
            # Both metric reductions only read the slabs this worker's rows
            # reference, so they are exact under a2a as well.
            r_local = b_sh - jnp.einsum("sw,swk->sk", vals_sh, xw[cols_sh])
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            if xs_full is not None:
                e = xw - xs_full
                Ae_own = jnp.einsum("sw,swk->sk", vals_sh, e[cols_sh])
                e_own = jax.lax.dynamic_slice_in_dim(e, row0, slab, 0)
                esq = jax.lax.psum(jnp.einsum("sk,sk->k", e_own, Ae_own),
                                   axis)
            else:
                esq = jnp.full((k,), jnp.nan, jnp.float32)
            return esq, jnp.sqrt(rsq)

        if overlap:
            foreign = jnp.arange(num_workers) != w

            def round_body(carry, rkey):
                xw, own_prev, cnt, seen = carry
                cnt_all = jax.lax.all_gather(cnt, axis)
                lag = jax.lax.pmax(
                    jnp.sum(jnp.where(foreign, cnt_all - seen, 0)), axis)
                seen = jnp.where(foreign, cnt_all, seen)
                cnt = cnt + local_steps
                xw = local_phase(xw, rkey)   # remote slabs one round stale
                xw = refresh(xw, own_prev)   # in-flight payload lands
                own = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
                return (xw, own, cnt, seen), (metrics(xw), lag)

            xw0 = pvary(x0_full, (axis,))
            own0 = jax.lax.dynamic_slice_in_dim(xw0, row0, slab, 0)
            cnt0 = pvary(jnp.zeros((), jnp.int32), (axis,))
            seen0 = pvary(jnp.zeros((num_workers,), jnp.int32), (axis,))
            (xw, *_), ((errs, resids), lags) = round_scan(
                round_body, (xw0, own0, cnt0, seen0), keys)
            x_slab = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
            return x_slab, errs, resids, lags

        def round_body(xw, rkey):
            xw = local_phase(xw, rkey)
            xw = refresh(xw)
            return xw, metrics(xw)

        xw, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                        keys)
        x_slab = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
        return x_slab, errs, resids

    out_specs = [P(axis, None), P(None, None), P(None, None)]
    if overlap:
        out_specs.append(P(None))
    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None),
                  P(axis, None), P(None), P(None, None), P(None, None)),
        out_specs=tuple(out_specs),
    )
    out = mapped(vals, cols, b, a2a_masks, round_keys, x0, xs)
    return out if overlap else out + (None,)


def _sparse_rk(op, b, x0, xs, key, *, mesh, axis, rounds, local_steps, beta,
               with_metrics, num_workers, zero_m, local_scan, round_scan,
               sync="psum", a2a_schedule=(), a2a_masks=None, fused=False,
               overlap=False, compress="none"):
    """Row-sparse Kaczmarz with per-worker LOCAL sampling (CsrOp / EllOp).

    The wall-clock-faithful scheme: each worker samples its ``local_steps``
    rows ∝ its *own slab's* row norms, so every step is a useful update —
    contrast ``_dense_rk``, where each worker scans the global pick stream
    and masks out the (P-1)/P foreign picks.  Interleaving the P local
    streams round-robin gives a round-level shared stream of
    ``P * local_steps`` picks partitioned by owner, so the shared-stream
    bound applies to that stream length: ``scheduled_tau(P,
    P * local_steps, shared_stream=True) = P * local_steps - 1`` (a
    worker's read misses at most the other workers' (P-1)*local_steps
    current-round updates, which this bounds).  (The stationary row law is
    ∝ ||A_i||² *within* each slab; it matches Strohmer–Vershynin globally
    when the slabs carry equal norm mass — ``partition="balanced"`` makes
    that hold by construction.)  All-zero slabs are safe: ``sample_rows``
    falls back to uniform picks and the zero rows make the updates no-ops.

    Sync is the RK delta psum, or — ``sync="a2a"`` — the two-phase
    exchange over the column-slab neighbor graph: phase 1 reduces every
    column slab's per-worker deltas onto the slab's *owner* (worker c owns
    column slab c) with one (cs, k) ppermute rotation per shift, the owner
    accumulating in device order so the sum carries exactly the bits of the
    psum's left-to-right reduction; phase 2 broadcasts each owner's summed
    slab back to the workers whose rows reference it.  Slabs a worker never
    references stay stale — they are never read, and the returned iterate
    is assembled from the owners' slabs, so iterates and metrics are
    bitwise identical to the psum sync at a fraction of its wire volume.

    ``fused=True`` runs the local phase as one ``sweep_rows_rk_delta``
    launch per round: BOTH carries — the working replica and the round's
    delta — stay VMEM-resident across all ``local_steps`` updates (the
    ``banded_rk_sweep`` two-carry pattern on padded rows); iterates match
    the scan to roundoff (the kernel's per-column scatter is a sequence of
    row RMWs where the scan uses one segment scatter).

    ``overlap=True`` exchanges the delta ACCUMULATED IN round r-1 (carried
    through the round scan) while round r's sweep accumulates a fresh one,
    so foreign updates land one round late; the final round's delta is
    flushed with one trailing exchange after the scan so the returned
    iterate contains every update.  Staleness counters measure the
    per-round lag.

    ``compress`` shrinks the psum payload (a2a is forced back to psum by
    the caller — its bitwise-psum invariant cannot survive lossy bits):
    each worker sends its round delta bf16-rounded or int8-quantized and
    applies ``psum(sent) - sent`` — its OWN updates stay exact in its
    replica, only the foreign contributions arrive rounded, so the scheme
    perturbs exactly what the wire carries.  ``int8_ef`` additionally
    carries a per-worker error-feedback residual through the round scan
    (quantize ``delta + residual``, keep the quantization error as the
    next residual — Karimireddy-style EF), so dropped bits are re-sent
    rather than lost; the residual is flushed with one exact trailing
    psum after the scan (after the overlap flush, when both compose), so
    the RETURNED iterate contains every update while the per-round
    metrics keep measuring the true compressed trajectory.
    """
    m, k = b.shape
    n = x0.shape[0]
    if m % num_workers:
        raise ValueError(
            f"worker count ({num_workers}) must divide the row count ({m})")
    vals, cols = op.padded_rows()
    rn = op.row_norms_sq()
    round_keys = jax.random.split(key, rounds)
    use_a2a = sync == "a2a"
    if use_a2a:
        assert n % num_workers == 0, (n, num_workers)  # caller fell back
        reduce_scheds, bcast_scheds = a2a_schedule
    cs = n // num_workers if n % num_workers == 0 else None
    if a2a_masks is None:
        a2a_masks = jnp.zeros((num_workers, max(num_workers - 1, 0)), bool)
    if num_workers == 1 or use_a2a:
        # P = 1 has no collective to compress; a2a was already forced back
        # to psum by the caller.  Normalizing here keeps the carries clean.
        compress = "none"
    use_ef = compress == "int8_ef"

    def worker(vals_sh, cols_sh, b_sh, rn_sh, masks_sh, keys, x0_full,
               xs_full):
        # vals_sh/cols_sh: (slab, width); rn_sh: (slab,); x0/xs replicated.
        w = jax.lax.axis_index(axis)
        rn_safe = jnp.where(rn_sh > 0, rn_sh, 1.0)

        def col_slab(v, c0):
            return jax.lax.dynamic_slice_in_dim(v, c0 * cs, cs, 0)

        def refresh(xw, delta):
            if num_workers == 1:
                return xw
            if not use_a2a:
                return xw + (jax.lax.psum(delta, axis) - delta)
            # Phase 1 — reduce-to-owner.  terms[s] is the slab-w delta of
            # worker (w - s) mod P (zeros when that worker never references
            # slab w: skipped pairs receive ppermute's zero fill, exactly
            # the all-zero delta the psum would have added).
            own = col_slab(delta, w)
            terms = [own]
            for si, perm in enumerate(reduce_scheds):
                sent = col_slab(delta, (w + si + 1) % num_workers)
                terms.append(jax.lax.ppermute(sent, axis, perm) if perm
                             else jnp.zeros_like(own))
            stack = jnp.stack(terms)               # indexed by shift s
            # Accumulate in DEVICE order v = 0..P-1 (term index (w - v) mod
            # P) — the order the psum reduces in, so S carries its bits.
            total = jnp.take(stack, jnp.mod(w, num_workers), axis=0)
            for v in range(1, num_workers):
                total = total + jnp.take(stack, jnp.mod(w - v, num_workers),
                                         axis=0)
            # Owner applies its summed slab locally...
            xw = jax.lax.dynamic_update_slice_in_dim(
                xw, col_slab(xw, w) + (total - own), w * cs, 0)
            # ...phase 2 — broadcast to the slab's readers, who apply the
            # same (S - own contribution) correction where accepted.
            for si, perm in enumerate(bcast_scheds):
                if not perm:
                    continue
                recv = jax.lax.ppermute(total, axis, perm)
                src = jnp.mod(w - si - 1, num_workers)
                cur = col_slab(xw, src)
                upd = cur + (recv - col_slab(delta, src))
                xw = jax.lax.dynamic_update_slice_in_dim(
                    xw, jnp.where(masks_sh[0, si], upd, cur), src * cs, 0)
            return xw

        def wire(payload, resid):
            """(bytes actually sent, next EF residual) for one payload.

            Identity under compress="none"; bf16 rounds the payload; int8
            EF quantizes (payload + residual) and keeps the quantization
            error as the next residual so no update is permanently lost.
            """
            if compress == "bf16":
                return compression.bf16_roundtrip_array(payload), resid
            if use_ef:
                corrected = payload + resid
                sent = compression.roundtrip_array(corrected)
                return sent, corrected - sent
            return payload, resid

        def local_phase(xw, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = sample_rows(rkey, rn_sh, local_steps)
            delta = pvary(jnp.zeros_like(xw), (axis,))
            if fused:
                from repro.kernels import ops
                return ops.sweep_rows_rk_delta(
                    vals_sh, cols_sh, b_sh, rn_safe, xw, delta, picks,
                    beta=beta)

            def step(carry, li):
                xw, delta = carry
                vr, cr = vals_sh[li], cols_sh[li]
                g = (b_sh[li] - jnp.einsum("w,wk->k", vr, xw[cr])) / rn_safe[li]
                upd = beta * vr[:, None] * g[None, :]
                return (xw.at[cr].add(upd), delta.at[cr].add(upd)), None

            (xw, delta), _ = local_scan(step, (xw, delta), picks)
            return xw, delta

        def metrics(xw):
            if not with_metrics:
                return zero_m
            if xs_full is None:
                err = jnp.full((k,), jnp.nan, jnp.float32)
            elif cs is not None:
                # Column-slab-local error, psum'd: reads only the worker's
                # own (always fresh) slab, so it is exact — and bitwise
                # identical — under both syncs.
                e_own = col_slab(xw, w) - col_slab(xs_full, w)
                err = jax.lax.psum(jnp.einsum("sk,sk->k", e_own, e_own),
                                   axis)
            else:
                err = jnp.einsum("nk,nk->k", xw - xs_full, xw - xs_full)
            r_local = b_sh - jnp.einsum("sw,swk->sk", vals_sh, xw[cols_sh])
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return err, jnp.sqrt(rsq)

        if overlap:
            foreign = jnp.arange(num_workers) != w

            def round_body(carry, rkey):
                if use_ef:
                    xw, dprev, resid, cnt, seen = carry
                else:
                    (xw, dprev, cnt, seen), resid = carry, None
                cnt_all = jax.lax.all_gather(cnt, axis)
                lag = jax.lax.pmax(
                    jnp.sum(jnp.where(foreign, cnt_all - seen, 0)), axis)
                seen = jnp.where(foreign, cnt_all, seen)
                cnt = cnt + local_steps
                xw, delta = local_phase(xw, rkey)
                sent, resid = wire(dprev, resid)
                xw = refresh(xw, sent)       # previous round's deltas land
                carry = ((xw, delta, resid, cnt, seen) if use_ef
                         else (xw, delta, cnt, seen))
                return carry, (metrics(xw), lag)

            xw0 = pvary(x0_full, (axis,))
            d0 = pvary(jnp.zeros_like(xw0), (axis,))
            cnt0 = pvary(jnp.zeros((), jnp.int32), (axis,))
            seen0 = pvary(jnp.zeros((num_workers,), jnp.int32), (axis,))
            carry0 = ((xw0, d0, pvary(jnp.zeros_like(xw0), (axis,)), cnt0,
                       seen0) if use_ef else (xw0, d0, cnt0, seen0))
            (xw, dlast, *rest), ((errs, resids), lags) = round_scan(
                round_body, carry0, keys)
            # Flush the final round's in-flight delta — plus, under EF, the
            # outstanding residual — with one EXACT trailing exchange so
            # the returned iterate contains every update.
            xw = refresh(xw, dlast + rest[0] if use_ef else dlast)
            if use_a2a:
                return col_slab(xw, w), errs, resids, lags
            return xw, errs, resids, lags

        if use_ef:
            def round_body(carry, rkey):
                xw, resid = carry
                xw, delta = local_phase(xw, rkey)
                sent, resid = wire(delta, resid)
                xw = refresh(xw, sent)
                return (xw, resid), metrics(xw)

            xw0 = pvary(x0_full, (axis,))
            resid0 = pvary(jnp.zeros_like(xw0), (axis,))
            (xw, resid), (errs, resids) = round_scan(
                round_body, (xw0, resid0), keys)
            # Exact trailing flush of the outstanding residual: per-round
            # metrics above measured the compressed trajectory; the
            # returned iterate misses no update.
            return refresh(xw, resid), errs, resids

        def round_body(xw, rkey):
            xw, delta = local_phase(xw, rkey)
            sent, _ = wire(delta, None)
            xw = refresh(xw, sent)
            return xw, metrics(xw)

        xw, (errs, resids) = round_scan(round_body, pvary(x0_full, (axis,)),
                                        keys)
        if use_a2a:
            # Only the owners' slabs are globally consistent; reassemble
            # the full iterate from them (out_spec P(axis)).
            return col_slab(xw, w), errs, resids
        return xw, errs, resids

    out_specs = [P(axis, None) if use_a2a else P(None, None),
                 P(None, None), P(None, None)]
    if overlap:
        out_specs.append(P(None))
    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis),
                  P(axis, None), P(None), P(None, None), P(None, None)),
        out_specs=tuple(out_specs),
    )
    out = mapped(vals, cols, b, rn, a2a_masks, round_keys, x0, xs)
    return out if overlap else out + (None,)


# ---------------------------------------------------------------------------
# Batched-RHS chunked entry (the serving layer's engine surface)
# ---------------------------------------------------------------------------

class BatchedSolveResult(NamedTuple):
    x: jax.Array          # (n, k) iterate after the last executed chunk
    resid: jax.Array      # (k,) ||b - A x||_2 at exit, per RHS column
    #: (k,) int32: record chunks each column needed to reach ITS tolerance
    #: (columns that never reached it report the chunks actually run)
    rounds: jax.Array
    converged: jax.Array  # (k,) bool, resid <= tol at some record point
    iters_run: int        # iterations actually executed (<= num_iters)


def sequential_chunk(op, b, x, picks, *, action: str, beta: float = 1.0,
                     block: int = 1, fused: bool | str = False):
    """One record chunk of the sequential engine: ``picks.shape[0]`` steps
    from iterate ``x``; returns ``(x_next, resid)`` with ``resid`` the
    per-column ``||b - A x_next||_2``.

    This is the unit the serving layer's executor cache compiles once and
    re-launches per record point: the same (operator layout, k bucket,
    chunk length, statics) always maps to the same executable.  The
    arithmetic is the one-shot impls' own — they are invoked with the
    pre-drawn pick slice — so chaining chunks over consecutive
    ``draw_picks`` slices bitwise-reproduces ``solve_sequential``.
    ``fused="auto"`` resolves through the tuning table, exactly as in
    ``solve_sequential``.
    """
    if fused == "auto":
        fused = tune_runtime.resolve_fused(fused, op, action)
    impl = _sequential_scan_impl
    if fused and _fused_sweep_supported(op, action, block):
        impl = _sequential_fused_impl
    res = impl(op, b, x, None, action=action, key=jax.random.key(0),
               num_iters=picks.shape[0], beta=beta, block=block,
               record_every=0, picks=picks)
    return res.x, res.resid[-1]


def solve_batched(
    op,
    b: jax.Array,
    x0: jax.Array | None = None,
    *,
    action: str,
    key: jax.Array,
    num_iters: int,
    tol,
    record_every: int = 0,
    beta: float = 1.0,
    block: int = 1,
    fused: bool | str = False,
    chunk_fn=None,
    on_record=None,
) -> BatchedSolveResult:
    """Sequential solve over the multi-RHS axis with HETEROGENEOUS
    per-column tolerances and per-column round counts.

    ``tol`` is an absolute residual target — a scalar or a ``(k,)`` array,
    one entry per RHS column (the serving layer batches independent
    tenants' requests onto the columns, each with its own tolerance).
    The solve runs record chunk by record chunk (``record_every``
    iterations per chunk, validated by ``resolve_record_every``) and exits
    early once EVERY column has met its tolerance; a column's ``rounds``
    entry is the number of chunks it needed.  Columns are independent
    under both actions (the update ``gamma`` is computed per column), so
    each column's trajectory is bitwise the trajectory it would have had
    in any other batch with the same key — the property that makes
    cross-tenant batching safe.

    ``on_record(chunk_idx, x, resid, converged) -> bool`` is invoked at
    every record point (serving uses it to stream partial iterates and to
    enforce per-request deadlines); returning False stops the solve after
    that chunk.  ``chunk_fn`` overrides the chunk executor (the serving
    layer passes its cached executable); the default builds
    ``sequential_chunk`` with this call's statics.
    """
    if num_iters <= 0:
        raise ValueError(f"num_iters must be > 0 (got {num_iters})")
    rec = resolve_record_every(num_iters, record_every)
    chunks = num_iters // rec
    k = b.shape[1]
    if x0 is None:
        n_x = op.shape[0] if action == "gs" else op.shape[1]
        x0 = jnp.zeros((n_x, k), b.dtype)
    tol_np = np.broadcast_to(np.asarray(tol, np.float32), (k,))
    picks = draw_picks(op, action, key, num_iters, block=block)
    if chunk_fn is None:
        chunk_fn = functools.partial(sequential_chunk, action=action,
                                     beta=beta, block=block, fused=fused)
    x, resid = x0, None
    rounds = np.zeros((k,), np.int32)
    conv = np.zeros((k,), bool)
    ran = 0
    for c in range(chunks):
        x, resid = chunk_fn(op, b, x, picks[c * rec:(c + 1) * rec])
        ran = c + 1
        newly = ~conv & (np.asarray(resid) <= tol_np)
        rounds[newly] = ran
        conv |= newly
        go = on_record is None or bool(on_record(c, x, resid, conv.copy()))
        if conv.all() or not go:
            break
    rounds = np.where(conv, rounds, ran).astype(np.int32)
    return BatchedSolveResult(
        x=x, resid=resid, rounds=jnp.asarray(rounds),
        converged=jnp.asarray(conv), iters_run=ran * rec)


# ---------------------------------------------------------------------------
# Unified entry point: solve(problem, format=..., schedule=...)
# ---------------------------------------------------------------------------

def solve(
    problem,
    *,
    key: jax.Array,
    schedule: Schedule,
    format: str = "dense",
    action: str | None = None,
    mesh: jax.sharding.Mesh | None = None,
    axis: str = "workers",
    beta: float = 1.0,
    block: int = 128,
    bands: int = 2,
    width: int = 32,
    rows_per_panel: int | None = None,
    storage_dtype=None,
    gs_block: int = 1,
    x0: jax.Array | None = None,
    sync: str = "auto",
    fused: bool | str | None = None,
    unroll: bool = False,
    with_metrics: bool = True,
    delay_key: jax.Array | None = None,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
):
    """One front door for the whole solver family.

    ``problem`` is an ``SPDProblem`` (GS action by default) or an
    ``LSQProblem`` (Kaczmarz action by default).  ``format`` picks the
    operator ("dense", "banded", "ell", "csr"); ``schedule`` picks
    sequential / bounded-delay simulator / distributed execution (see
    ``Schedule``).  ``block``/``bands`` parameterize the banded format,
    ``width`` the ELL format, ``rows_per_panel`` the CSR panel layout
    (``None``, the default, asks the tuning table for the measured
    winner at this shape and falls back to 8 — the panel grouping never
    changes per-row summation order, so the choice is layout-only),
    ``storage_dtype`` the precision the operator's coefficients are held
    in (``None`` keeps the input dtype — bitwise-unchanged; the iterate,
    ``b`` and all accumulation stay f32 regardless), and ``gs_block`` the
    dense/CSR block-GS action granularity.  ``fused`` overrides
    ``schedule.fused`` (``None`` defers to the schedule): run inner loops
    as fused Pallas sweep kernels where the action × format has one,
    falling back to the per-step scan with a warning elsewhere;
    ``"auto"`` runs the tuning table's measured fused-vs-scan winner
    (see ``Schedule``).
    """
    if action is None:
        action = "rk" if hasattr(problem, "sigma_min") else "gs"
    # Validate the EFFECTIVE configuration, once: the ``fused`` keyword
    # override is folded into the schedule BEFORE ``validate()``, so an
    # invalid effective combination (e.g. ``fused=True`` forced onto the
    # bounded-delay simulator) fails here with a schedule-level error
    # instead of surviving to a late warning path.
    schedule = schedule if fused is None else schedule._replace(fused=fused)
    schedule.validate()
    use_fused = schedule.fused
    if rows_per_panel is None:
        rows_per_panel = tune_runtime.tuned_rows_per_panel(
            problem.A.shape[0], storage_dtype) or 8
    op = as_operator(problem.A, format, block=block, bands=bands, width=width,
                     rows_per_panel=rows_per_panel,
                     storage_dtype=storage_dtype)
    if x0 is None:
        # Shape/dtype from b and the operator, NOT from x_star: real
        # workloads carry x_star=None (nobody knows the solution), and
        # the RK iterate lives in column space while b lives in row space.
        n_x = op.shape[0] if action == "gs" else op.shape[1]
        x0 = jnp.zeros((n_x, problem.b.shape[1]), problem.b.dtype)

    if schedule.distributed:
        if mesh is None:
            raise ValueError("a distributed Schedule needs a mesh")
        return solve_distributed(
            op, problem.b, x0, problem.x_star, action=action, key=key,
            mesh=mesh, axis=axis, rounds=schedule.rounds,
            local_steps=schedule.local_steps, block=gs_block, beta=beta,
            sync=sync, partition=schedule.partition, fused=use_fused,
            overlap=schedule.overlap, compress=schedule.compress,
            unroll=unroll, with_metrics=with_metrics)
    if schedule.tau > 0:
        if delay_key is None:
            raise ValueError("the bounded-delay simulator needs a delay_key")
        return solve_async_sim(
            op, problem.b, x0, problem.x_star, action=action, key=key,
            delay_key=delay_key, num_iters=schedule.num_iters,
            tau=schedule.tau, beta=beta, read_model=read_model,
            delay_mode=delay_mode, miss_prob=miss_prob,
            record_every=schedule.record_every)
    return solve_sequential(
        op, problem.b, x0, problem.x_star, action=action, key=key,
        num_iters=schedule.num_iters, beta=beta, block=gs_block,
        record_every=schedule.record_every, fused=use_fused)


__all__ = [
    "BatchedSolveResult",
    "BlockBandedOp",
    "CsrOp",
    "DenseOp",
    "EllOp",
    "ParallelSolveResult",
    "Schedule",
    "SolveResult",
    "as_operator",
    "draw_picks",
    "record_metrics",
    "resolve_record_every",
    "sample_rows",
    "scheduled_tau",
    "sequential_chunk",
    "solve",
    "solve_async_sim",
    "solve_batched",
    "solve_distributed",
    "solve_sequential",
]
