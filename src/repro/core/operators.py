"""Unified matrix-format layer for the RGS/RK solver engine (DESIGN.md §3).

The paper's algorithms are one family — randomized row/coordinate actions
with bounded-staleness reads — and the matrix *format* is an orthogonal
axis: what changes between dense, block-banded, and ELL storage is only how
a row panel is read and which remote coordinates an update can touch.  This
module factors that axis out as operator classes sharing one protocol:

* ``matvec(x)``            — full ``A @ x`` (Pallas kernel on TPU, pure-jnp
                             reference on CPU / interpret mode);
* ``row_panel(bi)``        — the dense rows of aligned block ``bi``;
* ``residual_panel(...)``  — ``(b - A x)`` restricted to a block of rows;
* ``nnz_cost()``           — stored nonzeros (bytes/flops per matvec);
* ``halo_width``           — how far (in rows) an update's reads/writes can
                             reach outside an owned slab.  ``None`` means
                             unbounded (the sync strategy must replicate the
                             full iterate); a finite width lets the engine
                             choose neighbor halo exchange over all-gather;
* ``shard_spec(axis)``     — how the stored arrays shard over a worker axis.

Operators are registered pytrees, so they pass straight through ``jax.jit``
(arrays as leaves, static layout metadata as aux data).  The distributed
engine additionally uses the module-level ``banded_*`` panel routines, which
operate on a worker's *sharded* tile array inside ``shard_map`` — they are
kept as free functions (and their arithmetic is kept exactly as the
pre-refactor solvers wrote it) because the bit-identity contract of the
legacy entry points depends on the order of operations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import register_pytree_node_class

__all__ = [
    "BlockBandedOp",
    "CsrOp",
    "DenseOp",
    "EllOp",
    "as_operator",
    "banded_panel_residual",
    "banded_panel_residual_window",
    "banded_rows_matvec",
    "banded_window_matvec",
    "canonical_storage_dtype",
    "slab_neighbor_matrix",
]

# The storage-precision axis (DESIGN.md §7): coefficient panels may be held
# low-precision while row_norms_sq, sampling scales, and the iterate stay
# f32 — the kernels up-cast tiles on load and accumulate in f32.  ``None``
# keeps the input dtype untouched (the pre-existing behavior, bitwise).
STORAGE_DTYPES = ("float32", "bfloat16")


def canonical_storage_dtype(storage_dtype):
    """Validate/normalize a ``storage_dtype`` argument to a jnp dtype.

    ``None`` -> None (keep the input dtype, the bitwise-pinned default).
    """
    if storage_dtype is None:
        return None
    name = (storage_dtype if isinstance(storage_dtype, str)
            else jnp.dtype(storage_dtype).name)
    if name not in STORAGE_DTYPES:
        raise ValueError(
            f"unknown storage_dtype: {storage_dtype!r} "
            f"(choose from {STORAGE_DTYPES})")
    return jnp.dtype(name)


def _index_dtype(values_dtype, n: int):
    """Column-index dtype paired with a value dtype: low-precision values
    narrow the index stream to int16 when every column id fits, halving
    the index bytes alongside the value bytes (the sparse paths are
    bandwidth-bound, so the index stream is half the win)."""
    if jnp.dtype(values_dtype).itemsize < 4 and n <= np.iinfo(np.int16).max:
        return np.int16
    return np.int32


def slab_neighbor_matrix(rows, cols, real, m: int, n: int,
                         num_workers: int) -> np.ndarray:
    """Host-side neighbor graph of a row-slab partition.

    ``need[w, v]`` is True when worker ``w``'s rows (slab ``[w*m/P,
    (w+1)*m/P)``) read at least one coefficient owned by worker ``v``
    (column slab ``[v*n/P, (v+1)*n/P)``).  The diagonal is always True.
    This is what the engine's ``sync="a2a"`` strategy builds its masked
    ppermute schedule from — and what lets it fall back to all-gather when
    the graph is dense.
    """
    if m % num_workers or n % num_workers:
        raise ValueError(
            f"worker count ({num_workers}) must divide rows ({m}) and "
            f"columns ({n}) for a slab partition")
    rows = np.asarray(rows).reshape(-1)
    cols = np.asarray(cols).reshape(-1)
    real = np.asarray(real).reshape(-1)
    need = np.zeros((num_workers, num_workers), bool)
    w = rows[real] // (m // num_workers)
    v = cols[real] // (n // num_workers)
    need[w, v] = True
    np.fill_diagonal(need, True)
    return need


# ---------------------------------------------------------------------------
# Operator classes
# ---------------------------------------------------------------------------

@register_pytree_node_class
class DenseOp:
    """Dense row-major operator — square SPD or rectangular (m, n)."""

    def __init__(self, A: jax.Array):
        self.A = A

    def tree_flatten(self):
        return (self.A,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self) -> tuple[int, int]:
        return self.A.shape

    @property
    def halo_width(self):
        """Dense rows read every column: no finite halo."""
        return None

    def matvec(self, x: jax.Array) -> jax.Array:
        return self.A @ x

    def row(self, r) -> jax.Array:
        return self.A[r]

    def row_dot(self, r, x: jax.Array) -> jax.Array:
        """``A[r] @ x`` — the Θ(n) read a coordinate/row action performs."""
        return self.A[r] @ x

    def row_panel(self, bi, block: int) -> jax.Array:
        rows = bi * block + jnp.arange(block)
        return self.A[rows]

    def residual_panel(self, b, x, bi, block: int) -> jax.Array:
        rows = bi * block + jnp.arange(block)
        return b[rows] - self.A[rows] @ x

    def row_norms_sq(self) -> jax.Array:
        """Per-row ||A_i||² — always f32 (sampling/divisors stay exact)."""
        A = self.A.astype(jnp.float32)
        return jnp.einsum("mn,mn->m", A, A)

    def rk_update(self, x, r, g, beta):
        """Kaczmarz row action, exact legacy operation order (the row
        up-casts to f32; identity for f32 storage)."""
        return x + beta * self.A[r].astype(jnp.float32)[:, None] * g[None, :]

    def nnz_cost(self) -> int:
        m, n = self.A.shape
        return m * n

    def shard_spec(self, axis: str) -> P:
        return P(axis, None)

    def to_dense(self) -> jax.Array:
        return self.A


@register_pytree_node_class
class BlockBandedOp:
    """Block-banded operator: tiles ``A_bands[nb, 2*bands+1, block, block]``.

    The TPU-native sparse layout (kernels/bbmv.py): contiguous HBM->VMEM
    streams, MXU-shaped tiles, and a *finite halo* — a row panel only ever
    reads x within ``bands*block`` rows of itself, which is what lets the
    distributed engine swap the all-gather for a neighbor halo exchange.
    """

    def __init__(self, A_bands: jax.Array, *, bands: int):
        self.A_bands = A_bands
        self.bands = bands

    def tree_flatten(self):
        return (self.A_bands,), self.bands

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, bands=aux)

    @classmethod
    def from_dense(cls, A: jax.Array, *, block: int, bands: int,
                   storage_dtype=None) -> "BlockBandedOp":
        from repro.kernels.bbmv import dense_to_bands
        tiles = dense_to_bands(A, bands=bands, block=block)
        dt = canonical_storage_dtype(storage_dtype)
        if dt is not None:
            tiles = tiles.astype(dt)
        return cls(tiles, bands=bands)

    @property
    def nb(self) -> int:
        return self.A_bands.shape[0]

    @property
    def block(self) -> int:
        return self.A_bands.shape[2]

    @property
    def width(self) -> int:
        return self.A_bands.shape[1]

    @property
    def n(self) -> int:
        return self.nb * self.block

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.n)

    @property
    def halo_width(self) -> int:
        return self.bands * self.block

    def matvec(self, x: jax.Array, *, interpret=None) -> jax.Array:
        """``A @ x`` via the Pallas kernel (interpret-mode on CPU)."""
        from repro.kernels import ops
        return ops.bbmv(self.A_bands, x, bands=self.bands, block=self.block,
                        interpret=interpret)

    def matvec_ref(self, x: jax.Array) -> jax.Array:
        """Pure-jnp reference matvec (no Pallas)."""
        return banded_rows_matvec(self.A_bands, x, 0, self.nb, self.nb,
                                  self.block, self.bands)

    def packed_band_tiles(self) -> jax.Array:
        """Border tiles zero-padded for the fused sweep kernels (which
        bake validity into the data instead of masking) — the single
        packing definition the sequential ``gs_sweep`` and the engine's
        three fused distributed banded strategies all share."""
        from repro.kernels.banded_gs import pack_bands_local
        return pack_bands_local(self.A_bands, 0, self.nb, self.nb,
                                self.bands)

    def gs_sweep(self, b, x, picks, *, beta: float = 1.0,
                 interpret=None) -> jax.Array:
        """Fused sequential block-GS sweep: ``len(picks)`` block-row
        updates in one Pallas launch (kernels/banded_gs.py), the iterate
        VMEM-resident throughout.  Border validity is baked into the data
        (``packed_band_tiles``; ``dense_to_bands`` already stores border
        tiles as zeros), so the arithmetic — and the iterate — is bitwise
        the scan engine's."""
        from repro.kernels import ops
        halo = self.bands * self.block
        xw = jnp.pad(x, ((halo, halo), (0, 0)))
        xw = ops.banded_gs_sweep(self.packed_band_tiles(), b, xw, picks,
                                 block=self.block, bands=self.bands,
                                 beta=beta, interpret=interpret)
        return xw[halo:halo + self.n]

    def row_panel(self, bi) -> jax.Array:
        """Dense (block, n) rows of block-row ``bi`` (diagnostic use)."""
        tiles = self.A_bands[bi]                       # (width, block, block)
        out = jnp.zeros((self.block, self.n), tiles.dtype)
        for d in range(self.width):
            cb = bi + d - self.bands
            cbc = jnp.clip(cb, 0, self.nb - 1)
            valid = (cb >= 0) & (cb < self.nb)
            out = jax.lax.dynamic_update_slice(
                out, jnp.where(valid, tiles[d], 0.0), (0, cbc * self.block))
        return out

    def residual_panel(self, b, x, bi) -> jax.Array:
        """``(b - A x)`` on block-row ``bi`` — Θ(width) tile reads."""
        return banded_panel_residual(
            self.A_bands, b, x, bi, bi, self.nb, self.block, self.bands)

    def row_norms_sq(self) -> jax.Array:
        """Per-row ||A_i||^2 from the tiles, shaped (nb, block) — always
        computed (and returned) in f32 regardless of the tile storage
        dtype: the sampling distribution and RK divisors stay exact."""
        t = self.A_bands.astype(jnp.float32)
        return jnp.sum(t * t, axis=(1, 3))

    def nnz_cost(self) -> int:
        return self.nb * self.width * self.block * self.block

    def shard_spec(self, axis: str) -> P:
        return P(axis, None, None, None)

    def to_dense(self) -> jax.Array:
        out = jnp.zeros((self.n, self.n), self.A_bands.dtype)
        for bi in range(self.nb):
            for d in range(self.width):
                cb = bi + d - self.bands
                if 0 <= cb < self.nb:
                    out = out.at[bi * self.block:(bi + 1) * self.block,
                                 cb * self.block:(cb + 1) * self.block].set(
                        self.A_bands[bi, d])
        return out


@register_pytree_node_class
class EllOp:
    """Fixed-width ELLPACK operator: ``vals``/``cols`` of shape (n, width).

    The GPU-style gather format (kernels/spmv_ell.py) — kept as a first-class
    format so the engine's sequential row actions get a true Θ(nnz) read on
    unstructured sparsity, and as the contrast case in the kernel benchmarks.
    """

    def __init__(self, vals: jax.Array, cols: jax.Array):
        self.vals = vals
        self.cols = cols
        self._neighbors_cache: dict[int, "np.ndarray"] = {}

    def tree_flatten(self):
        return (self.vals, self.cols), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_dense(cls, A: jax.Array, *, width: int,
                   storage_dtype=None) -> "EllOp":
        from repro.core.spd import ell_from_dense
        vals, cols = ell_from_dense(A, width)
        dt = canonical_storage_dtype(storage_dtype)
        if dt is not None:
            vals = vals.astype(dt)
            cols = cols.astype(_index_dtype(dt, A.shape[1]))
        return cls(vals, cols)

    @property
    def shape(self) -> tuple[int, int]:
        n = self.vals.shape[0]
        return (n, n)

    @property
    def width(self) -> int:
        return self.vals.shape[1]

    @property
    def halo_width(self):
        """Gather columns are unstructured: no finite halo."""
        return None

    def matvec(self, x: jax.Array, *, interpret=None) -> jax.Array:
        from repro.kernels import ops
        return ops.spmv_ell(self.vals, self.cols, x, interpret=interpret)

    def matvec_ref(self, x: jax.Array) -> jax.Array:
        from repro.kernels import ref
        return ref.spmv_ell_ref(self.vals, self.cols, x)

    def row_dot(self, r, x: jax.Array) -> jax.Array:
        """``A[r] @ x`` in Θ(width): gather the row's columns only (the
        value window up-casts to f32; identity for f32 storage)."""
        return jnp.einsum("w,wk->k", self.vals[r].astype(jnp.float32),
                          x[self.cols[r]])

    def row_norms_sq(self) -> jax.Array:
        """Per-row ||A_i||² — always f32 (sampling/divisors stay exact)."""
        v = self.vals.astype(jnp.float32)
        return jnp.einsum("nw,nw->n", v, v)

    def rk_update(self, x, r, g, beta):
        """Kaczmarz row action as a Θ(width) scatter-add (padding cols carry
        zero values, so duplicate indices contribute nothing).  The value
        window up-casts to f32 so low-precision storage still applies an
        f32-accumulated update (identity for f32 storage)."""
        vw = self.vals[r].astype(jnp.float32)
        return x.at[self.cols[r]].add(beta * vw[:, None] * g[None, :])

    def nnz_cost(self) -> int:
        n, w = self.vals.shape
        return n * w

    def padded_rows(self) -> tuple[jax.Array, jax.Array]:
        """ELL already is the per-row padded-window form (CsrOp protocol)."""
        return self.vals, self.cols

    def gs_sweep(self, b, x, picks, *, beta: float = 1.0, write_base=0,
                 interpret=None) -> jax.Array:
        """Fused sequential coordinate-GS sweep (kernels/sweep_ell.py).
        ``write_base`` offsets writes for distributed slab-local phases."""
        from repro.kernels import ops
        return ops.sweep_ell_gs(self.vals, self.cols, b, x, picks,
                                beta=beta, write_base=write_base,
                                interpret=interpret)

    def rk_sweep(self, b, rn, x, picks, *, beta: float = 1.0,
                 interpret=None) -> jax.Array:
        """Fused sequential Kaczmarz sweep (kernels/sweep_ell.py).  ``rn``
        is the caller's row-norm vector so the divisor matches the scan
        engine's sampling distribution exactly."""
        from repro.kernels import ops
        return ops.sweep_ell_rk(self.vals, self.cols, b, rn, x, picks,
                                beta=beta, interpret=interpret)

    def slab_neighbors(self, num_workers: int) -> np.ndarray:
        """Row-slab neighbor graph (host-side; see slab_neighbor_matrix).
        Memoized per worker count, like CsrOp."""
        if num_workers not in self._neighbors_cache:
            n, w = self.vals.shape
            rows = np.broadcast_to(np.arange(n)[:, None], (n, w))
            self._neighbors_cache[num_workers] = slab_neighbor_matrix(
                rows, self.cols, np.asarray(self.vals) != 0, n, n,
                num_workers)
        return self._neighbors_cache[num_workers]

    def shard_spec(self, axis: str) -> P:
        return P(axis, None)

    def to_dense(self) -> jax.Array:
        n = self.vals.shape[0]
        out = jnp.zeros((n, n), self.vals.dtype)
        return out.at[jnp.arange(n)[:, None], self.cols].add(self.vals)


@register_pytree_node_class
class CsrOp:
    """General compressed-sparse-row operator, panel-aligned for the TPU.

    The format of the paper's reference scenario: unstructured sparsity,
    arbitrary (possibly rectangular) shape, exact nonzero storage.  Layout
    (kernels/spmv_csr.py): nonzeros stay in row-major CSR order, but each
    *panel* of ``rows_per_panel`` consecutive rows is padded to a common
    nnz budget ``panel_width`` (a lane multiple), so the flat arrays
    reshape to ``(num_panels, panel_width)`` and stream contiguously.

    * ``data``/``indices``/``row_id`` — value, column, and row of every
      slot (padding slots carry value 0, so they never contribute);
    * ``row_start``/``row_nnz`` — the CSR row pointers against the padded
      layout: row ``r`` occupies slots ``[row_start[r], row_start[r] +
      row_nnz[r])``, always contiguous and never straddling a panel.
      The flat arrays keep ``row_cap`` slack slots past the last panel so a
      fixed-size ``row_cap`` window read never runs off the end.

    In place of the scalar ``halo_width`` (meaningless for unstructured
    sparsity — a single far-off coupling would inflate it to n), the format
    answers *per-row reach* queries: ``row_reach()`` per row, and
    ``slab_neighbors(P)`` — the row-slab neighbor graph the distributed
    engine's ``sync="a2a"`` strategy exchanges along.
    """

    def __init__(self, data, indices, row_id, row_start, row_nnz, *,
                 shape, nnz, row_cap, rows_per_panel, panel_width):
        self.data = data
        self.indices = indices
        self.row_id = row_id
        self.row_start = row_start
        self.row_nnz = row_nnz
        self._shape = tuple(shape)
        self.nnz = nnz
        self.row_cap = row_cap
        self.rows_per_panel = rows_per_panel
        self.panel_width = panel_width
        self._neighbors_cache: dict[int, np.ndarray] = {}
        self._panel_nnz_cache: jax.Array | None = None
        self._sliced_cache: tuple[jax.Array, jax.Array] | None = None

    def tree_flatten(self):
        leaves = (self.data, self.indices, self.row_id, self.row_start,
                  self.row_nnz)
        aux = (self._shape, self.nnz, self.row_cap, self.rows_per_panel,
               self.panel_width)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, nnz, row_cap, rows_per_panel, panel_width = aux
        return cls(*children, shape=shape, nnz=nnz, row_cap=row_cap,
                   rows_per_panel=rows_per_panel, panel_width=panel_width)

    @classmethod
    def from_dense(cls, A: jax.Array, *, rows_per_panel: int = 8,
                   lane: int = 128, storage_dtype=None) -> "CsrOp":
        """Exact CSR capture of every nonzero of dense ``A`` (host-side).

        ``storage_dtype`` rounds the captured *values* to a low-precision
        storage dtype (the pattern is taken from the input dtype first, so
        the stored sparsity is dtype-independent); column indices narrow
        to int16 alongside when every id fits (``_index_dtype``)."""
        An = np.asarray(A)
        m, n = An.shape
        nz = An != 0.0
        counts = nz.sum(axis=1).astype(np.int64)
        cap = max(int(counts.max()) if m else 1, 1)
        row_vals = np.zeros((max(m, 1), cap), An.dtype)
        row_cols = np.zeros((max(m, 1), cap), np.int32)
        for r in range(m):
            cj = np.nonzero(nz[r])[0]
            row_vals[r, :cj.size] = An[r, cj]
            row_cols[r, :cj.size] = cj
        dt = canonical_storage_dtype(storage_dtype)
        if dt is not None:
            row_vals = row_vals.astype(dt)
        return cls._assemble(row_vals, row_cols, counts, shape=(m, n),
                             rows_per_panel=rows_per_panel, lane=lane)

    @classmethod
    def _assemble(cls, row_vals, row_cols, counts, *, shape,
                  rows_per_panel: int = 8, lane: int = 128) -> "CsrOp":
        """Pack per-row nonzero windows into the panel-aligned flat layout.

        ``row_vals``/``row_cols`` are host arrays of shape (m, >= max nnz/row)
        whose first ``counts[r]`` slots hold row ``r``'s values and *global*
        column ids (slots past the count are ignored); this is the shared
        assembly path of ``from_dense`` and the row-permutation constructor
        in ``core.partition`` (a permuted operator re-panelizes here so the
        panel machinery never sees non-contiguity).
        """
        m, n = shape
        counts = np.asarray(counts, np.int64).reshape(-1)
        nnz = int(counts.sum())
        row_cap = max(int(counts.max()) if m else 1, 1)
        R = rows_per_panel
        num_panels = -(-m // R)
        padded_counts = np.zeros((num_panels * R,), np.int64)
        padded_counts[:m] = counts
        panel_nnz = padded_counts.reshape(num_panels, R).sum(axis=1)
        W = int(-(-max(int(panel_nnz.max()) if num_panels else 1, 1) // lane)
                * lane)
        total = num_panels * W + row_cap        # row-window slack at the end
        vals_np = np.asarray(row_vals)
        data = np.zeros((total,), vals_np.dtype)
        # Low-precision values narrow the column stream too (re-derived
        # here so re-assembly — e.g. partition.permute_rows — preserves
        # the compact layout); row_id/row_start stay int32: row_start
        # addresses the flat layout, whose extent is not bounded by n.
        cols = np.zeros((total,), _index_dtype(vals_np.dtype, n))
        rows = np.zeros((total,), np.int32)
        row_start = np.zeros((max(m, 1),), np.int32)
        for p in range(num_panels):
            cursor = p * W
            for r in range(p * R, min((p + 1) * R, m)):
                c = int(counts[r])
                row_start[r] = cursor
                data[cursor:cursor + c] = row_vals[r, :c]
                cols[cursor:cursor + c] = row_cols[r, :c]
                rows[cursor:cursor + c] = r
                cursor += c
        return cls(jnp.asarray(data), jnp.asarray(cols),
                   jnp.asarray(rows), jnp.asarray(row_start),
                   jnp.asarray(counts.astype(np.int32)),
                   shape=(m, n), nnz=nnz, row_cap=row_cap,
                   rows_per_panel=R, panel_width=W)

    @property
    def shape(self) -> tuple[int, int]:
        return self._shape

    @property
    def halo_width(self):
        """Unstructured reach: no *scalar* halo (see ``row_reach``)."""
        return None

    def matvec(self, x: jax.Array, *, interpret=None,
               skip_empty: bool | None = None,
               variant: str | None = None) -> jax.Array:
        """``A @ x`` — the tunable CSR matvec dispatch seam.

        Four pinned kernel variants serve this entry point: the sliced-ELL
        gather-accumulate kernel (``"sliced"``, the PR-5 overhaul that
        retired the one-hot-matmul segment sum from the matvec path), its
        empty-panel-predicated twin (``"sliced_prefetch"`` —
        scalar-prefetched per-panel nnz counts; empty panels — common
        after norm-balanced partitioning of banded-structure matrices —
        write zeros without gathering ``x``), and the legacy segment-sum
        pair (``"segsum"`` / ``"segsum_prefetch"``, the measured contrast
        case).  Selection order (repro.tune):

        1. an explicit ``variant`` forces that kernel (bitwise-pinned);
        2. an explicit ``skip_empty`` bool forces the pre-autotune pick:
           the sliced kernel, predicated iff True (bitwise-pinned);
        3. the active tuning table's ``matvec`` entry for this operator's
           shape bucket and storage dtype, when one exists;
        4. the pre-autotune auto-selection, bitwise-unchanged: the
           predicated sliced kernel when the stored pattern actually has
           empty panels, the plain sliced kernel otherwise.

        The predication stream (``panel_nnz``) needs concrete metadata, so
        under jit tracing steps 3–4 drop to the variant's non-prefetch
        sibling (exactly the pre-autotune tracer behavior)."""
        from repro.kernels import ops
        if variant is None:
            if skip_empty is not None:
                variant = "sliced_prefetch" if skip_empty else "sliced"
            else:
                from repro.tune import runtime as tune_runtime
                variant = tune_runtime.matvec_variant(self)
                if variant is None:
                    if isinstance(self.row_nnz, jax.core.Tracer):
                        variant = "sliced"
                    else:
                        empty = bool(
                            (np.asarray(self.panel_nnz()) == 0).any())
                        variant = "sliced_prefetch" if empty else "sliced"
                elif variant.endswith("_prefetch") \
                        and isinstance(self.row_nnz, jax.core.Tracer):
                    variant = variant[:-len("_prefetch")]
        if variant in ("segsum", "segsum_prefetch"):
            if variant == "segsum_prefetch":
                return ops.spmv_csr_prefetch(
                    self.data, self.indices, self.row_id, self.panel_nnz(),
                    x, m=self._shape[0], rows_per_panel=self.rows_per_panel,
                    panel_width=self.panel_width, interpret=interpret)
            return ops.spmv_csr(self.data, self.indices, self.row_id, x,
                                m=self._shape[0],
                                rows_per_panel=self.rows_per_panel,
                                panel_width=self.panel_width,
                                interpret=interpret)
        if variant not in ("sliced", "sliced_prefetch"):
            from repro.tune.table import MATVEC_VARIANTS
            raise ValueError(f"unknown matvec variant: {variant!r} "
                             f"(expected one of {MATVEC_VARIANTS})")
        vals, cols = self.sliced_rows()
        if variant == "sliced_prefetch":
            return ops.spmv_csr_sliced_prefetch(
                vals, cols, self.panel_nnz(), x, m=self._shape[0],
                rows_per_panel=self.rows_per_panel, interpret=interpret)
        return ops.spmv_csr_sliced(vals, cols, x, m=self._shape[0],
                                   rows_per_panel=self.rows_per_panel,
                                   interpret=interpret)

    def matvec_segsum(self, x: jax.Array, *, interpret=None,
                      skip_empty: bool = False) -> jax.Array:
        """The legacy segment-sum-as-one-hot-matmul matvec kernels, kept
        as the measured contrast case (benchmarks/bench_kernels.py) and as
        an independent second kernel implementation in the conformance
        tests."""
        from repro.kernels import ops
        if skip_empty:
            return ops.spmv_csr_prefetch(
                self.data, self.indices, self.row_id, self.panel_nnz(), x,
                m=self._shape[0], rows_per_panel=self.rows_per_panel,
                panel_width=self.panel_width, interpret=interpret)
        return ops.spmv_csr(self.data, self.indices, self.row_id, x,
                            m=self._shape[0],
                            rows_per_panel=self.rows_per_panel,
                            panel_width=self.panel_width, interpret=interpret)

    def sliced_rows(self) -> tuple[jax.Array, jax.Array]:
        """Sliced-ELL view of the stored nonzeros: the ``padded_rows()``
        windows padded to a lane-friendly width and to whole panels
        (``num_panels * rows_per_panel`` rows), panel-major — what the
        gather-accumulate matvec kernels stream.  Memoized host-side when
        the leaves are concrete (the view is static metadata of the stored
        pattern); recomputed in-graph under jit."""
        if self._sliced_cache is not None:
            return self._sliced_cache
        R = self.rows_per_panel
        m = self._shape[0]
        mp = -(-max(m, 1) // R) * R
        width = -(-self.row_cap // 8) * 8
        vals, cols = self.padded_rows()
        if width > self.row_cap or mp > m:
            vals = jnp.pad(vals, ((0, mp - m), (0, width - self.row_cap)))
            cols = jnp.pad(cols, ((0, mp - m), (0, width - self.row_cap)))
        if not isinstance(self.data, jax.core.Tracer):
            self._sliced_cache = (vals, cols)
        return vals, cols

    def panel_nnz(self) -> jax.Array:
        """Per-panel stored-nonzero counts, shape (num_panels,) — the
        predicate stream the empty-panel-skipping matvec prefetches.
        Memoized: it is static metadata of the stored pattern, and the
        skip variant consults it on every matvec."""
        if self._panel_nnz_cache is None:
            R = self.rows_per_panel
            m = self._shape[0]
            num_panels = -(-m // R)
            # Host-side, like slab_neighbors: never caches a tracer (an
            # attempt to trace through raises a concretization error).
            padded = np.zeros((num_panels * R,), np.int64)
            padded[:m] = np.asarray(self.row_nnz)
            self._panel_nnz_cache = jnp.asarray(
                padded.reshape(num_panels, R).sum(axis=1).astype(np.int32))
        return self._panel_nnz_cache

    def matvec_ref(self, x: jax.Array) -> jax.Array:
        from repro.kernels import ref
        return ref.spmv_csr_ref(self.data, self.indices, self.row_id, x,
                                m=self._shape[0])

    def _row_window(self, r):
        """Row ``r``'s values/columns as a fixed Θ(row_cap) masked window."""
        vw = jax.lax.dynamic_slice_in_dim(self.data, self.row_start[r],
                                          self.row_cap, 0)
        cw = jax.lax.dynamic_slice_in_dim(self.indices, self.row_start[r],
                                          self.row_cap, 0)
        mask = jnp.arange(self.row_cap) < self.row_nnz[r]
        return jnp.where(mask, vw, 0.0), jnp.where(mask, cw, 0)

    def row_dot(self, r, x: jax.Array) -> jax.Array:
        """``A[r] @ x`` in Θ(row_cap): gather the row's columns only (the
        value window up-casts to f32; identity for f32 storage)."""
        vw, cw = self._row_window(r)
        return jnp.einsum("w,wk->k", vw.astype(jnp.float32), x[cw])

    def row_panel(self, bi, block: int) -> jax.Array:
        """Dense (block, n) rows of aligned block ``bi`` (block-GS reads)."""
        rows = bi * block + jnp.arange(block)
        vw, cw = jax.vmap(self._row_window)(rows)
        out = jnp.zeros((block, self._shape[1]), self.data.dtype)
        return out.at[jnp.arange(block)[:, None], cw].add(vw)

    def residual_panel(self, b, x, bi, block: int) -> jax.Array:
        """``(b - A x)`` on aligned row block ``bi`` — Θ(block·row_cap)."""
        rows = bi * block + jnp.arange(block)
        dots = jax.vmap(lambda r: self.row_dot(r, x))(rows)
        return b[rows] - dots

    def row_norms_sq(self) -> jax.Array:
        """Per-row ||A_i||² — always f32 (sampling/divisors stay exact)."""
        d = self.data.astype(jnp.float32)
        return jax.ops.segment_sum(d * d, self.row_id,
                                   num_segments=self._shape[0])

    def rk_update(self, x, r, g, beta):
        """Kaczmarz row action as a Θ(row_cap) scatter-add (masked padding
        slots carry zero values, so duplicate indices contribute nothing).
        The value window up-casts to f32 (identity for f32 storage)."""
        vw, cw = self._row_window(r)
        vw = vw.astype(jnp.float32)
        return x.at[cw].add(beta * vw[:, None] * g[None, :])

    def padded_rows(self) -> tuple[jax.Array, jax.Array]:
        """(m, row_cap) per-row value/column windows with global column ids
        — the slab-shardable form the distributed engine partitions."""
        idx = self.row_start[:, None] + jnp.arange(self.row_cap)[None, :]
        idx = jnp.minimum(idx, self.data.shape[0] - 1)
        mask = jnp.arange(self.row_cap)[None, :] < self.row_nnz[:, None]
        vals = jnp.where(mask, self.data[idx], 0.0)
        cols = jnp.where(mask, self.indices[idx], 0)
        return vals, cols

    def gs_sweep(self, b, x, picks, *, beta: float = 1.0, write_base=0,
                 interpret=None) -> jax.Array:
        """Fused sequential coordinate-GS sweep (kernels/sweep_csr.py):
        the row windows stream via scalar-prefetch index maps over the
        ``padded_rows()`` form — the same masked windows ``row_dot``
        reads, so the iterate is bitwise the scan engine's.
        ``write_base`` offsets writes for distributed slab-local phases."""
        from repro.kernels import ops
        vals, cols = self.padded_rows()
        return ops.sweep_rows_gs(vals, cols, b, x, picks, beta=beta,
                                 write_base=write_base,
                                 interpret=interpret)

    def rk_sweep(self, b, rn, x, picks, *, beta: float = 1.0,
                 interpret=None) -> jax.Array:
        """Fused sequential Kaczmarz sweep (kernels/sweep_csr.py).  ``rn``
        is the caller's row-norm vector so the divisor matches the scan
        engine's sampling distribution exactly."""
        from repro.kernels import ops
        vals, cols = self.padded_rows()
        return ops.sweep_rows_rk(vals, cols, b, rn, x, picks, beta=beta,
                                 interpret=interpret)

    def row_reach(self) -> jax.Array:
        """Per-row reach ``max_j |col_ij - i|`` — the per-row refinement of
        the scalar ``halo_width`` (square systems; 0 for empty rows)."""
        d = jnp.abs(self.indices - self.row_id)
        d = jnp.where(self.data != 0, d, 0)
        return jnp.maximum(
            jax.ops.segment_max(d, self.row_id,
                                num_segments=self._shape[0]), 0)

    def slab_neighbors(self, num_workers: int) -> np.ndarray:
        """Row-slab neighbor graph (host-side; see slab_neighbor_matrix).
        Memoized per worker count — the graph is a property of the stored
        sparsity pattern, and solve_distributed consults it every call."""
        if num_workers not in self._neighbors_cache:
            m, n = self._shape
            self._neighbors_cache[num_workers] = slab_neighbor_matrix(
                self.row_id, self.indices, np.asarray(self.data) != 0,
                m, n, num_workers)
        return self._neighbors_cache[num_workers]

    def nnz_cost(self) -> int:
        return self.nnz

    def shard_spec(self, axis: str) -> P:
        """Spec of the ``padded_rows()`` slab form the engine shards (the
        flat panel layout itself does not split evenly on a row axis)."""
        return P(axis, None)

    def to_dense(self) -> jax.Array:
        out = jnp.zeros(self._shape, self.data.dtype)
        return out.at[self.row_id, self.indices].add(self.data)


def as_operator(A: jax.Array, format: str = "dense", *, block: int = 128,
                bands: int = 2, width: int = 32, rows_per_panel: int = 8,
                storage_dtype=None):
    """Build an operator of the requested ``format`` from a dense matrix.

    ``storage_dtype`` ("float32"/"bfloat16"/None) selects the coefficient
    storage precision for every format; ``None`` keeps the input dtype
    (the bitwise-pinned default).  The iterate, ``row_norms_sq``, and all
    kernel accumulators stay f32 regardless.
    """
    dt = canonical_storage_dtype(storage_dtype)
    if format == "dense":
        return DenseOp(A if dt is None else jnp.asarray(A).astype(dt))
    if format == "banded":
        return BlockBandedOp.from_dense(A, block=block, bands=bands,
                                        storage_dtype=storage_dtype)
    if format == "ell":
        return EllOp.from_dense(A, width=width, storage_dtype=storage_dtype)
    if format == "csr":
        return CsrOp.from_dense(A, rows_per_panel=rows_per_panel,
                                storage_dtype=storage_dtype)
    raise ValueError(f"unknown operator format: {format!r}")


# ---------------------------------------------------------------------------
# Shard-local banded panel routines (used inside shard_map by the engine)
# ---------------------------------------------------------------------------
# The arithmetic below is transplanted *verbatim* from the pre-refactor
# parallel_rgs solvers: the legacy entry points' bit-identity contract
# depends on the exact operation order, so do not "simplify" these.

def banded_panel_residual(Ab_sh, b_sh, xw, bi_local, gb, nb, block, bands):
    """``(b - A x)`` on a worker's local block-row, reading the *global*
    (n, k) iterate ``xw``.  ``gb`` is the global block-row index of
    ``bi_local`` (``gb = w * nb_local + bi_local`` under sharding)."""
    width = 2 * bands + 1
    acc = jax.lax.dynamic_slice_in_dim(
        b_sh, bi_local * block, block, 0).astype(jnp.float32)
    tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi_local, 1, 0)[0]
    for d in range(width):
        cb = gb + d - bands                  # global column block
        cbc = jnp.clip(cb, 0, nb - 1)
        xs = jax.lax.dynamic_slice_in_dim(xw, cbc * block, block, 0)
        contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
        valid = (cb >= 0) & (cb < nb)
        acc = acc - jnp.where(valid, contrib, 0.0)
    return acc.astype(xw.dtype)


def banded_panel_residual_window(Ab_sh, b_sh, xw, bi, gb, nb, slab, block,
                                 bands):
    """``(b - A x)`` on local block-row ``bi``, reading a halo-padded
    *window* ``xw`` of shape (slab + 2*bands*block, k)."""
    width = 2 * bands + 1
    halo = bands * block
    acc = jax.lax.dynamic_slice_in_dim(
        b_sh, bi * block, block, 0).astype(jnp.float32)
    tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi, 1, 0)[0]
    for d in range(width):
        cb = gb + d - bands
        xs = jax.lax.dynamic_slice_in_dim(
            xw, jnp.clip((bi + d) * block, 0, slab + 2 * halo - block),
            block, 0)
        contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
        acc = acc - jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
    return acc.astype(xw.dtype)


def banded_rows_matvec(Ab_sh, x, w, nb, nb_local, block, bands):
    """``(A x)`` for the ``nb_local`` block-rows owned by worker ``w``,
    reading the global (n, k) vector ``x``."""
    width = 2 * bands + 1

    def one(bi):
        gb = w * nb_local + bi
        acc = jnp.zeros((block, x.shape[1]), jnp.float32)
        for d in range(width):
            cb = gb + d - bands
            cbc = jnp.clip(cb, 0, nb - 1)
            xs = jax.lax.dynamic_slice_in_dim(x, cbc * block, block, 0)
            contrib = jnp.dot(Ab_sh[bi, d], xs, preferred_element_type=jnp.float32)
            acc = acc + jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
        return acc.astype(x.dtype)

    out = jax.vmap(one)(jnp.arange(nb_local))          # (nb_local, block, k)
    return out.reshape(nb_local * block, x.shape[1])


def banded_window_matvec(Ab_sh, vw, w, nb, nb_local, block, bands):
    """``(A v)`` for the worker's own block-rows, reading a halo-padded
    window ``vw`` of shape (nb_local*block + 2*bands*block, k)."""
    width = 2 * bands + 1
    slab = nb_local * block
    halo = bands * block

    def one(bi):
        gb = w * nb_local + bi
        acc = jnp.zeros((block, vw.shape[1]), jnp.float32)
        for d in range(width):
            cb = gb + d - bands
            xs = jax.lax.dynamic_slice_in_dim(
                vw, jnp.clip((bi + d) * block, 0, slab + 2 * halo - block),
                block, 0)
            contrib = jnp.dot(Ab_sh[bi, d], xs, preferred_element_type=jnp.float32)
            acc = acc + jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
        return acc.astype(vw.dtype)

    out = jax.vmap(one)(jnp.arange(nb_local))
    return out.reshape(slab, vw.shape[1])
