"""SPD problem construction and normalization utilities.

The paper assumes WLOG a unit diagonal (Sec. 2.3): for a general SPD ``B``
we solve ``A x = D z`` with ``A = D B D``, ``D = diag(B)^{-1/2}``, and map the
iterates back by ``y = D x``.  The generators below produce the paper's
*reference scenario*: large sparse SPD with between C1 and C2 nonzeros per
row and a small C2/C1 ratio.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SPDProblem(NamedTuple):
    """A unit-diagonal SPD system ``A x = b`` with known solution."""

    A: jax.Array  # (n, n) dense, unit diagonal, SPD
    b: jax.Array  # (n, k) right-hand sides (k >= 1; the paper uses k = 51)
    x_star: jax.Array  # (n, k) exact solution
    # Diagnostics used by the theory module / tests.
    lam_min: jax.Array
    lam_max: jax.Array

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def kappa(self) -> jax.Array:
        return self.lam_max / self.lam_min


def to_unit_diagonal(B: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return ``(A, d)`` with ``A = D B D`` unit-diagonal, ``D = diag(d)``."""
    d = 1.0 / jnp.sqrt(jnp.diagonal(B))
    A = B * d[:, None] * d[None, :]
    # Exact ones on the diagonal (kills rounding fuzz that breaks (d,d)_A = 1).
    A = A.at[jnp.arange(A.shape[0]), jnp.arange(A.shape[0])].set(1.0)
    return A, d


def _finish(A: np.ndarray, key: jax.Array, n_rhs: int) -> SPDProblem:
    A = jnp.asarray(A, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    A, _ = to_unit_diagonal(A)
    evals = jnp.linalg.eigvalsh(A)
    x_star = jax.random.normal(key, (A.shape[0], n_rhs), A.dtype)
    b = A @ x_star
    return SPDProblem(A=A, b=b, x_star=x_star, lam_min=evals[0], lam_max=evals[-1])


def random_sparse_spd(
    n: int,
    row_nnz: int = 8,
    *,
    offdiag: float = 0.9,
    n_rhs: int = 1,
    seed: int = 0,
) -> SPDProblem:
    """Reference-scenario matrix: ~``row_nnz`` nonzeros/row, unit diagonal.

    ``A = I + c * (S + S^T)/2`` with ``c`` chosen via Gershgorin so that
    ``lam_min >= 1 - offdiag > 0``.  ``offdiag -> 1`` raises the condition
    number.  This mirrors the social-media matrix of Sec. 8 structurally:
    unstructured sparsity, modest nnz/row, multiple right-hand sides.
    """
    rng = np.random.default_rng(seed)
    M = np.zeros((n, n))
    for i in range(n):
        cols = rng.choice(n, size=row_nnz, replace=False)
        M[i, cols] = rng.standard_normal(row_nnz)
    M = (M + M.T) / 2.0
    np.fill_diagonal(M, 0.0)
    s = np.abs(M).sum(axis=1).max()
    M *= offdiag / max(s, 1e-30)
    A = np.eye(n) + M
    return _finish(A, jax.random.key(seed + 1), n_rhs)


def laplacian_spd(side: int, *, shift: float = 1e-2, n_rhs: int = 1, seed: int = 0) -> SPDProblem:
    """2-D grid Laplacian + shift, unit-diagonal-normalized.

    Ill-conditioned as ``side`` grows (kappa ~ side^2 / shift): the stress
    case where lam_min shrinks with n, discussed in the paper's weak-scaling
    remarks.
    """
    n = side * side
    A = np.zeros((n, n))
    for i in range(side):
        for j in range(side):
            p = i * side + j
            A[p, p] = 4.0 + shift
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                q_i, q_j = i + di, j + dj
                if 0 <= q_i < side and 0 <= q_j < side:
                    A[p, q_i * side + q_j] = -1.0
    return _finish(A, jax.random.key(seed + 1), n_rhs)


def dense_spd(n: int, *, n_rhs: int = 1, seed: int = 0) -> SPDProblem:
    """Dense Wishart-plus-identity SPD (outside the reference scenario)."""
    rng = np.random.default_rng(seed)
    G = rng.standard_normal((n, n))
    A = G @ G.T / n + np.eye(n)
    return _finish(A, jax.random.key(seed + 1), n_rhs)


def block_banded_spd(
    n: int, *, block: int = 128, bands: int = 2, offdiag: float = 0.9, n_rhs: int = 1, seed: int = 0
) -> SPDProblem:
    """Block-banded SPD used by the blocked Pallas kernels.

    Nonzeros live in ``bands`` blocks of width ``block`` on each side of the
    diagonal; contiguous block structure is the TPU-friendly layout argued
    for in DESIGN.md (HBM->VMEM streams stay contiguous).
    """
    assert n % block == 0
    rng = np.random.default_rng(seed)
    nb = n // block
    M = np.zeros((n, n))
    for bi in range(nb):
        for bj in range(max(0, bi - bands), min(nb, bi + bands + 1)):
            if bi == bj:
                continue
            blk = rng.standard_normal((block, block)) / block
            M[bi * block:(bi + 1) * block, bj * block:(bj + 1) * block] = blk
    M = (M + M.T) / 2.0
    np.fill_diagonal(M, 0.0)
    s = np.abs(M).sum(axis=1).max()
    M *= offdiag / max(s, 1e-30)
    A = np.eye(n) + M
    return _finish(A, jax.random.key(seed + 1), n_rhs)


@functools.partial(jax.jit, static_argnames=("width",))
def ell_from_dense(A: jax.Array, width: int) -> tuple[jax.Array, jax.Array]:
    """Convert dense ``A`` to fixed-width ELL: values (n, width), cols (n, width).

    Keeps the ``width`` largest-magnitude entries per row (exact when each
    row has <= width nonzeros).  Padding uses col = row's own index with
    value 0 so gathers stay in-bounds.
    """
    n = A.shape[0]
    mag = jnp.abs(A)
    _, cols = jax.lax.top_k(mag, width)  # (n, width)
    vals = jnp.take_along_axis(A, cols, axis=1)
    keep = jnp.take_along_axis(mag, cols, axis=1) > 0
    vals = jnp.where(keep, vals, 0.0)
    cols = jnp.where(keep, cols, jnp.arange(n)[:, None])
    return vals, cols


def a_norm_sq(A: jax.Array, v: jax.Array) -> jax.Array:
    """``||v||_A^2`` per RHS column: v is (n,) or (n, k)."""
    if v.ndim == 1:
        return v @ (A @ v)
    return jnp.einsum("nk,nk->k", v, A @ v)
