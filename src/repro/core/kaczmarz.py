"""Randomized Kaczmarz for rectangular systems and least squares (Sec. 7).

The paper's second contribution is an algorithm for unsymmetric systems and
overdetermined least-squares problems: instead of forming the normal
equations A^T A x = A^T b (squaring the condition number), iterate directly
on the rows of A.  With row ``i`` sampled with probability
``||A_i||^2 / ||A||_F^2`` (Strohmer & Vershynin), the update

    gamma_j = (b_i - A_i x_j) / ||A_i||^2
    x_{j+1} = x_j + beta * gamma_j * A_i^T

contracts E ||x_j - x*||^2 by ``1 - beta(2-beta) sigma_min(A)^2/||A||_F^2``
per iteration on consistent systems.  For inconsistent (noisy) b the
iterates converge to a ball around the least-squares solution whose radius
scales with the residual at the optimum — the low-accuracy regime the
paper's regression workload actually needs.

Three solvers, mirroring the SPD family (rgs / async_rgs / parallel_rgs):

* ``rk_solve`` — sequential, multi-RHS, chunked error recording;
* ``async_rk_solve`` — the bounded-delay model of Secs. 4/6 transplanted to
  row-action updates (consistent and inconsistent reads, same ring-buffer
  mechanics as ``async_rgs_solve``);
* ``parallel_rk_solve`` — shard_map over row slabs.  The row schedule is a
  single *global* i.i.d. sequence (identical in law AND realization to the
  sequential solver); each pick is applied by the worker owning that row,
  reading its own in-round updates fresh and other workers' updates stale
  until the per-round synchronization (psum of accumulated deltas — the
  paper's periodic-synchronization scheme).  Staleness is therefore
  *scheduled*: tau = local_steps - 1 for P > 1, and P = 1 reproduces the
  sequential iterates bit-for-bit (every pick is owned, no update is ever
  stale).  Step sizes come from ``theory.beta_opt_rk``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.core.parallel_rgs import ParallelSolveResult
from repro.core.rgs import SolveResult


class LSQProblem(NamedTuple):
    """An overdetermined system ``A x ~= b`` with known LSQ solution."""

    A: jax.Array        # (m, n) rectangular, m >= n
    b: jax.Array        # (m, k) right-hand sides
    x_star: jax.Array   # (n, k) least-squares solution argmin ||A x - b||
    x_true: jax.Array   # (n, k) planted coefficients (== x_star iff noise=0)
    sigma_min: jax.Array
    sigma_max: jax.Array

    @property
    def shape(self) -> tuple[int, int]:
        return self.A.shape

    @property
    def kappa(self) -> jax.Array:
        return self.sigma_max / self.sigma_min


def random_lsq(
    m: int,
    n: int,
    *,
    n_rhs: int = 1,
    noise: float = 0.0,
    col_scale: float = 1.0,
    seed: int = 0,
) -> LSQProblem:
    """Gaussian design with exponentially skewed column scales (the shape of
    the paper's regression features), planted coefficients, optional noise.

    ``noise = 0`` gives a *consistent* system (RK converges to x* exactly);
    ``noise > 0`` gives a genuine least-squares problem where RK reaches the
    low-accuracy neighborhood of x* (its convergence horizon).
    """
    assert m >= n
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(np.float32)
    if col_scale:
        A *= rng.exponential(col_scale, n).astype(np.float32)
    x_true = rng.standard_normal((n, n_rhs)).astype(np.float32)
    b = A @ x_true
    if noise:
        b = b + noise * rng.standard_normal((m, n_rhs)).astype(np.float32)
    A_j = jnp.asarray(A)
    b_j = jnp.asarray(b)
    x_star = jnp.linalg.lstsq(A_j, b_j)[0] if noise else jnp.asarray(x_true)
    s = jnp.linalg.svd(A_j, compute_uv=False)
    return LSQProblem(A=A_j, b=b_j, x_star=x_star, x_true=jnp.asarray(x_true),
                      sigma_min=s[-1], sigma_max=s[0])


def row_norms_sq(A: jax.Array) -> jax.Array:
    """||A_i||^2 per row — the (unnormalized) sampling distribution."""
    return jnp.einsum("mn,mn->m", A, A)


def sample_rows(key: jax.Array, A: jax.Array, num: int) -> jax.Array:
    """``num`` i.i.d. row indices with P(i) ∝ ||A_i||^2 (zero rows never)."""
    return jax.random.categorical(key, jnp.log(row_norms_sq(A)), shape=(num,))


def _record_lsq(A, b, x, x_star):
    e = x - x_star
    return jnp.einsum("nk,nk->k", e, e), jnp.linalg.norm(b - A @ x, axis=0)


@functools.partial(jax.jit, static_argnames=("num_iters", "record_every"))
def rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    """Sequential randomized Kaczmarz on ``A x ~= b`` (A rectangular).

    b is (m, k): the same random row serves all k right-hand sides, exactly
    as the SPD solvers share directions across the paper's 51 RHS.
    ``err_sq`` records ||x - x*||_2^2 (Euclidean — there is no A-norm for
    rectangular A); ``resid`` records ||b - A x||_2 per RHS.
    """
    rn = row_norms_sq(A)
    rec = record_every or num_iters
    assert num_iters % rec == 0
    rows = sample_rows(key, A, num_iters)

    def step(x, r):
        g = (b[r] - A[r] @ x) / rn[r]               # (k,)
        return x + beta * A[r][:, None] * g[None, :], None

    def chunk(x, rs):
        x, _ = jax.lax.scan(step, x, rs)
        return x, _record_lsq(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(chunk, x0, rows.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "tau", "record_every", "read_model", "delay_mode"),
)
def async_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    """Asynchronous RK under the paper's bounded-delay model.

    Same simulator mechanics as ``async_rgs_solve``: a ring buffer of the
    last ``tau`` applied updates (row index r_t, applied coefficient
    c_t = beta*gamma_t), and the stale read reconstructed exactly via

        A_r x_{k(j)} = A_r x_j - sum_{t invisible} c_t <A_r, A_{r_t}>

    (the update directions are rows A_{r_t}^T instead of coordinate vectors,
    so the correction weights are row inner products).  Delay schedules are
    drawn from ``delay_key``, independent of the row key (Assumption A-4).

    delay_mode (consistent reads): "fixed" (s_j = tau), "uniform"
    (s_j ~ U{0..tau}), "cyclic" (s_j = j mod (tau+1)).  read_model
    "inconsistent": each of the last tau updates is invisible independently
    with probability ``miss_prob``.
    """
    k = b.shape[1]
    rn = row_norms_sq(A)
    rec = record_every or num_iters
    assert num_iters % rec == 0
    rows = sample_rows(key, A, num_iters)
    t_buf = max(tau, 1)

    if read_model == "consistent":
        if delay_mode == "fixed":
            delays = jnp.full((num_iters,), tau, jnp.int32)
        elif delay_mode == "uniform":
            delays = jax.random.randint(delay_key, (num_iters,), 0, tau + 1)
        elif delay_mode == "cyclic":
            delays = (jnp.arange(num_iters) % (tau + 1)).astype(jnp.int32)
        else:
            raise ValueError(delay_mode)
        aux = delays
    elif read_model == "inconsistent":
        aux = jax.random.bernoulli(delay_key, miss_prob, (num_iters, t_buf))
    else:
        raise ValueError(read_model)

    ring_r0 = jnp.zeros((t_buf,), jnp.int32)
    ring_c0 = jnp.zeros((t_buf, k), x0.dtype)
    offsets = jnp.arange(t_buf)

    def step(carry, inp):
        x, ring_r, ring_c, j = carry
        r, a = inp
        it_idx = j - 1 - offsets                      # iteration indices, newest first
        valid = it_idx >= 0
        if read_model == "consistent":
            invisible = (offsets < a) & valid          # suffix of length s_j
        else:
            invisible = a & valid & (offsets < tau)    # arbitrary subset of last tau
        slots = jnp.mod(it_idx, t_buf)
        rs = ring_r[slots]                             # (t_buf,)
        cs = ring_c[slots]                             # (t_buf, k) applied coefficients
        # Correction restores the stale read: see docstring identity.
        w = jnp.where(invisible, A[rs] @ A[r], 0.0)    # (t_buf,)
        corr = w @ cs                                  # (k,)
        gamma = (b[r] - A[r] @ x + corr) / rn[r]
        c = beta * gamma
        x = x + A[r][:, None] * c[None, :]
        ring_r = ring_r.at[jnp.mod(j, t_buf)].set(r)
        ring_c = ring_c.at[jnp.mod(j, t_buf)].set(c)
        return (x, ring_r, ring_c, j + 1), None

    def chunk(carry, inp):
        carry, _ = jax.lax.scan(step, carry, inp)
        errs = _record_lsq(A, b, carry[0], x_star)
        return carry, errs

    inps = (rows.reshape(-1, rec), aux.reshape((-1, rec) + aux.shape[1:]))
    carry = (x0, ring_r0, ring_c0, jnp.array(0, jnp.int32))
    carry, (errs, resids) = jax.lax.scan(chunk, carry, inps)
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=carry[0], err_sq=errs, resid=resids, iters=iters)


def rk_effective_tau(num_workers: int, local_steps: int) -> int:
    """Scheduled staleness bound of ``parallel_rk_solve``: within a round a
    pick misses at most the other workers' earlier in-round updates."""
    return 0 if num_workers == 1 else local_steps - 1


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "beta", "unroll"),
)
def parallel_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    beta: float = 1.0,
    unroll: bool = False,
) -> ParallelSolveResult:
    """Distributed asynchronous RK: rows sharded into P slabs (owner-computes).

    The schedule is one global i.i.d. row sequence of length
    ``rounds * local_steps`` — the same stochastic process the sequential
    solver and the paper's analysis use, partitioned by row owner.  Within a
    round every worker applies its own picks with fresh reads (its full
    working replica ``xw`` carries them) while other workers' in-round
    updates stay invisible until the end-of-round psum of accumulated
    deltas — the periodic-synchronization scheme of Thm 4.1(a), with
    scheduled staleness ``rk_effective_tau(P, local_steps)``.

    With P = 1 every pick is owned and ``psum(delta) - delta == 0`` exactly,
    so the iterates are bit-identical to ``rk_solve`` with the same key and
    ``num_iters = rounds * local_steps`` (the consistency test relies on
    this).  ``err_sq``/``resid`` are recorded once per round.
    """
    num_workers = mesh.shape[axis]
    m = A.shape[0]
    slab = m // num_workers
    assert slab * num_workers == m, (
        f"worker count ({num_workers}) must divide the row count ({m})")
    rn = row_norms_sq(A)
    picks = sample_rows(key, A, rounds * local_steps).reshape(rounds, local_steps)

    def worker(A_sh, b_sh, rn_sh, x0_full, xs_full, picks):
        # A_sh: (slab, n); b_sh: (slab, k); rn_sh: (slab,); x0/xs replicated.
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def round_body(xw, picks_r):
            delta = pvary(jnp.zeros_like(xw), (axis,))

            def step(carry, p):
                xw, delta = carry
                li = p - row0
                mine = (li >= 0) & (li < slab)
                lic = jnp.clip(li, 0, slab - 1)
                Ar = A_sh[lic]                               # (n,)
                g = (b_sh[lic] - Ar @ xw) / rn_sh[lic]       # (k,)
                # Arithmetic mirrors rk_solve's step exactly (bit-identity
                # at P=1): scalar coefficient times row outer product.
                upd = jnp.where(mine, beta, 0.0) * Ar[:, None] * g[None, :]
                return (xw + upd, delta + upd), None

            (xw, delta), _ = jax.lax.scan(
                step, (xw, delta), picks_r,
                unroll=local_steps if unroll else 1)
            if num_workers > 1:
                # Periodic synchronization: pull in the other workers'
                # updates.  Skipped entirely at P=1 — it would be a bitwise
                # no-op in exact arithmetic, but XLA folds the single-device
                # psum away and reassociates xw + (delta - delta), costing
                # an ulp per round and breaking the exact-degeneracy
                # guarantee the consistency tests rely on.
                xw = xw + (jax.lax.psum(delta, axis) - delta)
            # xw is a full replica, so the error is local; residual rows are
            # sharded, so the squared norm needs a psum.
            err = jnp.einsum("nk,nk->k", xw - xs_full, xw - xs_full)
            r_local = b_sh - A_sh @ xw
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return xw, (err, jnp.sqrt(rsq))

        xw, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), picks,
            unroll=rounds if unroll else 1)
        return xw, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(None, None),
                  P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A, b, rn, x0, x_star, picks)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=rk_effective_tau(num_workers, local_steps))
