"""Randomized Kaczmarz for rectangular systems and least squares (Sec. 7).

The paper's second contribution is an algorithm for unsymmetric systems and
overdetermined least-squares problems: instead of forming the normal
equations A^T A x = A^T b (squaring the condition number), iterate directly
on the rows of A.  With row ``i`` sampled with probability
``||A_i||^2 / ||A||_F^2`` (Strohmer & Vershynin), the update

    gamma_j = (b_i - A_i x_j) / ||A_i||^2
    x_{j+1} = x_j + beta * gamma_j * A_i^T

contracts E ||x_j - x*||^2 by ``1 - beta(2-beta) sigma_min(A)^2/||A||_F^2``
per iteration on consistent systems.  For inconsistent (noisy) b the
iterates converge to a ball around the least-squares solution whose radius
scales with the residual at the optimum — the low-accuracy regime the
paper's regression workload actually needs.

All three solvers are thin wrappers over the unified engine — the "rk"
(row) action of ``repro.core.engine`` — and produce bit-identical iterates
to their pre-refactor implementations (pinned by
tests/test_engine_equivalence.py):

* ``rk_solve`` — sequential, multi-RHS, chunked error recording;
* ``async_rk_solve`` — the bounded-delay model of Secs. 4/6 transplanted to
  row-action updates (the engine's ring-buffer simulator with row-inner-
  product correction weights);
* ``parallel_rk_solve`` — shard_map over row slabs.  The row schedule is a
  single *global* i.i.d. sequence (identical in law AND realization to the
  sequential solver); staleness is *scheduled*: tau = local_steps - 1 for
  P > 1, and P = 1 reproduces the sequential iterates bit-for-bit.
  Step sizes come from ``theory.beta_opt_rk``.

The block-banded Kaczmarz variant (Kaczmarz action × ``BlockBandedOp``)
lives entirely in the engine: ``engine.solve_distributed(BlockBandedOp(...),
action="rk", ...)`` — see benchmarks/bench_lsq.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.engine import (
    ParallelSolveResult,
    SolveResult,
    scheduled_tau,
    solve_async_sim,
    solve_distributed,
    solve_sequential,
)
from repro.core.operators import DenseOp

__all__ = [
    "LSQProblem",
    "async_rk_solve",
    "parallel_rk_solve",
    "random_lsq",
    "random_sparse_lsq",
    "rk_effective_tau",
    "rk_solve",
    "row_norms_sq",
    "sample_rows",
]


class LSQProblem(NamedTuple):
    """An overdetermined system ``A x ~= b`` with known LSQ solution."""

    A: jax.Array        # (m, n) rectangular, m >= n
    b: jax.Array        # (m, k) right-hand sides
    x_star: jax.Array   # (n, k) least-squares solution argmin ||A x - b||
    x_true: jax.Array   # (n, k) planted coefficients (== x_star iff noise=0)
    sigma_min: jax.Array
    sigma_max: jax.Array

    @property
    def shape(self) -> tuple[int, int]:
        return self.A.shape

    @property
    def kappa(self) -> jax.Array:
        return self.sigma_max / self.sigma_min


def random_lsq(
    m: int,
    n: int,
    *,
    n_rhs: int = 1,
    noise: float = 0.0,
    col_scale: float = 1.0,
    seed: int = 0,
) -> LSQProblem:
    """Gaussian design with exponentially skewed column scales (the shape of
    the paper's regression features), planted coefficients, optional noise.

    ``noise = 0`` gives a *consistent* system (RK converges to x* exactly);
    ``noise > 0`` gives a genuine least-squares problem where RK reaches the
    low-accuracy neighborhood of x* (its convergence horizon).
    """
    assert m >= n
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(np.float32)
    if col_scale:
        A *= rng.exponential(col_scale, n).astype(np.float32)
    x_true = rng.standard_normal((n, n_rhs)).astype(np.float32)
    b = A @ x_true
    if noise:
        b = b + noise * rng.standard_normal((m, n_rhs)).astype(np.float32)
    A_j = jnp.asarray(A)
    b_j = jnp.asarray(b)
    x_star = jnp.linalg.lstsq(A_j, b_j)[0] if noise else jnp.asarray(x_true)
    s = jnp.linalg.svd(A_j, compute_uv=False)
    return LSQProblem(A=A_j, b=b_j, x_star=x_star, x_true=jnp.asarray(x_true),
                      sigma_min=s[-1], sigma_max=s[0])


def random_sparse_lsq(
    m: int,
    n: int,
    *,
    row_nnz: int = 8,
    n_rhs: int = 1,
    noise: float = 0.0,
    seed: int = 0,
) -> LSQProblem:
    """Sparse overdetermined design: ``row_nnz`` nonzeros per row, planted
    coefficients, optional noise — the rectangular face of the paper's
    reference scenario (unstructured sparsity, few nnz/row).  This is the
    regime where concurrent row projections rarely collide, so the
    asynchronous Kaczmarz variants keep near-sequential rates (Thm 4.1's
    "P small relative to size and sparsity").
    """
    assert m >= n
    rng = np.random.default_rng(seed)
    A = np.zeros((m, n), np.float32)
    for i in range(m):
        cols = rng.choice(n, size=row_nnz, replace=False)
        A[i, cols] = rng.standard_normal(row_nnz).astype(np.float32)
    x_true = rng.standard_normal((n, n_rhs)).astype(np.float32)
    b = A @ x_true
    if noise:
        b = b + noise * rng.standard_normal((m, n_rhs)).astype(np.float32)
    A_j = jnp.asarray(A)
    b_j = jnp.asarray(b)
    x_star = jnp.linalg.lstsq(A_j, b_j)[0] if noise else jnp.asarray(x_true)
    s = jnp.linalg.svd(A_j, compute_uv=False)
    return LSQProblem(A=A_j, b=b_j, x_star=x_star, x_true=jnp.asarray(x_true),
                      sigma_min=s[-1], sigma_max=s[0])


def row_norms_sq(A: jax.Array) -> jax.Array:
    """||A_i||^2 per row — the (unnormalized) sampling distribution."""
    return jnp.einsum("mn,mn->m", A, A)


def sample_rows(key: jax.Array, A: jax.Array, num: int) -> jax.Array:
    """``num`` i.i.d. row indices with P(i) ∝ ||A_i||^2 (zero rows never)."""
    return engine.sample_rows(key, row_norms_sq(A), num)


def rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    """Sequential randomized Kaczmarz on ``A x ~= b`` (A rectangular).

    b is (m, k): the same random row serves all k right-hand sides, exactly
    as the SPD solvers share directions across the paper's 51 RHS.
    ``err_sq`` records ||x - x*||_2^2 (Euclidean — there is no A-norm for
    rectangular A); ``resid`` records ||b - A x||_2 per RHS.
    """
    return solve_sequential(
        DenseOp(A), b, x0, x_star, action="rk", key=key, num_iters=num_iters,
        beta=beta, record_every=record_every)


def async_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    """Asynchronous RK under the paper's bounded-delay model.

    The engine's ring-buffer simulator with the row action: the stale read
    is reconstructed exactly via

        A_r x_{k(j)} = A_r x_j - sum_{t invisible} c_t <A_r, A_{r_t}>

    (update directions are rows A_{r_t}^T instead of coordinate vectors, so
    the correction weights are row inner products).  Delay schedules are
    drawn from ``delay_key``, independent of the row key (Assumption A-4).

    delay_mode (consistent reads): "fixed" (s_j = tau), "uniform"
    (s_j ~ U{0..tau}), "cyclic" (s_j = j mod (tau+1)).  read_model
    "inconsistent": each of the last tau updates is invisible independently
    with probability ``miss_prob``.
    """
    return solve_async_sim(
        DenseOp(A), b, x0, x_star, action="rk", key=key, delay_key=delay_key,
        num_iters=num_iters, tau=tau, beta=beta, read_model=read_model,
        delay_mode=delay_mode, miss_prob=miss_prob, record_every=record_every)


def rk_effective_tau(num_workers: int, local_steps: int) -> int:
    """Scheduled staleness bound of ``parallel_rk_solve`` (compat re-export
    of ``engine.scheduled_tau(shared_stream=True)``): within a round a pick
    misses at most the other workers' earlier in-round updates."""
    return scheduled_tau(num_workers, local_steps, shared_stream=True)


def parallel_rk_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    beta: float = 1.0,
    unroll: bool = False,
) -> ParallelSolveResult:
    """Distributed asynchronous RK: rows sharded into P slabs (owner-computes).

    The schedule is one global i.i.d. row sequence of length
    ``rounds * local_steps`` — the same stochastic process the sequential
    solver and the paper's analysis use, partitioned by row owner.  Within a
    round every worker applies its own picks with fresh reads while other
    workers' in-round updates stay invisible until the end-of-round psum of
    accumulated deltas — the periodic-synchronization scheme of Thm 4.1(a),
    with scheduled staleness ``rk_effective_tau(P, local_steps)``.

    With P = 1 every pick is owned and the sync is skipped entirely, so the
    iterates are bit-identical to ``rk_solve`` with the same key and
    ``num_iters = rounds * local_steps`` (the consistency test relies on
    this).  ``err_sq``/``resid`` are recorded once per round.
    """
    return solve_distributed(
        DenseOp(A), b, x0, x_star, action="rk", key=key, mesh=mesh, axis=axis,
        rounds=rounds, local_steps=local_steps, beta=beta, sync="psum",
        unroll=unroll)
