"""Convergence-rate theory of the paper, as executable formulas.

Everything the theorems need: rho, rho_2, eigenvalue extremes (exact for
small n, Lanczos for large), the rate factors nu_tau(beta) / omega_tau(beta)
/ chi, the optimal step size beta~ = 1/(1+2 rho tau), and bound curves that
the tests check measured error against.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp


def rho(A: jax.Array) -> jax.Array:
    """rho = max_l (1/n) sum_r |A_lr|   (Thm 4.1)."""
    n = A.shape[0]
    return jnp.max(jnp.sum(jnp.abs(A), axis=1)) / n


def rho2(A: jax.Array) -> jax.Array:
    """rho_2 = max_l (1/n) sum_r A_lr^2   (Thm 6.1)."""
    n = A.shape[0]
    return jnp.max(jnp.sum(A * A, axis=1)) / n


def block_rho(A: jax.Array, block: int) -> jax.Array:
    """Block generalization of rho for the TPU-adapted block iteration:
    rho_B = max_L (1/n_B) sum_R ||A_{L,R}||_1-ish, computed as the max over
    block-rows of the mean absolute block-coupling.  Reduces to rho when
    block == 1."""
    n = A.shape[0]
    nb = n // block
    Ab = jnp.abs(A).reshape(nb, block, nb, block).sum(axis=(1, 3)) / block
    return jnp.max(jnp.sum(Ab, axis=1)) / nb


def extreme_eigs_dense(A: jax.Array) -> tuple[jax.Array, jax.Array]:
    ev = jnp.linalg.eigvalsh(A)
    return ev[0], ev[-1]


@functools.partial(jax.jit, static_argnames=("iters",))
def lanczos_extreme_eigs(A: jax.Array, key: jax.Array, iters: int = 64):
    """Lanczos estimate of (lam_min, lam_max) for large A (no full eigh).

    Full reorthogonalization (iters is small); returns Ritz extremes.
    """
    n = A.shape[0]
    v0 = jax.random.normal(key, (n,), A.dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    V = jnp.zeros((iters + 1, n), A.dtype).at[0].set(v0)
    alphas = jnp.zeros((iters,), A.dtype)
    betas = jnp.zeros((iters,), A.dtype)

    def body(i, carry):
        V, alphas, betas = carry
        v = V[i]
        w = A @ v
        alpha = v @ w
        w = w - alpha * v - jnp.where(i > 0, betas[i - 1], 0.0) * V[i - 1]
        # full reorthogonalization
        w = w - (V[: iters + 1].T @ (V[: iters + 1] @ w))
        beta = jnp.linalg.norm(w)
        V = V.at[i + 1].set(jnp.where(beta > 1e-12, w / beta, 0.0))
        return V, alphas.at[i].set(alpha), betas.at[i].set(beta)

    V, alphas, betas = jax.lax.fori_loop(0, iters, body, (V, alphas, betas))
    T = jnp.diag(alphas) + jnp.diag(betas[:-1], 1) + jnp.diag(betas[:-1], -1)
    ev = jnp.linalg.eigvalsh(T)
    return ev[0], ev[-1]


# ---------------------------------------------------------------------------
# Rate factors
# ---------------------------------------------------------------------------

def nu_tau(rho_val: float, tau: int, beta: float = 1.0) -> float:
    """Sec. 5: nu_tau(beta) = 2 beta - beta^2 - 2 rho tau beta^2.
    beta = 1 recovers Thm 4.1's nu_tau = 1 - 2 rho tau."""
    return 2 * beta - beta**2 - 2 * rho_val * tau * beta**2


def beta_opt(rho_val: float, tau: int) -> float:
    """Optimal step size beta~ = 1/(1 + 2 rho tau); nu_tau(beta~) = beta~."""
    return 1.0 / (1.0 + 2.0 * rho_val * tau)


def omega_tau(rho2_val: float, tau: int, beta: float) -> float:
    """Thm 6.1: omega_tau(beta) = beta (1 - beta - rho_2 tau^2 beta / 2)."""
    return beta * (1.0 - beta - rho2_val * tau**2 * beta / 2.0)


def beta_opt_inconsistent(rho2_val: float, tau: int) -> float:
    """argmax_beta omega_tau(beta) = 1 / (2 + rho_2 tau^2)."""
    return 1.0 / (2.0 + rho2_val * tau**2)


def _check_lam_max(lam_max: float, n: int, where: str) -> None:
    """Both epoch formulas need 0 < lam_max/n < 1 (they take logs/negative
    powers of 1 - lam_max/n).  lam_max == n is REACHABLE for a valid
    unit-diagonal SPD matrix (e.g. the all-ones rank-one-plus-identity
    family pushes lam_max -> n), where the expressions silently degenerate
    — a math domain error from ``log`` or a garbage ``0 ** -2tau`` —
    so reject with the actual constraint instead."""
    if not 0.0 < lam_max < n:
        raise ValueError(
            f"{where} needs 0 < lam_max < n (got lam_max={lam_max}, "
            f"n={n}): the epoch length ~ log(1/2)/log(1 - lam_max/n) is "
            "undefined at the boundary — lam_max = n means a single "
            "coordinate step can solve the dominant mode, so no epoch "
            "analysis applies")


def chi_consistent(rho_val: float, tau: int, lam_max: float, n: int, beta: float = 1.0) -> float:
    _check_lam_max(lam_max, n, "chi_consistent")
    dmax = 1.0 - lam_max / n
    return rho_val * tau**2 * beta**2 * lam_max * dmax ** (-2 * tau) / n


def epoch_len(lam_max: float, n: int) -> int:
    """T0 = ceil(log(1/2) / log(1 - lam_max/n)) ~= 0.693 n / lam_max."""
    _check_lam_max(lam_max, n, "epoch_len")
    return int(math.ceil(math.log(0.5) / math.log(1.0 - lam_max / n)))


# ---------------------------------------------------------------------------
# Bound curves (what the tests check against)
# ---------------------------------------------------------------------------

def ll_bound(e0, m, lam_min: float, n: int):
    """Leventhal-Lewis synchronous bound (2): E_m <= (1 - lam_min/n)^m E_0."""
    return (1.0 - lam_min / n) ** m * e0


def thm41a_factor(rho_val, tau, kappa, beta=1.0):
    """Thm 4.1(a)/Sec.5(a): E_m <= (1 - nu_tau(beta)/(2 kappa)) E_0 for
    m >= ~0.693 n / lam_max, assuming nu_tau > 0."""
    return 1.0 - nu_tau(rho_val, tau, beta) / (2.0 * kappa)


def thm61a_factor(rho2_val, tau, kappa, beta):
    """Thm 6.1(a): E_m <= (1 - omega_tau(beta)/kappa) E_0."""
    return 1.0 - omega_tau(rho2_val, tau, beta) / kappa


def iters_to_eps(n: int, lam_min: float, eps: float, delta: float) -> int:
    """Sec. 2.2 Markov bound: m >= (n/lam_min) ln(1/(delta eps^2))."""
    return int(math.ceil(n / lam_min * math.log(1.0 / (delta * eps**2))))


# ---------------------------------------------------------------------------
# Randomized Kaczmarz (Sec. 7: unsymmetric / overdetermined least squares)
# ---------------------------------------------------------------------------
#
# RK with row sampling P(i) = ||A_i||^2 / ||A||_F^2 is RGS run implicitly on
# the normal equations without ever forming them; the analogues of the
# paper's quantities are built from *normalized-row coherences*
# <A_l/||A_l||, A_r/||A_r||> in place of the unit-diagonal couplings A_lr,
# and the sampling distribution p_r in place of the uniform 1/n.

def rk_row_probs(A: jax.Array) -> jax.Array:
    """Row sampling distribution p_i = ||A_i||^2 / ||A||_F^2."""
    rn = jnp.einsum("mn,mn->m", A, A)
    return rn / jnp.sum(rn)


def rk_rho(A: jax.Array) -> jax.Array:
    """RK analogue of rho (Thm 4.1): max_l E_r |<A_l/||A_l||, A_r/||A_r||>|
    under row sampling — the expected |coherence| a stale update can inject
    into a row's residual, maximized over rows.  Reduces to the paper's
    rho = max_l (1/n) sum_r |A_lr| when A is square, unit-diagonal SPD and
    sampling is uniform.  O(m^2 n): diagnostic / step-size use only.
    """
    norms = jnp.sqrt(jnp.einsum("mn,mn->m", A, A))
    Ahat = A / norms[:, None]
    return jnp.max(jnp.abs(Ahat @ Ahat.T) @ rk_row_probs(A))


def rk_rho2(A: jax.Array) -> jax.Array:
    """RK analogue of rho_2 (Thm 6.1): max_l E_r <A_l/||A_l||, A_r/||A_r||>^2
    under row sampling (squared coherences, for the inconsistent-read rate).
    """
    norms = jnp.sqrt(jnp.einsum("mn,mn->m", A, A))
    Ahat = A / norms[:, None]
    G = Ahat @ Ahat.T
    return jnp.max((G * G) @ rk_row_probs(A))


def rk_factor(A: jax.Array, beta: float = 1.0) -> jax.Array:
    """Strohmer-Vershynin per-iteration contraction of E||x - x*||^2 for
    (beta-damped) RK on a consistent system:
    1 - beta(2-beta) sigma_min(A)^2 / ||A||_F^2."""
    s = jnp.linalg.svd(A, compute_uv=False)
    return 1.0 - beta * (2.0 - beta) * (s[-1] ** 2) / jnp.sum(s**2)


def rk_bound(e0, m, factor):
    """Expected-error bound curve: E||x_m - x*||^2 <= factor^m * E_0."""
    return factor**m * e0


def beta_opt_rk(rho_rk: float, tau: int) -> float:
    """Thm-analogous step size for asynchronous RK: beta~ = 1/(1+2 rho_rk tau)
    — the paper's beta~ = 1/(1+2 rho tau) with the coherence constant of
    ``rk_rho`` standing in for rho (AsyRK, Liu-Wright-Sridhar style)."""
    return 1.0 / (1.0 + 2.0 * rho_rk * tau)


def async_rk_factor(A: jax.Array, tau: int, beta: float,
                    rho_rk: float | None = None) -> jax.Array:
    """Per-iteration factor for delay-tau RK: 1 - nu_tau(rho_rk) sigma_min^2
    / ||A||_F^2 — Thm 4.1(a)'s shape with the RK contraction modulus.

    Pass ``rho_rk`` when already computed: ``rk_rho`` costs O(m^2 n)."""
    s = jnp.linalg.svd(A, compute_uv=False)
    if rho_rk is None:
        rho_rk = float(rk_rho(A))
    nu = nu_tau(rho_rk, tau, beta)
    return 1.0 - nu * (s[-1] ** 2) / jnp.sum(s**2)


# ---------------------------------------------------------------------------
# Perturbed rates — what bounded inexactness (quantized payloads, low-
# precision storage) does to a linear contraction
# ---------------------------------------------------------------------------
#
# The iteration tolerates bounded perturbation at a quantified rate cost
# (the inexactness/staleness tolerance made explicit in Liu–Wright's
# asynchronous parallel RK and Chow–Frommer–Szyld's asynchronous Richardson
# practice): if the exact iteration contracts the error norm by sqrt(factor)
# per step and each step additionally injects a relative perturbation eps
# (e.g. the codec's measured ``quantization_error_bound`` over the payload
# norm), the perturbed iteration still contracts at
# (sqrt(factor) + eps)^2 per step — worst case, perturbation aligned with
# the error.  Once sqrt(factor) + eps >= 1 the contraction argument gives
# nothing (the iterate stalls at an eps-ball floor instead of diverging,
# but the bound degenerates), hence the min with 1.

def perturbed_factor(factor: float, eps: float) -> float:
    """Per-iteration contraction of the eps-perturbed iteration:
    min(1, (sqrt(max(factor, 0)) + eps)^2)."""
    if eps < 0:
        raise ValueError(f"perturbation bound must be >= 0, got {eps}")
    root = math.sqrt(max(float(factor), 0.0)) + float(eps)
    return min(1.0, root * root)


def iteration_inflation(factor: float, eps: float) -> float:
    """Predicted iterations-to-tolerance ratio (perturbed / exact):
    log(factor) / log(perturbed_factor(factor, eps)).

    Both factors must be contractions (< 1); a degenerate perturbed factor
    (>= 1, i.e. eps at least cancels the contraction) returns ``inf`` —
    the bound predicts no convergence to arbitrary tolerance, only an
    eps-ball floor."""
    f = float(factor)
    if not 0.0 < f < 1.0:
        raise ValueError(f"exact factor must be in (0, 1), got {factor}")
    pf = perturbed_factor(f, eps)
    if pf >= 1.0:
        return math.inf
    return math.log(f) / math.log(pf)
