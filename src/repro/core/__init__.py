"""repro.core — the paper's contribution: randomized (asynchronous) linear
solvers for SPD systems with provable rates, plus the supporting theory.

Layering (DESIGN.md): ``operators`` (matrix formats) → ``engine`` (the one
action×format×schedule solver) → legacy entry points (thin wrappers kept
bit-compatible) → ``theory`` (rate formulas the schedules consume).
"""

from repro.core.spd import (
    SPDProblem,
    a_norm_sq,
    block_banded_spd,
    dense_spd,
    ell_from_dense,
    laplacian_spd,
    random_sparse_spd,
    to_unit_diagonal,
)
from repro.core.operators import (BlockBandedOp, CsrOp, DenseOp, EllOp,
                                  as_operator)
from repro.core import engine
from repro.core import partition
from repro.core.engine import Schedule, scheduled_tau, solve
from repro.core.partition import RowPermutation, balanced_row_permutation
from repro.core.rgs import SolveResult, block_gs_solve, rgs_general, rgs_solve
from repro.core.async_rgs import async_rgs_solve, iteration_identity_gap
from repro.core.parallel_rgs import (
    ParallelSolveResult,
    effective_tau,
    parallel_rgs_banded,
    parallel_rgs_halo,
    parallel_rgs_solve,
)
from repro.core.cg import cg_solve, fcg_solve, make_rgs_preconditioner
from repro.core.kaczmarz import (
    LSQProblem,
    async_rk_solve,
    parallel_rk_solve,
    random_lsq,
    random_sparse_lsq,
    rk_effective_tau,
    rk_solve,
)
from repro.core import theory

__all__ = [
    "BlockBandedOp",
    "CsrOp",
    "DenseOp",
    "EllOp",
    "LSQProblem",
    "ParallelSolveResult",
    "RowPermutation",
    "SPDProblem",
    "Schedule",
    "SolveResult",
    "a_norm_sq",
    "as_operator",
    "balanced_row_permutation",
    "async_rgs_solve",
    "async_rk_solve",
    "block_banded_spd",
    "block_gs_solve",
    "cg_solve",
    "dense_spd",
    "effective_tau",
    "ell_from_dense",
    "engine",
    "fcg_solve",
    "iteration_identity_gap",
    "laplacian_spd",
    "make_rgs_preconditioner",
    "parallel_rgs_banded",
    "parallel_rgs_halo",
    "parallel_rgs_solve",
    "parallel_rk_solve",
    "partition",
    "random_lsq",
    "random_sparse_lsq",
    "random_sparse_spd",
    "rgs_general",
    "rgs_solve",
    "rk_effective_tau",
    "rk_solve",
    "scheduled_tau",
    "solve",
    "theory",
    "to_unit_diagonal",
]
