"""Graph/norm-balanced slab assignment for the distributed engine.

The periodic-synchronization schedule partitions rows into P *contiguous*
slabs.  That is the wrong partition for two of the paper's assumptions:

* **Sampling** — sparse RK samples per worker ∝ its slab's row norms
  (DESIGN.md §4); the interleaved stream only matches the global
  Strohmer–Vershynin law `P(i) ∝ ||A_i||²` when every slab carries the same
  norm mass.  Contiguous slabs of real matrices concentrate mass (scaled
  sensors, degree-skewed graphs), biasing the stationary row law.
* **Work balance** — Thm 4.1's rate is per *round*; a round lasts as long
  as its slowest worker, so nnz-skewed slabs stretch wall-clock tau.

This module computes a **non-contiguous** assignment and realizes it as a
row *permutation*: rows are bin-packed by ``row_norms_sq`` (primary) and
nonzero count (tie-break) into P equal-size bins, and the permutation
placing bin w at positions ``[w*m/P, (w+1)*m/P)`` is applied to the
operator *once, up front* — downstream, every slab is contiguous again and
all existing panel/sync machinery works unchanged.  For the row action
("rk", rectangular) the permutation touches rows only; for the coordinate
action ("gs", square SPD) it must be *symmetric* (``P A Pᵀ``) because the
row slab is also the coordinate slab — SPD-ness and the unit diagonal are
preserved, and the engine un-permutes the returned iterate.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import CsrOp, EllOp

__all__ = [
    "RowPermutation",
    "apply_partition",
    "balanced_labels",
    "balanced_row_permutation",
    "cross_slab_edges",
    "norm_balanced_assignment",
    "partition_permutation",
    "permute_rows",
    "slab_norm_mass",
]


class RowPermutation(NamedTuple):
    """A slab assignment realized as a row permutation (a pytree of arrays,
    so it travels through jit untouched).

    ``perm[i]`` is the original row placed at permuted position ``i`` —
    slab ``w`` owns permuted positions ``[w*m/P, (w+1)*m/P)``; ``inv`` is
    the inverse (``inv[perm[i]] == i``), used to un-permute iterates and to
    relabel columns under a symmetric permutation.
    """
    perm: jax.Array   # (m,) int32
    inv: jax.Array    # (m,) int32


def norm_balanced_assignment(row_norms_sq, row_nnz,
                             num_slabs: int) -> np.ndarray:
    """Greedy LPT bin-packing of rows into ``num_slabs`` equal-size bins.

    Rows are processed in decreasing ``row_norms_sq`` order; each goes to
    the non-full bin with the least accumulated norm mass, tie-broken by
    least accumulated nonzero count (so equal-mass choices still balance
    per-round work), then lowest bin index (determinism).  Equal bin
    *sizes* (m/P rows each) are a hard constraint — the engine shards slabs
    of identical length.  Returns per-row bin labels, shape (m,).
    """
    rn = np.asarray(row_norms_sq, np.float64).reshape(-1)
    nz = np.asarray(row_nnz, np.float64).reshape(-1)
    m = rn.size
    if m % num_slabs:
        raise ValueError(
            f"slab count ({num_slabs}) must divide the row count ({m})")
    cap = m // num_slabs
    order = np.argsort(-rn, kind="stable")
    labels = np.empty((m,), np.int32)
    mass = np.zeros((num_slabs,), np.float64)
    work = np.zeros((num_slabs,), np.float64)
    fill = np.zeros((num_slabs,), np.int64)
    for r in order:
        cand = np.flatnonzero(fill < cap)
        best = cand[np.lexsort((cand, work[cand], mass[cand]))[0]]
        labels[r] = best
        mass[best] += rn[r]
        work[best] += nz[r]
        fill[best] += 1
    return labels


def partition_permutation(labels, num_slabs: int) -> RowPermutation:
    """Permutation placing each bin's rows (in ascending original order,
    preserving locality within a slab) at its contiguous slab positions."""
    labels = np.asarray(labels).reshape(-1)
    perm = np.concatenate(
        [np.flatnonzero(labels == w) for w in range(num_slabs)])
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return RowPermutation(perm=jnp.asarray(perm, jnp.int32),
                          inv=jnp.asarray(inv, jnp.int32))


def balanced_labels(op, num_slabs: int) -> np.ndarray:
    """Per-row slab labels of the norm/nnz-balanced assignment for a
    padded-row operator — the single source the permutation AND the
    diagnostics (``slab_norm_mass``, ``cross_slab_edges``) derive from."""
    if not hasattr(op, "padded_rows"):
        raise NotImplementedError(
            "balanced partitioning needs a padded-row format (CsrOp/EllOp); "
            f"got {type(op).__name__} — contiguous slabs are the only "
            "assignment the dense/banded layouts support")
    rn = np.asarray(op.row_norms_sq()).reshape(-1)
    vals, _ = op.padded_rows()
    nnz = (np.asarray(vals) != 0).sum(axis=1)
    return norm_balanced_assignment(rn, nnz, num_slabs)


def balanced_row_permutation(op, num_slabs: int) -> RowPermutation:
    """Norm/nnz-balanced ``RowPermutation`` for a padded-row operator."""
    return partition_permutation(balanced_labels(op, num_slabs), num_slabs)


def cross_slab_edges(op, labels, num_slabs: int, *,
                     col_labels=None) -> int:
    """Count of stored nonzeros reaching outside their owner slab.

    ``labels`` assigns each *row* to a slab (``labels[i]`` in
    ``[0, num_slabs)`` — e.g. the output of ``norm_balanced_assignment``,
    or ``arange(m) // (m // P)`` for the contiguous baseline).  A nonzero
    ``(i, j)`` is a *cross-slab edge* when the column's owning slab differs
    from the row's: by default columns are owned contiguously
    (``j // (n / P)`` — the distributed engine's column-slab ownership,
    which both RK delta syncs reduce onto); pass ``col_labels`` for a
    square symmetric assignment where columns move with their rows.

    This is the wire-volume side of the partition-quality trade-off: the
    norm-balanced bin-packing of ``norm_balanced_assignment`` optimizes
    sampling fidelity and per-round work but is free to scatter a row far
    from the slabs it reads, and every cross-slab edge is a coefficient
    the periodic sync must carry.  Reported per assignment by
    ``benchmarks/bench_lsq.py::run_partitioned_rk`` — the measurement
    groundwork for reach-aware bin-packing (minimize edges jointly with
    norm mass).
    """
    if not hasattr(op, "padded_rows"):
        raise NotImplementedError(
            "cross_slab_edges needs a padded-row format (CsrOp/EllOp); "
            f"got {type(op).__name__}")
    m, n = op.shape
    if n % num_slabs:
        raise ValueError(
            f"slab count ({num_slabs}) must divide the column count ({n}) "
            "for contiguous column ownership")
    labels = np.asarray(labels).reshape(-1)
    if labels.shape != (m,):
        raise ValueError(f"labels must assign every row: {labels.shape} "
                         f"vs m={m}")
    vals, cols = map(np.asarray, op.padded_rows())
    if col_labels is None:
        col_lab = cols // (n // num_slabs)
    else:
        col_lab = np.asarray(col_labels).reshape(-1)[cols]
    real = vals != 0
    return int((real & (labels[:, None] != col_lab)).sum())


def slab_norm_mass(row_norms_sq, perm, num_slabs: int) -> np.ndarray:
    """Per-slab Σ ||A_i||² under ``perm`` — the balance diagnostic the
    partition tests assert on (uniform = total/P for every slab)."""
    rn = np.asarray(row_norms_sq, np.float64).reshape(-1)[np.asarray(perm)]
    return rn.reshape(num_slabs, -1).sum(axis=1)


def permute_rows(op, rp: RowPermutation, *, symmetric: bool = False):
    """Apply ``rp`` to an operator, returning the *same* format.

    ``symmetric=False`` permutes rows only (``P A`` — the rectangular row
    action); ``symmetric=True`` applies ``P A Pᵀ`` (square coordinate
    action: columns are relabeled through ``inv`` so the coordinate slab
    moves with the row slab).  CsrOp re-panelizes through ``_assemble`` so
    the permuted instance keeps the contiguous panel layout; EllOp permutes
    its fixed-width windows directly.  Padding slots carry value 0, so
    relabeling their column ids contributes nothing.
    """
    if symmetric and op.shape[0] != op.shape[1]:
        raise ValueError(
            f"symmetric permutation needs a square operator; got {op.shape}")
    if isinstance(op, EllOp):
        vals = op.vals[rp.perm]
        cols = op.cols[rp.perm]
        if symmetric:
            # relabeling through int32 ``inv`` widens; restore the stored
            # index dtype (ids are bounded by n, so narrowing is safe)
            cols = rp.inv[cols].astype(op.cols.dtype)
        return EllOp(vals, cols)
    if isinstance(op, CsrOp):
        vals, cols = map(np.asarray, op.padded_rows())
        perm = np.asarray(rp.perm)
        counts = np.asarray(op.row_nnz)[perm]
        vals, cols = vals[perm], cols[perm].astype(np.int64)
        if symmetric:
            cols = np.asarray(rp.inv)[cols]
        return CsrOp._assemble(vals, cols.astype(np.int32), counts,
                               shape=op.shape,
                               rows_per_panel=op.rows_per_panel)
    raise NotImplementedError(
        "balanced partitioning needs a padded-row format (CsrOp/EllOp); "
        f"got {type(op).__name__}")


def apply_partition(op, b, x0, x_star, *, action: str, num_slabs: int):
    """Permute an (op, b, x0, x_star) problem onto balanced slabs.

    Returns ``(op', b', x0', x_star', rp)``.  For "rk" the iterate lives in
    column space and is untouched; for "gs" the symmetric permutation moves
    the coordinate vectors too, and the caller un-permutes the result with
    ``rp.inv``.  Metric values (norms) are permutation-invariant.
    """
    rp = balanced_row_permutation(op, num_slabs)
    symmetric = action == "gs"
    op2 = permute_rows(op, rp, symmetric=symmetric)
    b2 = b[rp.perm]
    if symmetric:
        x0 = x0[rp.perm]
        x_star = None if x_star is None else x_star[rp.perm]
    return op2, b2, x0, x_star, rp
