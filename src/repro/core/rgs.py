"""Synchronous Randomized Gauss-Seidel (Leventhal & Lewis), the paper's §2.2.

Iteration (1):  pick d_j = e^{(r)} with r ~ U{1..n};
                gamma_j = (b - A x_j)_r;   x_{j+1} = x_j + beta * gamma_j e^{(r)}.

Multi-RHS: x and b are (n, k); the same random direction is used for all k
columns, exactly as in the paper's experiments (51 RHS solved together).

``rgs_solve`` and ``block_gs_solve`` are thin wrappers over the unified
engine (repro.core.engine) — the "gs" action on a ``DenseOp`` — and produce
bit-identical iterates to their pre-refactor implementations (pinned by
tests/test_engine_equivalence.py).  Also implements the general
non-unit-diagonal iteration (3) used by the rescaling-equivalence property
test, which takes explicit directions and stays a standalone loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import spd
from repro.core.engine import SolveResult, solve_sequential
from repro.core.operators import DenseOp

__all__ = ["SolveResult", "block_gs_solve", "rgs_general", "rgs_solve"]


def _record(A, b, x, x_star):
    """Legacy recording helper (A-norm error + residual); kept for cg.py."""
    e = x - x_star
    return spd.a_norm_sq(A, e), jnp.linalg.norm(b - A @ x, axis=0)


def rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    """Run ``num_iters`` randomized GS iterations; record error every
    ``record_every`` iterations (0 -> only at the end)."""
    return solve_sequential(
        DenseOp(A), b, x0, x_star, action="gs", key=key, num_iters=num_iters,
        beta=beta, block=1, record_every=record_every)


def block_gs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_sweeps: int,
    block: int,
    beta: float = 1.0,
) -> SolveResult:
    """Randomized *block* GS — the TPU-adapted granularity (DESIGN.md §2).

    Each step picks a random aligned block of ``block`` coordinates and
    applies a damped block-Jacobi update x_B += beta * (b - A x)_B.  One
    sweep = n/block steps.  This is the pure-jnp semantic twin of the Pallas
    kernel in repro.kernels.block_gs.
    """
    nb = A.shape[0] // block
    return solve_sequential(
        DenseOp(A), b, x0, x_star, action="gs", key=key,
        num_iters=num_sweeps * nb, beta=beta, block=block, record_every=nb)


@functools.partial(jax.jit, static_argnames=("num_iters",))
def rgs_general(
    B: jax.Array,
    z: jax.Array,
    y0: jax.Array,
    *,
    coords: jax.Array,
    beta: float = 1.0,
    num_iters: int,
) -> jax.Array:
    """Non-unit-diagonal iteration (3):
    gamma~ = (z - B y)_r / B_rr ; y_r += beta * gamma~.  Directions are given
    explicitly (``coords``) so the equivalence test can share them with the
    unit-diagonal run."""
    del num_iters

    def step(y, r):
        gamma = (z[r] - B[r] @ y) / B[r, r]
        return y.at[r].add(beta * gamma), None

    y, _ = jax.lax.scan(step, y0, coords)
    return y
