"""Synchronous Randomized Gauss-Seidel (Leventhal & Lewis), the paper's §2.2.

Iteration (1):  pick d_j = e^{(r)} with r ~ U{1..n};
                gamma_j = (b - A x_j)_r;   x_{j+1} = x_j + beta * gamma_j e^{(r)}.

Multi-RHS: x and b are (n, k); the same random direction is used for all k
columns, exactly as in the paper's experiments (51 RHS solved together).

Also implements the general non-unit-diagonal iteration (3) used by the
rescaling-equivalence property test.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import spd


class SolveResult(NamedTuple):
    x: jax.Array           # (n, k) final iterate
    err_sq: jax.Array      # (records, k) ||x_m - x*||_A^2 at each record point
    resid: jax.Array       # (records, k) ||b - A x_m||_2 at each record point
    iters: jax.Array       # (records,) iteration index of each record


def _record(A, b, x, x_star):
    e = x - x_star
    return spd.a_norm_sq(A, e), jnp.linalg.norm(b - A @ x, axis=0)


@functools.partial(jax.jit, static_argnames=("num_iters", "record_every"))
def rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_iters: int,
    beta: float = 1.0,
    record_every: int = 0,
) -> SolveResult:
    """Run ``num_iters`` randomized GS iterations; record error every
    ``record_every`` iterations (0 -> only at the end)."""
    n = A.shape[0]
    rec = record_every or num_iters
    assert num_iters % rec == 0
    coords = jax.random.randint(key, (num_iters,), 0, n)

    def step(x, r):
        gamma = b[r] - A[r] @ x          # (k,)
        return x.at[r].add(beta * gamma), None

    def chunk(x, cs):
        x, _ = jax.lax.scan(step, x, cs)
        return x, _record(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(chunk, x0, coords.reshape(-1, rec))
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=x, err_sq=errs, resid=resids, iters=iters)


@functools.partial(jax.jit, static_argnames=("num_iters",))
def rgs_general(
    B: jax.Array,
    z: jax.Array,
    y0: jax.Array,
    *,
    coords: jax.Array,
    beta: float = 1.0,
    num_iters: int,
) -> jax.Array:
    """Non-unit-diagonal iteration (3):
    gamma~ = (z - B y)_r / B_rr ; y_r += beta * gamma~.  Directions are given
    explicitly (``coords``) so the equivalence test can share them with the
    unit-diagonal run."""
    del num_iters

    def step(y, r):
        gamma = (z[r] - B[r] @ y) / B[r, r]
        return y.at[r].add(beta * gamma), None

    y, _ = jax.lax.scan(step, y0, coords)
    return y


@functools.partial(jax.jit, static_argnames=("num_sweeps", "block"))
def block_gs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    num_sweeps: int,
    block: int,
    beta: float = 1.0,
) -> SolveResult:
    """Randomized *block* GS — the TPU-adapted granularity (DESIGN.md §2).

    Each step picks a random aligned block of ``block`` coordinates and
    applies a damped block-Jacobi update x_B += beta * (b - A x)_B.  One
    sweep = n/block steps.  This is the pure-jnp semantic twin of the Pallas
    kernel in repro.kernels.block_gs.
    """
    n = A.shape[0]
    nb = n // block
    steps = num_sweeps * nb
    blocks = jax.random.randint(key, (steps,), 0, nb)

    def step(x, bi):
        rows = bi * block + jnp.arange(block)
        Ab = A[rows]                      # (block, n)
        gamma = b[rows] - Ab @ x          # (block, k)
        return x.at[rows].add(beta * gamma), None

    def sweep(x, bs):
        x, _ = jax.lax.scan(step, x, bs)
        return x, _record(A, b, x, x_star)

    x, (errs, resids) = jax.lax.scan(sweep, x0, blocks.reshape(num_sweeps, nb))
    return SolveResult(x=x, err_sq=errs, resid=resids,
                       iters=(1 + jnp.arange(num_sweeps)) * nb)
