"""Asynchronous Randomized Gauss-Seidel under the paper's bounded-delay model.

This is the *faithful* simulator of the paper's two read models:

Consistent read (eq. 4, Thm 4.1):
    gamma_j = (x* - x_{k(j)}, d_j)_A,   j - tau <= k(j) <= j
    x_{j+1} = x_j + beta * gamma_j d_j

Inconsistent read (eq. 16, Thm 6.1):
    gamma_j = (x* - x_{K(j)}, d_j)_A,   {0..j-tau-1} ⊆ K(j)
    x_{j+1} = x_j + beta * gamma_j d_j

``async_rgs_solve`` is a thin wrapper over the engine's bounded-delay
simulator (``repro.core.engine.solve_async_sim`` with the "gs" action; the
same simulator drives ``async_rk_solve`` with the row action — the two
differ only in the correction weight and update direction).  See the engine
docstring for the ring-buffer mechanics that reconstruct the stale read
exactly in O(n + tau) per iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import SolveResult, solve_async_sim
from repro.core.operators import DenseOp

__all__ = ["SolveResult", "async_rgs_solve", "iteration_identity_gap"]


def async_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    """Simulate asynchronous RGS with delays bounded by ``tau``.

    delay_mode (consistent reads):
      * "fixed":    s_j = tau                      (worst case allowed by A-3)
      * "uniform":  s_j ~ U{0..tau}                (random but independent)
      * "cyclic":   s_j = j mod (tau+1)            (P processors round-robin)
    read_model "inconsistent": each of the last tau updates is invisible
    independently with prob ``miss_prob`` (K(j) = arbitrary subset, eq. 6).
    """
    return solve_async_sim(
        DenseOp(A), b, x0, x_star, action="gs", key=key, delay_key=delay_key,
        num_iters=num_iters, tau=tau, beta=beta, read_model=read_model,
        delay_mode=delay_mode, miss_prob=miss_prob, record_every=record_every)


def iteration_identity_gap(A, b, x, x_star, x_stale, r, beta=1.0):
    """Exact per-iteration identity, eq. (7)/(14) — used by property tests.

    Returns (lhs, rhs) of
      ||x_{j+1}-x*||_A^2 = ||x_j-x*||_A^2
                           - beta(2-beta) (x_stale - x*, d)_A^2
                           - 2 beta (x_stale - x*, d)_A (x_j - x_stale, d)_A
    which should match to rounding for any x, x_stale, r.
    """
    d = jnp.zeros(A.shape[0], A.dtype).at[r].set(1.0)

    def inner_a(u, v):
        return u @ (A @ v)

    gamma = inner_a(x_star - x_stale, d)
    x_next = x + beta * gamma * d
    lhs = inner_a(x_next - x_star, x_next - x_star)
    g = inner_a(x_stale - x_star, d)
    rhs = (
        inner_a(x - x_star, x - x_star)
        - beta * (2 - beta) * g**2
        - 2 * beta * g * inner_a(x - x_stale, d)
    )
    return lhs, rhs
