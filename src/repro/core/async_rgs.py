"""Asynchronous Randomized Gauss-Seidel under the paper's bounded-delay model.

This is the *faithful* simulator of the paper's two read models:

Consistent read (eq. 4, Thm 4.1):
    gamma_j = (x* - x_{k(j)}, d_j)_A,   j - tau <= k(j) <= j
    x_{j+1} = x_j + beta * gamma_j d_j

Inconsistent read (eq. 16, Thm 6.1):
    gamma_j = (x* - x_{K(j)}, d_j)_A,   {0..j-tau-1} ⊆ K(j)
    x_{j+1} = x_j + beta * gamma_j d_j

Mechanics: we keep a ring buffer of the last ``tau`` applied updates
(coordinate r_t, applied amount beta*gamma_t).  The stale read is never
materialized; instead we use

    A_r x_{k(j)} = A_r x_j - sum_{t invisible} (beta*gamma_t) A[r, r_t]

which is exact, O(n + tau) per iteration, and valid for both models (the
models differ only in *which* recent updates are invisible: a suffix of
length s_j for consistent reads, an arbitrary independent subset for
inconsistent reads).  Delay schedules are drawn from a key independent of
the direction key — Assumption A-4 (independent delays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import spd
from repro.core.rgs import SolveResult, _record


@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "tau", "record_every", "read_model", "delay_mode"),
)
def async_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    delay_key: jax.Array,
    num_iters: int,
    tau: int,
    beta: float = 1.0,
    read_model: str = "consistent",
    delay_mode: str = "fixed",
    miss_prob: float = 0.5,
    record_every: int = 0,
) -> SolveResult:
    """Simulate asynchronous RGS with delays bounded by ``tau``.

    delay_mode (consistent reads):
      * "fixed":    s_j = tau                      (worst case allowed by A-3)
      * "uniform":  s_j ~ U{0..tau}                (random but independent)
      * "cyclic":   s_j = j mod (tau+1)            (P processors round-robin)
    read_model "inconsistent": each of the last tau updates is invisible
    independently with prob ``miss_prob`` (K(j) = arbitrary subset, eq. 6).
    """
    n = A.shape[0]
    k = b.shape[1]
    rec = record_every or num_iters
    assert num_iters % rec == 0
    if tau == 0:
        # Degenerates exactly to synchronous RGS; keep one code path anyway
        # so tests can diff the two implementations.
        pass

    coords = jax.random.randint(key, (num_iters,), 0, n)
    t_buf = max(tau, 1)

    if read_model == "consistent":
        if delay_mode == "fixed":
            delays = jnp.full((num_iters,), tau, jnp.int32)
        elif delay_mode == "uniform":
            delays = jax.random.randint(delay_key, (num_iters,), 0, tau + 1)
        elif delay_mode == "cyclic":
            delays = (jnp.arange(num_iters) % (tau + 1)).astype(jnp.int32)
        else:
            raise ValueError(delay_mode)
        aux = delays
    elif read_model == "inconsistent":
        aux = jax.random.bernoulli(delay_key, miss_prob, (num_iters, t_buf))
    else:
        raise ValueError(read_model)

    ring_r0 = jnp.zeros((t_buf,), jnp.int32)
    ring_g0 = jnp.zeros((t_buf, k), x0.dtype)

    offsets = jnp.arange(t_buf)

    def step(carry, inp):
        x, ring_r, ring_g, j = carry
        r, a = inp
        # Slot of the update made at iteration (j - 1 - i) is (j - 1 - i) mod t_buf.
        it_idx = j - 1 - offsets                      # iteration indices, newest first
        valid = it_idx >= 0
        if read_model == "consistent":
            invisible = (offsets < a) & valid          # suffix of length s_j
        else:
            invisible = a & valid & (offsets < tau)    # arbitrary subset of last tau
        slots = jnp.mod(it_idx, t_buf)
        rs = ring_r[slots]                             # (t_buf,)
        gs = ring_g[slots]                             # (t_buf, k) applied amounts
        # Correction restores the stale read: A_r x_stale = A_r x - sum beta*g*A[r, r_t]
        w = jnp.where(invisible, A[r, rs], 0.0)        # (t_buf,)
        corr = w @ gs                                  # (k,)
        gamma = b[r] - A[r] @ x + corr
        applied = beta * gamma
        x = x.at[r].add(applied)
        ring_r = ring_r.at[jnp.mod(j, t_buf)].set(r)
        ring_g = ring_g.at[jnp.mod(j, t_buf)].set(applied)
        return (x, ring_r, ring_g, j + 1), None

    def chunk(carry, inp):
        carry, _ = jax.lax.scan(step, carry, inp)
        errs = _record(A, b, carry[0], x_star)
        return carry, errs

    inps = (coords.reshape(-1, rec), aux.reshape((-1, rec) + aux.shape[1:]))
    carry = (x0, ring_r0, ring_g0, jnp.array(0, jnp.int32))
    carry, (errs, resids) = jax.lax.scan(chunk, carry, inps)
    iters = (1 + jnp.arange(num_iters // rec)) * rec
    return SolveResult(x=carry[0], err_sq=errs, resid=resids, iters=iters)


def iteration_identity_gap(A, b, x, x_star, x_stale, r, beta=1.0):
    """Exact per-iteration identity, eq. (7)/(14) — used by property tests.

    Returns (lhs, rhs) of
      ||x_{j+1}-x*||_A^2 = ||x_j-x*||_A^2
                           - beta(2-beta) (x_stale - x*, d)_A^2
                           - 2 beta (x_stale - x*, d)_A (x_j - x_stale, d)_A
    which should match to rounding for any x, x_stale, r.
    """
    d = jnp.zeros(A.shape[0], A.dtype).at[r].set(1.0)

    def inner_a(u, v):
        return u @ (A @ v)

    gamma = inner_a(x_star - x_stale, d)
    x_next = x + beta * gamma * d
    lhs = inner_a(x_next - x_star, x_next - x_star)
    g = inner_a(x_stale - x_star, d)
    rhs = (
        inner_a(x - x_star, x - x_star)
        - beta * (2 - beta) * g**2
        - 2 * beta * g * inner_a(x - x_stale, d)
    )
    return lhs, rhs
