"""Conjugate Gradients (the paper's baseline, Sec. 8) and flexible CG
preconditioned by randomized Gauss-Seidel sweeps (the paper's proposed
future-work path, Sec. 8/9).

Multi-RHS throughout: b, x are (n, k) and every scalar of textbook CG
becomes a (k,) vector (the paper solves 51 systems with a shared A).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import spd
from repro.core.rgs import SolveResult, _record


@functools.partial(jax.jit, static_argnames=("num_iters",))
def cg_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    num_iters: int,
) -> SolveResult:
    r0 = b - A @ x0

    def step(carry, _):
        x, r, p, rs = carry
        Ap = A @ p
        # 0/0 guards: once a column converges to machine zero, freeze it.
        live = rs > 1e-30
        alpha = jnp.where(live, rs / jnp.maximum(
            jnp.einsum("nk,nk->k", p, Ap), 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.einsum("nk,nk->k", r, r)
        p = r + jnp.where(live, rs_new / jnp.maximum(rs, 1e-30), 0.0) * p
        err = _record(A, b, x, x_star)
        return (x, r, p, rs_new), err

    carry = (x0, r0, r0, jnp.einsum("nk,nk->k", r0, r0))
    carry, (errs, resids) = jax.lax.scan(step, carry, None, length=num_iters)
    return SolveResult(x=carry[0], err_sq=errs, resid=resids,
                       iters=1 + jnp.arange(num_iters))


def make_rgs_preconditioner(A: jax.Array, *, sweeps: int, block: int, beta: float, seed: int = 7):
    """M^{-1} r ~= `sweeps` randomized block-GS sweeps on A z = r from z0=0.

    The preconditioner is a *changing* linear operator (fresh random blocks
    per application) — precisely why flexible CG is required (Sec. 8).
    """
    n = A.shape[0]
    nb = n // block
    counter = {"i": 0}

    def apply(r: jax.Array) -> jax.Array:
        key = jax.random.key(seed + counter["i"])
        counter["i"] += 1
        blocks = jax.random.randint(key, (sweeps * nb,), 0, nb)

        def step(z, bi):
            rows = bi * block + jnp.arange(block)
            g = r[rows] - A[rows] @ z
            return z.at[rows].add(beta * g), None

        z, _ = jax.lax.scan(step, jnp.zeros_like(r), blocks)
        return z

    return apply


def fcg_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    precond: Callable[[jax.Array], jax.Array],
    num_iters: int,
) -> SolveResult:
    """Flexible CG (Notay's FCG(1)): beta via the Polak-Ribiere-like form
    beta = (z_{i+1}, r_{i+1} - r_i) / (z_i, r_i), robust to a preconditioner
    that changes between iterations."""
    x, r = x0, b - A @ x0
    z = precond(r)
    p = z
    zr = jnp.einsum("nk,nk->k", z, r)
    errs, resids = [], []
    for _ in range(num_iters):
        Ap = A @ p
        alpha = zr / jnp.einsum("nk,nk->k", p, Ap)
        x = x + alpha * p
        r_new = r - alpha * Ap
        z = precond(r_new)
        zr_new = jnp.einsum("nk,nk->k", z, r_new)
        beta = jnp.einsum("nk,nk->k", z, r_new - r) / zr
        p = z + beta * p
        r, zr = r_new, zr_new
        e, rr = _record(A, b, x, x_star)
        errs.append(e)
        resids.append(rr)
    return SolveResult(x=x, err_sq=jnp.stack(errs), resid=jnp.stack(resids),
                       iters=1 + jnp.arange(num_iters))
