"""Distributed asynchronous randomized (block) Gauss-Seidel via shard_map.

This is the pod-scale adaptation of the paper's algorithm (DESIGN.md §2):

* coordinates are partitioned into P slabs, one per device along a mesh axis
  (owner-computes replaces the shared-memory atomic write);
* every device holds a *stale replica* of the full iterate x and performs
  ``local_steps`` randomized (block) updates restricted to its own slab;
* an all-gather (or, for the banded format, a neighbor halo exchange) is
  the paper's *periodic synchronization* (Thm 4.1(a) scheme).  The
  effective delay bound is tau = (P - 1) * local_steps, which is
  *scheduled*, so the optimal step size beta~ = 1/(1 + 2 rho tau) is
  computable in closed form.

All three entry points are thin wrappers over the unified distributed
driver (``repro.core.engine.solve_distributed``) — the "gs" action over a
``DenseOp`` or ``BlockBandedOp`` with the all-gather or halo sync
strategy — and produce bit-identical iterates to their pre-refactor
implementations (pinned by tests/test_engine_equivalence.py).
"""
from __future__ import annotations

import jax

from repro.core.engine import (
    ParallelSolveResult,
    scheduled_tau,
    solve_distributed,
)
from repro.core.operators import BlockBandedOp, DenseOp

__all__ = [
    "ParallelSolveResult",
    "effective_tau",
    "parallel_rgs_banded",
    "parallel_rgs_halo",
    "parallel_rgs_solve",
]


def effective_tau(num_workers: int, local_steps: int) -> int:
    """Scheduled staleness of the per-worker-stream schedule (compat
    re-export of ``engine.scheduled_tau(shared_stream=False)``)."""
    return scheduled_tau(num_workers, local_steps, shared_stream=False)


def parallel_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 1,
    beta: float = 1.0,
    unroll: bool = False,   # unroll both scans (dry-run cost fidelity)
) -> ParallelSolveResult:
    """Solve A x = b with P-way asynchronous block RGS.

    A: (n, n) with n divisible by P*block; b, x0, x_star: (n, k).
    """
    return solve_distributed(
        DenseOp(A), b, x0, x_star, action="gs", key=key, mesh=mesh, axis=axis,
        rounds=rounds, local_steps=local_steps, block=block, beta=beta,
        sync="allgather", unroll=unroll)


def parallel_rgs_banded(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star_or_none,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,   # False = production inner loop (the paper's
                                 # time-based sync scheme checks residuals
                                 # only periodically, Sec. 4 discussion)
) -> ParallelSolveResult:
    """Block-banded distributed asynchronous RGS — the TPU-native layout.

    The §Perf structural optimization over ``parallel_rgs_solve``: instead of
    streaming a dense (block, n) row panel per step (which reads mostly
    zeros in the reference scenario), the matrix is stored as block-band
    tiles ``A_bands[nb, 2*bands+1, block, block]`` (see kernels/bbmv.py) and
    each step reads only (2*bands+1) MXU-shaped tiles — restoring the
    paper's Θ(nnz) per-iteration cost on TPU.
    """
    op = BlockBandedOp(A_bands, bands=bands)
    assert op.block == block, (op.block, block)
    return solve_distributed(
        op, b, x0, x_star_or_none, action="gs", key=key, mesh=mesh, axis=axis,
        rounds=rounds, local_steps=local_steps, beta=beta, sync="allgather",
        unroll=unroll, with_metrics=with_metrics)


def parallel_rgs_halo(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,
) -> ParallelSolveResult:
    """Halo-exchange variant of the banded distributed RGS (§Perf iter 3).

    Band structure means a worker's rows only ever read x within
    ``bands*block`` rows of its own slab — so the per-round all-gather of
    the full (n, k) iterate is replaced by two neighbor ``ppermute`` halo
    exchanges of (bands*block, k) rows, and no worker ever materializes the
    global vector (memory O(slab)).  The iterates are IDENTICAL to
    ``parallel_rgs_banded`` — the gathered entries outside the halo were
    never read.

    This entry point takes no ``x_star``, so ``err_sq`` is NaN (pre-refactor
    it silently carried the squared residual); call the engine's
    ``solve_distributed(..., sync="halo")`` with ``x_star`` to get the
    window-local A-norm error.
    """
    op = BlockBandedOp(A_bands, bands=bands)
    assert op.block == block, (op.block, block)
    return solve_distributed(
        op, b, x0, None, action="gs", key=key, mesh=mesh, axis=axis,
        rounds=rounds, local_steps=local_steps, beta=beta, sync="halo",
        unroll=unroll, with_metrics=with_metrics)
