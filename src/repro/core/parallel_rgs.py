"""Distributed asynchronous randomized (block) Gauss-Seidel via shard_map.

This is the pod-scale adaptation of the paper's algorithm (DESIGN.md §2):

* coordinates are partitioned into P slabs, one per device along a mesh axis
  (owner-computes replaces the shared-memory atomic write);
* every device holds a *stale replica* of the full iterate x and performs
  ``local_steps`` randomized (block) updates restricted to its own slab —
  reading remote coordinates from the stale replica and its own coordinates
  fresh (exactly the consistent-read model: its reads correspond to the
  global iterate at the last synchronization plus its own prefix of updates);
* an all-gather of the slab deltas is the paper's *periodic synchronization*
  (Thm 4.1(a) scheme).  The effective delay bound is
  tau = (P - 1) * local_steps, which is *scheduled*, so the optimal step
  size beta~ = 1/(1 + 2 rho tau) is computable in closed form.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map


class ParallelSolveResult(NamedTuple):
    x: jax.Array        # (n, k)
    err_sq: jax.Array   # (rounds, k)
    resid: jax.Array    # (rounds, k)
    tau: int            # effective staleness bound of the schedule


def effective_tau(num_workers: int, local_steps: int) -> int:
    return (num_workers - 1) * local_steps


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "beta",
                     "unroll"),
)
def parallel_rgs_solve(
    A: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 1,
    beta: float = 1.0,
    unroll: bool = False,   # unroll both scans (dry-run cost fidelity)
) -> ParallelSolveResult:
    """Solve A x = b with P-way asynchronous block RGS.

    A: (n, n) with n divisible by P*block; b, x0, x_star: (n, k).
    """
    num_workers = mesh.shape[axis]
    n = A.shape[0]
    slab = n // num_workers
    assert slab * num_workers == n and slab % block == 0
    round_keys = jax.random.split(key, rounds)

    def worker(A_sh, b_sh, xs_sh, x0_full, keys):
        # A_sh: (slab, n), b_sh/xs_sh: (slab, k), x0_full: (n, k) replicated.
        w = jax.lax.axis_index(axis)
        col0 = w * slab

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, slab // block)
            # Mark as device-varying: each worker accumulates its own deltas.
            delta = pvary(
                jnp.zeros((slab, b_sh.shape[1]), x.dtype), (axis,)
            )

            def step(delta, bi):
                rows = bi * block + jnp.arange(block)
                Ar = A_sh[rows]                          # (block, n)
                stale = Ar @ x                           # stale replica read
                # own-slab columns see the *fresh* local updates:
                own = jax.lax.dynamic_slice(Ar, (0, col0), (block, slab))
                g = b_sh[rows] - stale - own @ delta
                return delta.at[rows].add(beta * g), None

            delta, _ = jax.lax.scan(step, delta, picks,
                                    unroll=local_steps if unroll else 1)
            # Periodic synchronization (the paper's Thm 4.1(a) scheme).
            x = x + jax.lax.all_gather(delta, axis, axis=0, tiled=True)
            # Metrics: ||x - x*||_A^2 and ||b - A x||_2 from slab-local parts.
            e_local = jax.lax.dynamic_slice_in_dim(x, col0, slab, 0) - xs_sh
            err = jax.lax.psum(
                jnp.einsum("sk,sk->k", e_local, A_sh @ (x - _xstar_full(x))), axis
            )
            r_local = b_sh - A_sh @ x
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            return x, (err, jnp.sqrt(rsq))

        def _xstar_full(x):
            # full x* reconstructed once per round via all-gather of slabs
            return jax.lax.all_gather(xs_sh, axis, axis=0, tiled=True)

        x, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), keys,
            unroll=rounds if unroll else 1,
        )
        # Every worker's x is identical after the final all-gather, but the
        # VMA type system cannot prove it; return the owned slab (the honest
        # sharding) and let the out_spec reassemble the global vector.
        x_slab = jax.lax.dynamic_slice_in_dim(x, col0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(None, None), P(None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A, b, x_star, x0, round_keys)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids, tau=effective_tau(num_workers, local_steps)
    )


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "bands",
                     "beta", "unroll", "with_metrics"),
)
def parallel_rgs_banded(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    x_star_or_none,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,   # False = production inner loop (the paper's
                                 # time-based sync scheme checks residuals
                                 # only periodically, Sec. 4 discussion)
) -> ParallelSolveResult:
    """Block-banded distributed asynchronous RGS — the TPU-native layout.

    The §Perf structural optimization over ``parallel_rgs_solve``: instead of
    streaming a dense (block, n) row panel per step (which reads mostly
    zeros in the reference scenario), the matrix is stored as block-band
    tiles ``A_bands[nb, 2*bands+1, block, block]`` (see kernels/bbmv.py) and
    each step reads only (2*bands+1) MXU-shaped tiles — restoring the
    paper's Θ(nnz) per-iteration cost on TPU.  Bytes per step drop by
    n / ((2*bands+1) * block) (~2 orders of magnitude at n=128k).

    Each worker keeps a full working replica ``xw``: own rows are updated in
    place (fresh, exactly the consistent-read model), remote rows stay stale
    until the per-round all-gather (the paper's periodic synchronization).
    """
    num_workers = mesh.shape[axis]
    n, k = b.shape
    nb = n // block
    slab = n // num_workers
    nb_local = slab // block
    assert nb * block == n and nb_local * block == slab
    width = A_bands.shape[1]
    assert width == 2 * bands + 1
    round_keys = jax.random.split(key, rounds)

    def worker(Ab_sh, b_sh, keys, x0_full, xs_full):
        # Ab_sh: (nb_local, width, block, block); b_sh: (slab, k).
        w = jax.lax.axis_index(axis)
        row0 = w * slab

        def banded_apply(xw, bi_local):
            """(b - A x)[rows of local block bi_local] using band tiles."""
            gb = w * nb_local + bi_local            # global block-row index
            acc = jax.lax.dynamic_slice_in_dim(
                b_sh, bi_local * block, block, 0).astype(jnp.float32)
            tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi_local, 1, 0)[0]
            for d in range(width):
                cb = gb + d - bands                  # global column block
                cbc = jnp.clip(cb, 0, nb - 1)
                xs = jax.lax.dynamic_slice_in_dim(xw, cbc * block, block, 0)
                contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
                valid = (cb >= 0) & (cb < nb)
                acc = acc - jnp.where(valid, contrib, 0.0)
            return acc.astype(xw.dtype)

        def round_body(x, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)
            xw = x   # working replica: own rows fresh, remote rows stale

            def step(xw, bi):
                g = banded_apply(xw, bi)
                rows0 = row0 + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, rows0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, rows0, 0), None

            xw, _ = jax.lax.scan(step, xw, picks,
                                 unroll=local_steps if unroll else 1)
            own = jax.lax.dynamic_slice_in_dim(xw, row0, slab, 0)
            x = jax.lax.all_gather(own, axis, axis=0, tiled=True)
            if not with_metrics:
                z = jnp.zeros((b_sh.shape[1],), jnp.float32)
                return x, (z, z)
            # metrics (slab-local residual psum)
            r_local = b_sh - _banded_matvec(Ab_sh, x, w, nb, nb_local, block,
                                            bands)
            rsq = jax.lax.psum(jnp.einsum("sk,sk->k", r_local, r_local), axis)
            if xs_full is not None:
                e_own = own - jax.lax.dynamic_slice_in_dim(xs_full, row0, slab, 0)
                esq = jax.lax.psum(
                    jnp.einsum("sk,sk->k", e_own,
                               -r_local + (b_sh - _banded_matvec(
                                   Ab_sh, xs_full, w, nb, nb_local, block, bands))),
                    axis)
            else:
                esq = rsq
            return x, (esq, jnp.sqrt(rsq))

        x, (errs, resids) = jax.lax.scan(
            round_body, pvary(x0_full, (axis,)), keys,
            unroll=rounds if unroll else 1)
        x_slab = jax.lax.dynamic_slice_in_dim(x, row0, slab, 0)
        return x_slab, errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(None),
                  P(None, None), P(None, None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A_bands, b, round_keys, x0, x_star_or_none)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=effective_tau(num_workers, local_steps))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "rounds", "local_steps", "block", "bands",
                     "beta", "unroll", "with_metrics"),
)
def parallel_rgs_halo(
    A_bands: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    *,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str = "workers",
    rounds: int,
    local_steps: int,
    block: int = 128,
    bands: int = 2,
    beta: float = 1.0,
    unroll: bool = False,
    with_metrics: bool = True,
) -> ParallelSolveResult:
    """Halo-exchange variant of the banded distributed RGS (§Perf iter 3).

    Band structure means a worker's rows only ever read x within
    ``bands*block`` rows of its own slab — so the per-round all-gather of
    the full (n, k) iterate is replaced by two neighbor ``ppermute`` halo
    exchanges of (bands*block, k) rows: wire volume drops from ~n*k to
    2*bands*block*k per round (~2 orders of magnitude at n=128k), and no
    worker ever materializes the global vector (memory O(slab), enabling
    n far beyond per-device HBM).  The iterates are IDENTICAL to
    ``parallel_rgs_banded`` — the gathered entries outside the halo were
    never read.  General (non-banded) sparsity would use an all-to-all of
    the sparsity-graph neighbors instead; see DESIGN.md.
    """
    num_workers = mesh.shape[axis]
    n, k = b.shape
    nb = n // block
    slab = n // num_workers
    nb_local = slab // block
    halo = bands * block
    assert halo <= slab, "halo exchange needs bands*block <= slab"
    width = 2 * bands + 1
    round_keys = jax.random.split(key, rounds)
    down = [(i, i + 1) for i in range(num_workers - 1)]
    up = [(i + 1, i) for i in range(num_workers - 1)]

    def worker(Ab_sh, b_sh, x0_sh, keys):
        w = jax.lax.axis_index(axis)

        def exchange(xw):
            own = jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0)
            lo_edge = own[:halo]          # my top rows -> prev worker's hi halo
            hi_edge = own[-halo:]         # my bottom rows -> next worker's lo halo
            from_prev = jax.lax.ppermute(hi_edge, axis, down)   # w-1's bottom
            from_next = jax.lax.ppermute(lo_edge, axis, up)     # w+1's top
            xw = jax.lax.dynamic_update_slice_in_dim(xw, from_prev, 0, 0)
            return jax.lax.dynamic_update_slice_in_dim(
                xw, from_next, halo + slab, 0)

        def banded_apply(xw, bi):
            gb = w * nb_local + bi
            acc = jax.lax.dynamic_slice_in_dim(
                b_sh, bi * block, block, 0).astype(jnp.float32)
            tiles = jax.lax.dynamic_slice_in_dim(Ab_sh, bi, 1, 0)[0]
            for d in range(width):
                cb = gb + d - bands
                xs = jax.lax.dynamic_slice_in_dim(
                    xw, jnp.clip((bi + d) * block, 0, slab + 2 * halo - block),
                    block, 0)
                contrib = jnp.dot(tiles[d], xs, preferred_element_type=jnp.float32)
                acc = acc - jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
            return acc.astype(xw.dtype)

        def round_body(xw, rkey):
            rkey = jax.random.fold_in(rkey, w)
            picks = jax.random.randint(rkey, (local_steps,), 0, nb_local)

            def step(xw, bi):
                g = banded_apply(xw, bi)
                r0 = halo + bi * block
                cur = jax.lax.dynamic_slice_in_dim(xw, r0, block, 0)
                return jax.lax.dynamic_update_slice_in_dim(
                    xw, cur + beta * g, r0, 0), None

            xw, _ = jax.lax.scan(step, xw, picks,
                                 unroll=local_steps if unroll else 1)
            xw = exchange(xw)
            if not with_metrics:
                z = jnp.zeros((k,), jnp.float32)
                return xw, (z, z)
            resid2 = jnp.zeros((k,), jnp.float32)
            for bi in range(nb_local):
                r = banded_apply(xw, bi).astype(jnp.float32)
                resid2 = resid2 + jnp.einsum("bk,bk->k", r, r)
            rsq = jax.lax.psum(resid2, axis)
            return xw, (rsq, jnp.sqrt(rsq))

        xw0 = jnp.pad(x0_sh, ((halo, halo), (0, 0)))
        xw0 = exchange(xw0)
        xw, (errs, resids) = jax.lax.scan(round_body, xw0, keys,
                                          unroll=rounds if unroll else 1)
        return jax.lax.dynamic_slice_in_dim(xw, halo, slab, 0), errs, resids

    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis, None, None, None), P(axis, None), P(axis, None),
                  P(None)),
        out_specs=(P(axis, None), P(None, None), P(None, None)),
    )
    x, errs, resids = mapped(A_bands, b, x0, round_keys)
    return ParallelSolveResult(
        x=x, err_sq=errs, resid=resids,
        tau=effective_tau(num_workers, local_steps))


def _banded_matvec(Ab_sh, x, w, nb, nb_local, block, bands):
    """(A x) for the rows owned by worker ``w`` (block-band tiles)."""
    width = 2 * bands + 1

    def one(bi):
        gb = w * nb_local + bi
        acc = jnp.zeros((block, x.shape[1]), jnp.float32)
        for d in range(width):
            cb = gb + d - bands
            cbc = jnp.clip(cb, 0, nb - 1)
            xs = jax.lax.dynamic_slice_in_dim(x, cbc * block, block, 0)
            contrib = jnp.dot(Ab_sh[bi, d], xs, preferred_element_type=jnp.float32)
            acc = acc + jnp.where((cb >= 0) & (cb < nb), contrib, 0.0)
        return acc.astype(x.dtype)

    out = jax.vmap(one)(jnp.arange(nb_local))          # (nb_local, block, k)
    return out.reshape(nb_local * block, x.shape[1])
