"""Mesh-agnostic, atomic checkpointing.

Format: one ``.npy`` per logical tensor (full global shape — leaves are
gathered before save), keyed by its pytree path, plus a ``manifest.json``
with the step, data-pipeline cursor and tree structure.  Restore re-shards
to *any* mesh via device_put with the target NamedSharding — elastic
rescaling and pod-count changes are free (DESIGN.md §3 fault tolerance).

Atomicity: writes land in ``<dir>/.tmp-<step>`` and are os.replace'd into
``<dir>/step_<n>`` only when complete; a crashed save can never shadow the
previous good checkpoint.  ``latest_step`` ignores incomplete directories.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None) -> str:
    """Write checkpoint atomically; returns the final directory."""
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    names = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"t{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        names[key] = fname
    manifest = {"step": step, "tensors": names, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Load into the structure of ``like_tree``; reshard to ``shardings``
    (same structure) if given — the saved mesh is irrelevant."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = manifest["tensors"]

    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = (jax.tree_util.tree_flatten(shardings,
                  is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (key_path, like), shard in zip(flat, shard_flat):
        key = jax.tree_util.keystr(key_path)
        arr = np.load(os.path.join(path, names[key]))
        if shard is not None:
            leaves.append(jax.device_put(arr.astype(like.dtype), shard))
        else:
            leaves.append(jax.numpy.asarray(arr, like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
