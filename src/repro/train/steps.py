"""Step builders: train_step / prefill_step / serve_step as pjit-able pure
functions, plus the abstract-state and input-spec machinery the multi-pod
dry-run lowers against (no allocation — everything ShapeDtypeStruct).

This is the single place where (arch config x input shape x mesh) becomes a
concrete jittable program with in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import attention as A
from repro.models import transformer as T
from repro.optim import (adamw, adafactor, clip_by_global_norm,
                         init_async_grads, push_pop, staleness_beta,
                         warmup_cosine, compression)
from repro.sharding import Partitioner, ShardCtx


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: Any
    async_grads: Any = None       # AsyncGradState when rcfg.async_tau > 0


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def mesh_axes(mesh: Optional[Mesh], fsdp: bool = True, pure_dp: bool = False):
    """(dp_axes, tp_axis, sc) for a production mesh (or CPU fallback)."""
    if mesh is None:
        return (), "model", ShardCtx(tp=1, dp=1, fsdp=fsdp)
    names = mesh.axis_names
    if pure_dp:
        # fold "model" into data parallelism: no TP anywhere; weights are
        # FSDP over "data" only (small-model right-sizing, §Perf q5)
        dp_axes = tuple(names)
        tp = 1
    else:
        dp_axes = tuple(a for a in names if a != "model")
        tp = mesh.shape["model"] if "model" in names else 1
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if pure_dp:
        dp_for_fsdp = mesh.shape["data"]  # shard weights over "data" only
        return dp_axes, "model", ShardCtx(tp=1, dp=dp_for_fsdp, fsdp=fsdp)
    return dp_axes, "model", ShardCtx(tp=tp, dp=dp, fsdp=fsdp)


def make_partitioner(mesh: Optional[Mesh], global_batch: int,
                     fsdp: bool = True, pure_dp: bool = False) -> Partitioner:
    """Batch placement falls back to replication when dp doesn't divide B
    (long_500k's batch of 1).  fsdp=False keeps weights replicated over the
    data axis (no ZeRO gathers); pure_dp=True folds the model axis into
    data parallelism — right for models whose full state fits a chip
    (§Perf q4/q5)."""
    dp_axes, tp_axis, sc = mesh_axes(mesh, fsdp, pure_dp)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a] if mesh else 1
    if dp > 1 and global_batch % dp != 0:
        dp_axes = ()
    return Partitioner(mesh=mesh, dp_axes=dp_axes, tp_axis=tp_axis, sc=sc)


def make_mesh_info(part: Partitioner, cfg: ModelConfig, batch: int, seq_len: int):
    """MeshInfo for sequence-sharded decode attention (None on CPU)."""
    if part.mesh is None:
        return None
    sp = T.seq_shard_axes(cfg, batch, seq_len,
                          part.sc, part.dp_axes or None)
    if not sp:
        return None
    return A.MeshInfo(mesh=part.mesh, dp_axes=part.dp_axes, sp_axes=sp)


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, part: Partitioner):
    """(abstract_batch, batch_pspecs) for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    dp = part.dp
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), i32)}
        specs = {"tokens": P(dp, None)}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), i32)
            specs["labels"] = P(dp, None)
        if cfg.frontend == "audio":
            batch["frames"] = sds((B, cfg.encoder_len, cfg.d_model), dt)
            specs["frames"] = P(dp, None, None)
        if cfg.frontend == "vision":
            batch["patches"] = sds((B, cfg.frontend_len, cfg.d_model), dt)
            specs["patches"] = P(dp, None, None)
        return batch, specs
    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": sds((B, 1), i32), "length": sds((), i32)}
    specs = {"tokens": P(dp, None), "length": P()}
    return batch, specs


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, hidden, labels, part: Partitioner,
            *, chunk: int = 512):
    """Chunked cross-entropy: logits materialize one (B, chunk, V) slab at a
    time (checkpointed, so backward recomputes them) — the full (B, S, V)
    fp32 logits tensor never exists.  labels == -1 are ignored."""
    B, S, D = hidden.shape
    c = min(chunk, S)
    while S % c != 0:
        c -= 1
    nc = S // c

    vocab = T.padded_vocab(cfg, part.sc)

    @jax.checkpoint
    def piece(h, l):
        logits = T.unembed_logits(params, cfg, h).astype(jnp.float32)
        logits = part.logits(logits, vocab)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        mask = l >= 0
        return jnp.where(mask, lse - ll, 0.0).sum(), mask.sum()

    def body(carry, i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        l = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        s, n = piece(h, l)
        return (carry[0] + s, carry[1] + n), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), jnp.arange(nc))
    return total / jnp.maximum(count, 1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_optimizer(rcfg: RunConfig):
    if rcfg.optimizer == "adafactor":
        return adafactor(weight_decay=rcfg.weight_decay)
    state_dtype = jnp.bfloat16 if rcfg.optimizer == "adamw_bf16" else jnp.float32
    return adamw(b1=rcfg.beta1, b2=rcfg.beta2, weight_decay=rcfg.weight_decay,
                 state_dtype=state_dtype)


def make_train_step(cfg: ModelConfig, rcfg: RunConfig, part: Partitioner):
    opt = make_optimizer(rcfg)
    schedule = warmup_cosine(rcfg.learning_rate, rcfg.warmup_steps, rcfg.total_steps)
    beta = staleness_beta(rcfg.async_tau) if (
        rcfg.async_tau > 0 and rcfg.staleness_damping) else 1.0

    def loss_fn(params, batch):
        hidden, _, moe_loss = T.forward(params, cfg, batch, part=part,
                                        remat=rcfg.remat, q_chunk=rcfg.q_chunk,
                                        unroll=rcfg.scan_unroll)
        loss = lm_loss(params, cfg, hidden, batch["labels"], part,
                       chunk=rcfg.loss_chunk)
        total = loss + rcfg.moe_loss_weight * moe_loss
        return total, {"loss": loss, "moe_loss": moe_loss}

    def compute_grads(params, batch):
        if rcfg.microbatches <= 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        mb = rcfg.microbatches
        split = jax.tree.map(lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                             batch)

        def body(acc, micro):
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, micro)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(body, zeros, split)
        grads = jax.tree.map(lambda g: g / mb, grads)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(state: TrainState, batch):
        grads, metrics = compute_grads(state.params, batch)
        if rcfg.grad_compression == "int8":
            grads = compression.roundtrip(grads)   # wire codec for the DCN hop
        async_grads = state.async_grads
        if rcfg.async_tau > 0:
            grads, async_grads = push_pop(async_grads, grads)
        grads, gnorm = clip_by_global_norm(grads, rcfg.grad_clip)
        lr = schedule(state.step) * beta
        params, opt_state = opt.update(grads, state.opt, state.params, lr)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return TrainState(step=state.step + 1, params=params, opt=opt_state,
                          async_grads=async_grads), metrics

    return train_step, opt


def abstract_train_state(cfg: ModelConfig, rcfg: RunConfig, part: Partitioner):
    """(state_shapes, state_pspecs) — no device allocation."""
    opt = make_optimizer(rcfg)
    cap = {}

    def build(key):
        params, specs = T.init_params(cfg, key, part.sc)
        cap["pspecs"] = specs
        st = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                        opt=opt.init(params),
                        async_grads=(init_async_grads(params, rcfg.async_tau)
                                     if rcfg.async_tau > 0 else None))
        return st

    shapes = jax.eval_shape(build, jax.random.key(0))
    pspecs = cap["pspecs"]
    ospecs = opt.state_specs(pspecs, shapes.params)
    aspecs = None
    if rcfg.async_tau > 0:
        from repro.optim import async_state_specs
        aspecs = async_state_specs(pspecs, rcfg.async_tau)
    sspecs = TrainState(step=P(), params=pspecs, opt=ospecs, async_grads=aspecs)
    return shapes, sspecs


def init_train_state(cfg: ModelConfig, rcfg: RunConfig, part: Partitioner,
                     key: jax.Array) -> tuple[TrainState, Any]:
    """Materialized state (CPU tests / real runs).  Returns (state, specs)."""
    opt = make_optimizer(rcfg)
    params, pspecs = T.init_params(cfg, key, part.sc)
    st = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                    opt=opt.init(params),
                    async_grads=(init_async_grads(params, rcfg.async_tau)
                                 if rcfg.async_tau > 0 else None))
    _, sspecs = abstract_train_state(cfg, rcfg, part)
    return st, sspecs


# ---------------------------------------------------------------------------
# Serve (prefill + decode)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, part: Partitioner, *, q_chunk: int = 1024,
                      capacity_len: int = 0, unroll: bool = False):
    def prefill_step(params, batch):
        hidden, cache, _ = T.forward(params, cfg, batch, part=part,
                                     remat="none", q_chunk=q_chunk,
                                     return_cache=True, capacity_len=capacity_len,
                                     unroll=unroll)
        logits = T.unembed_logits(params, cfg, hidden[:, -1:])[:, 0]
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, part: Partitioner, shape: ShapeConfig,
                    *, unroll: bool = False):
    mesh_info = make_mesh_info(part, cfg, shape.global_batch, shape.seq_len)

    def serve_step(params, cache, tokens, length):
        return T.decode_step(params, cfg, cache, tokens, length,
                             part=part, mesh_info=mesh_info, unroll=unroll)
    return serve_step


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, part: Partitioner):
    """(cache_shapes, cache_pspecs) for a decode cell."""
    cap = {}

    def build(_):
        cache, specs = T.init_cache(cfg, shape.global_batch, shape.seq_len,
                                    part.sc, dp=part.dp,
                                    enc_len=cfg.encoder_len)
        cap["specs"] = specs
        return cache

    shapes = jax.eval_shape(build, 0)
    return shapes, cap["specs"]


def param_count(shapes) -> int:
    leaves = jax.tree.leaves(shapes.params if hasattr(shapes, "params") else shapes)
    return sum(int(np_prod(l.shape)) for l in leaves)


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
