"""Training loop: checkpoint/restart, logging, fault-tolerance hooks.

Scale features (DESIGN.md §3):
* restart — on startup the trainer resumes from the latest complete
  checkpoint in ``rcfg.checkpoint_dir`` (atomic manifests mean a crash
  mid-save can never corrupt the resume point);
* elastic rescaling — checkpoints are mesh-agnostic (train/checkpoint.py),
  so the resumed run may use a different mesh/pod count;
* straggler mitigation — ``rcfg.async_tau > 0`` switches to the paper's
  bounded-staleness update (optim/async_update.py): a slow worker's
  gradient lands up to tau steps late instead of stalling the step barrier,
  with the paper's beta~ LR damping keeping the dynamics convergent;
* preemption — ``request_checkpoint()`` (e.g. from a SIGTERM handler)
  forces a save at the next step boundary.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.sharding import Partitioner, spec_tree_to_shardings
from repro.train import checkpoint as ckpt
from repro.train import steps as ST


@dataclass
class Trainer:
    cfg: ModelConfig
    rcfg: RunConfig
    part: Partitioner
    data: SyntheticLM
    log_every: int = 10
    log_fn: Callable[[str], None] = print

    state: Any = None
    step_fn: Any = None
    _want_ckpt: bool = field(default=False, init=False)
    history: list = field(default_factory=list)

    def __post_init__(self):
        step_fn, _ = ST.make_train_step(self.cfg, self.rcfg, self.part)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0,))
        if self.state is None:
            self.state, self.sspecs = ST.init_train_state(
                self.cfg, self.rcfg, self.part, jax.random.key(self.rcfg.seed))
        self._maybe_resume()

    # -- fault tolerance ----------------------------------------------------
    def request_checkpoint(self):
        """Preemption hook: force a save at the next step boundary."""
        self._want_ckpt = True

    def _maybe_resume(self):
        d = self.rcfg.checkpoint_dir
        if not d:
            return
        latest = ckpt.latest_step(d)
        if latest is None:
            return
        shardings = None
        if self.part.mesh is not None:
            shardings = spec_tree_to_shardings(self.part.mesh, self.sspecs)
        self.state, manifest = ckpt.restore(d, latest, self.state,
                                            shardings=shardings)
        self.log_fn(f"[trainer] resumed from step {latest} "
                    f"(data cursor from manifest: {manifest['extra']})")

    def _save(self, step: int):
        if not self.rcfg.checkpoint_dir:
            return
        path = ckpt.save(self.rcfg.checkpoint_dir, step, self.state,
                         extra={"data_step": step})
        self.log_fn(f"[trainer] checkpoint -> {path}")

    # -- loop ----------------------------------------------------------------
    def run(self, num_steps: int):
        start = int(self.state.step)
        t0 = time.time()
        tokens_per_step = self.data.cfg.global_batch * self.data.cfg.seq_len
        for step in range(start, start + num_steps):
            batch = self.data.batch_at(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.step_fn(self.state, batch)
            if (step + 1) % self.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                done = step - start + 1
                m["tokens_per_s"] = tokens_per_step * done / max(dt, 1e-9)
                self.history.append({"step": step + 1, **m})
                self.log_fn(f"[step {step+1}] loss={m['loss']:.4f} "
                            f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                            f"tok/s={m['tokens_per_s']:.0f}")
            ce = self.rcfg.checkpoint_every
            if (ce and (step + 1) % ce == 0) or self._want_ckpt:
                self._save(step + 1)
                self._want_ckpt = False
        return self.history


def make_data(cfg: ModelConfig, seq_len: int, global_batch: int,
              seed: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed,
        frames=cfg.encoder_len if cfg.frontend == "audio" else 0,
        patches=cfg.frontend_len if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model))
