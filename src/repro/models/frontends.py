"""Modality frontend STUBS (per the assignment: ``input_specs()`` provides
precomputed frame/patch embeddings; the conv/ViT towers are out of scope).

Each stub is a learnable linear adapter so the frontend (a) owns parameters
that train, shard and checkpoint like the real thing and (b) marks the
interface where a real tower would plug in.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.sharding import ShardCtx


def init_frontend(ini: L.Initializer, cfg, sc: ShardCtx = ShardCtx()):
    if cfg.frontend == "none":
        return {}, {}
    d = cfg.d_model
    params = {"adapter": ini.dense((d, d)), "adapter_b": ini.zeros((d,))}
    specs = {"adapter": P(sc.data(d), None), "adapter_b": P(None)}
    return params, specs


def apply_frontend(params, feats):
    """feats: (B, T, d) precomputed frame/patch embeddings -> (B, T, d)."""
    return feats @ params["adapter"] + params["adapter_b"]
