"""Shared primitive layers: norms, MLPs, embeddings, RoPE, init helpers.

All layers are pure functions over explicit parameter pytrees.  Every
``init_*`` returns ``(params, specs)`` — two pytrees of identical structure,
where ``specs`` holds a ``jax.sharding.PartitionSpec`` per leaf.  Sharding
convention (DESIGN.md §5):

  * "model"  — tensor-parallel axis (col-parallel out-dim / row-parallel in-dim)
  * "data"   — FSDP axis: weights are additionally sharded along a non-TP dim
               and all-gathered by XLA at use (standard v5e recipe)
  * "pod"    — pure data parallelism across pods (never shards weights)

Stacked (scanned) weights carry a leading ``periods`` dimension that is
never sharded.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import ShardCtx


def truncnorm_init(key, shape, dtype, scale):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


class Initializer:
    """Splits a root key deterministically per named leaf."""

    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.dtype = dtype
        self._i = 0

    def next_key(self) -> jax.Array:
        self._i += 1
        return jax.random.fold_in(self.key, self._i)

    def dense(self, shape, *, fan_in=None):
        fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
        return truncnorm_init(self.next_key(), shape, self.dtype, 1.0 / math.sqrt(fan_in))

    def zeros(self, shape):
        return jnp.zeros(shape, self.dtype)

    def ones(self, shape):
        return jnp.ones(shape, self.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    """RMSNorm with f32 *statistics* but no full-tensor f32 materialization:
    the variance reduction accumulates in f32 via dot_general's accumulator
    (the einsum below), while the normalization multiply stays in x.dtype.
    §Perf q3: on the bf16 training path this removes 2 full-tensor converts
    per call (the dominant `convert` traffic in the HLO byte histogram);
    numerics match the cast-everything form to ~1e-3 relative in bf16 and
    exactly in f32 (tests/test_models.py passes unchanged)."""
    d = x.shape[-1]
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / d
    r = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * r * (1.0 + scale).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(ini: Initializer, d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": ini.zeros((d,))}, {"scale": P(None)}
    return (
        {"scale": ini.ones((d,)), "bias": ini.zeros((d,))},
        {"scale": P(None), "bias": P(None)},
    )


def apply_norm(params, x, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(ini: Initializer, d: int, f: int, kind: str, sc: ShardCtx = ShardCtx()):
    if kind == "swiglu":
        params = {
            "w_gate": ini.dense((d, f)),
            "w_up": ini.dense((d, f)),
            "w_down": ini.dense((f, d)),
        }
        specs = {
            "w_gate": sc.dense_col(d, f),
            "w_up": sc.dense_col(d, f),
            "w_down": sc.dense_row(f, d),
        }
    else:  # gelu (non-gated, starcoder2/whisper style, with biases)
        params = {
            "w_up": ini.dense((d, f)),
            "b_up": ini.zeros((f,)),
            "w_down": ini.dense((f, d)),
            "b_down": ini.zeros((d,)),
        }
        specs = {
            "w_up": sc.dense_col(d, f),
            "b_up": sc.vec(f),
            "w_down": sc.dense_row(f, d),
            "b_down": P(None),
        }
    return params, specs


def apply_mlp(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(ini: Initializer, vocab: int, d: int, tie: bool, sc: ShardCtx = ShardCtx()):
    params = {"embedding": truncnorm_init(ini.next_key(), (vocab, d), ini.dtype, 1.0)}
    specs = {"embedding": P(sc.col(vocab), sc.data(d))}
    if not tie:
        params["unembed"] = ini.dense((d, vocab))
        specs["unembed"] = sc.dense_col(d, vocab)
    return params, specs


def embed_tokens(params, tokens, d_model: int):
    # one-hot matmul keeps the vocab-sharded embedding usable without gather
    # resharding at pod scale; XLA turns this into a sharded gather.
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, tie: bool, scale: float = 1.0):
    if tie:
        return (x * scale) @ params["embedding"].T
    return x @ params["unembed"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def sinusoidal(positions, d: int):
    """Whisper-style sinusoidal embeddings.  positions: (...,) -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
