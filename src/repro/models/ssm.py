"""Mamba (S6) selective-state-space block — jamba's sequence mixer.

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md hardware notes):
the GPU implementation fuses the recurrence into one SRAM-resident kernel;
on TPU we (a) shard d_inner on the model axis, (b) chunk the sequence and
run a *within-chunk associative scan* (log-depth, MXU/VPU friendly) carrying
the (B, d_inner, d_state) boundary state between chunks with an outer
lax.scan.  Materialized working set per chunk is
(B, chunk, d_inner/TP, d_state) — bounded, never the full (B,S,di,N) tensor
that a naive port would allocate.

Decode is the O(1) recurrence with a (d_conv-1)-deep conv ring buffer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.sharding import Partitioner, ShardCtx


def dt_rank(d_model: int) -> int:
    return -(-d_model // 16)


def init_mamba(ini: L.Initializer, cfg, sc: ShardCtx = ShardCtx()):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = dt_rank(d)
    col = sc.col(di)
    params = {
        "in_proj": ini.dense((d, 2 * di)),
        "conv_w": ini.dense((cfg.ssm_conv, di), fan_in=cfg.ssm_conv),
        "conv_b": ini.zeros((di,)),
        "x_proj": ini.dense((di, r + 2 * n)),
        "dt_w": ini.dense((r, di), fan_in=r),
        "dt_b": jnp.log(jnp.expm1(0.01)) * ini.ones((di,)),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(ini.dtype),
        "D": ini.ones((di,)),
        "out_proj": ini.dense((di, d)),
    }
    specs = {
        "in_proj": P(sc.data(d), col),          # column-parallel on 2*di (pairwise)
        "conv_w": P(None, col),
        "conv_b": P(col),
        "x_proj": P(col, None),                  # row-parallel: psum of (r+2n) vec
        "dt_w": P(None, col),
        "dt_b": P(col),
        "A_log": P(col, None),
        "D": P(col),
        "out_proj": P(col, sc.data(d)),          # row-parallel back to d
    }
    return params, specs


def _causal_conv(x, w, b):
    """Depthwise causal conv over S.  x: (B,S,di); w: (K,di)."""
    K = w.shape[0]
    y = jnp.zeros_like(x)
    for k in range(K):
        shift = K - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xs * w[k]
    return y + b


def _ssm_params(params, xc, cfg):
    """Common discretization: returns dA (B,S,di,n), dBx (B,S,di,n), C (B,S,n)."""
    n = cfg.ssm_state
    r = dt_rank(cfg.d_model)
    proj = xc @ params["x_proj"]                               # (B,S,r+2n)
    dt, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_w"] + params["dt_b"]).astype(jnp.float32)  # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (di,n)
    dA = jnp.exp(dt[..., None] * A)                            # (B,S,di,n)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[..., None, :]
    return dA, dBx, Cm.astype(jnp.float32)


def _chunk_scan(dA, dBx, h0):
    """Within-chunk associative scan of h_t = dA_t h_{t-1} + dBx_t.

    dA/dBx: (B, ck, di, n); h0: (B, di, n).  Returns (h (B,ck,di,n), h_last).
    """

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = a_cum * h0[:, None] + b_cum
    return h, h[:, -1]


def mamba_forward(params, x, cfg, *, chunk: int = 256,
                  part: Partitioner = Partitioner(), return_state: bool = False):
    """x: (B,S,d) -> (B,S,d).  Chunked parallel selective scan.

    ``return_state=True`` additionally returns the decode cache after the
    last position (prefill handoff).
    """
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                         # (B,S,di) each
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"], params["conv_b"]))
    ck = min(chunk, S)
    assert S % ck == 0
    nc = S // ck

    dA, dBx, Cm = _ssm_params(params, xc, cfg)

    def body(h, args):
        dA_c, dBx_c, C_c = args                                # (B,ck,di,n),(B,ck,n)
        h_all, h_last = _chunk_scan(dA_c, dBx_c, h)
        y_c = jnp.einsum("bkdn,bkn->bkd", h_all, C_c)
        return h_last, y_c

    resh = lambda t: jnp.moveaxis(t.reshape(B, nc, ck, *t.shape[2:]), 1, 0)
    h0 = jnp.zeros((B, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, (resh(dA), resh(dBx), resh(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = (y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        K = cfg.ssm_conv
        conv = xin[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, di), xin.dtype)
        return out, {"conv": conv, "h": h_last}
    return out


# ---------------------------------------------------------------------------
# Decode (O(1) recurrence)
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_cache_specs(cfg, sc: ShardCtx, dp):
    col = sc.col(cfg.ssm_expand * cfg.d_model)
    return {"conv": P(dp, None, col), "h": P(dp, col, None)}


def mamba_decode(params, x, cache, cfg):
    """x: (B,1,d); cache: {"conv","h"} -> (y (B,1,d), new_cache)."""
    B = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                          # (B,di)
    window = jnp.concatenate([cache["conv"], xin[:, None]], axis=1)  # (B,K,di)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"])
    dA, dBx, Cm = _ssm_params(params, xc[:, None], cfg)         # S = 1
    h = dA[:, 0] * cache["h"] + dBx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = (y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "h": h}
