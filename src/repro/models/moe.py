"""Mixture-of-Experts FFN: token-choice top-k router, capacity-based dense
dispatch (MaxText-style), experts sharded on the model axis (EP ⊂ TP).

Dispatch math: tokens are grouped into fixed-size groups; within each group
every expert accepts at most ``capacity`` tokens (position = running count of
tokens routed to that expert).  Dispatch/combine are dense einsums so the op
lowers to MXU matmuls and shards cleanly:

    x        (N, g, D)        dp-sharded on N (token groups follow batch)
    combine  (N, g, E, C)     routing weights (0 where dropped)
    exp_in   (N, E, C, D)     E sharded on "model"  -> expert-parallel
    exp_out  (N, E, C, D)     local expert FFN, no cross-device traffic
    y        (N, g, D)        contraction over (E, C) => all-reduce("model")

This is the TP-style EP used on TPU pods: activations stay data-parallel and
the only collective is the FFN-output all-reduce that Megatron TP pays
anyway.  The router aux (load-balance loss, drop fraction) is returned for
the trainer.

DESIGN.md §Arch-applicability: a random top-1 expert update *is* a
randomized block-GS step on the expert parameter space — this module is
where the paper's randomized-block-coordinate view meets the model zoo.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.sharding import Partitioner, ShardCtx


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array   # switch-style aux loss (scalar)
    drop_fraction: jax.Array       # fraction of routed slots over capacity


def init_moe(ini: L.Initializer, d: int, mcfg, sc: ShardCtx = ShardCtx()):
    E, F = mcfg.num_experts, mcfg.d_ff
    ecol = "model" if sc.tp > 1 and E % sc.tp == 0 else None
    params = {
        "router": ini.dense((d, E)),
        "w_gate": ini.dense((E, d, F), fan_in=d),
        "w_up": ini.dense((E, d, F), fan_in=d),
        "w_down": ini.dense((E, F, d), fan_in=F),
    }
    specs = {
        "router": P(None, None),
        "w_gate": P(ecol, sc.data(d), None),
        "w_up": P(ecol, sc.data(d), None),
        "w_down": P(ecol, None, sc.data(d)),
    }
    if mcfg.shared_expert:
        sp, ss = L.init_mlp(ini, d, F, "swiglu", sc)
        params["shared"] = sp
        specs["shared"] = ss
    return params, specs


def _capacity(group: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(group * top_k / num_experts * factor)
    return max(4, -(-c // 4) * 4)  # >= 4, rounded up to a multiple of 4


def apply_moe(params, x, mcfg, *, group: int = 512, part: Partitioner = Partitioner()):
    """x: (B, S, D) -> (y, MoEAux)."""
    B, S, D = x.shape
    E, K = mcfg.num_experts, mcfg.top_k
    T = B * S
    g = min(group, T)
    assert T % g == 0, (T, g)
    N = T // g
    C = _capacity(g, K, E, mcfg.capacity_factor)

    xg = x.reshape(N, g, D)
    logits = (xg @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (N,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    if mcfg.router == "sigmoid":          # llama4: top-k on logits, sigmoid gate
        top_vals, top_idx = jax.lax.top_k(logits, K)
        weights = jax.nn.sigmoid(top_vals)
    else:                                  # softmax, renormalized over the top-k
        top_vals, top_idx = jax.lax.top_k(probs, K)
        weights = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    combine = jnp.zeros((N, g, E, C), jnp.float32)
    kept = jnp.zeros((), jnp.float32)
    counts = jnp.zeros((N, 1, E), jnp.float32)   # slots used by earlier k rounds
    for k in range(K):
        mask_e = jax.nn.one_hot(top_idx[..., k], E, dtype=jnp.float32)   # (N,g,E)
        # 0-based slot id = rank among this round's picks + earlier rounds' usage
        pos = (jnp.cumsum(mask_e, axis=1) - 1.0 + counts) * mask_e
        keep = (mask_e > 0) & (pos < C)
        counts = counts + mask_e.sum(axis=1, keepdims=True)
        kept = kept + keep.sum()
        disp = mask_e[..., None] * jax.nn.one_hot(
            jnp.clip(pos, 0, C - 1).astype(jnp.int32), C, dtype=jnp.float32
        )
        disp = jnp.where(keep[..., None], disp, 0.0)
        combine = combine + disp * weights[..., k, None, None]

    dispatch = (combine > 0).astype(x.dtype)
    exp_in = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    exp_in = part.constrain(exp_in, P(part.dp, part.sc.col(E), None, None))
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", exp_in, params["w_gate"]))
    h = h * jnp.einsum("necd,edf->necf", exp_in, params["w_up"])
    exp_out = jnp.einsum("necf,efd->necd", h, params["w_down"])
    y = jnp.einsum("necd,ngec->ngd", exp_out, combine.astype(x.dtype))
    y = y.reshape(B, S, D)
    y = part.hidden(y)

    if mcfg.shared_expert:
        y = y + L.apply_mlp(params["shared"], x, "swiglu")

    # Switch-transformer load-balance loss: E * sum_e f_e * p_e.
    frac = jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32).mean((0, 1))
    mean_p = probs.mean((0, 1))
    lb = E * jnp.sum(frac * mean_p)
    drop = 1.0 - kept / (N * g * K)
    return y, MoEAux(load_balance_loss=lb, drop_fraction=drop)
