"""xLSTM blocks: mLSTM (matrix memory, parallel-trainable) and sLSTM
(scalar memory, inherently sequential) — the xlstm-125m mixers.

mLSTM training uses the stabilized *parallel* form (attention-like D-matrix
of cumulative forget-gate decays), chunked over queries so the materialized
score slab is (B, H, q_chunk, S).  Decode uses the O(1) stabilized matrix
recurrence (C, n, m).  sLSTM has no parallel form — training scans the
sequence (documented in DESIGN.md; xlstm-125m carries 3 such layers).

Head-structured state means TP requires H % tp == 0; xlstm-125m has H = 4,
so on the 16-wide model axis these blocks replicate (DESIGN.md §5 notes the
arch is too small for TP16 — DP carries the parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.sharding import Partitioner, ShardCtx


def _hcol(cfg, sc: ShardCtx):
    return "model" if sc.tp > 1 and cfg.num_heads % sc.tp == 0 else None


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    assert di % H == 0
    return di, H, di // H


def init_mlstm(ini: L.Initializer, cfg, sc: ShardCtx = ShardCtx()):
    d = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    col = _hcol(cfg, sc)
    params = {
        "w_up": ini.dense((d, 2 * di)),
        "conv_w": ini.dense((4, di), fan_in=4),
        "conv_b": ini.zeros((di,)),
        "wq": ini.dense((di, di)),
        "wk": ini.dense((di, di)),
        "wv": ini.dense((di, di)),
        "w_i": ini.dense((di, H)),
        "b_i": ini.zeros((H,)),
        "w_f": ini.dense((di, H)),
        "b_f": 3.0 * ini.ones((H,)),     # forget bias ~ sigmoid ≈ 0.95
        "h_norm": ini.zeros((di,)),
        "w_down": ini.dense((di, d)),
    }
    specs = {
        "w_up": P(sc.data(d), col),
        "conv_w": P(None, col),
        "conv_b": P(col),
        "wq": P(col, col), "wk": P(col, col), "wv": P(col, col),
        "w_i": P(col, None), "b_i": P(None),
        "w_f": P(col, None), "b_f": P(None),
        "h_norm": P(col),
        "w_down": P(col, sc.data(d)),
    }
    return params, specs


def _conv4(x, w, b):
    K = w.shape[0]
    y = jnp.zeros_like(x)
    for k in range(K):
        shift = K - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xs * w[k]
    return y + b


def _mlstm_from_parts(params, xm, xc, cfg):
    """(xm, xc): (B,S,di) -> q,k,v (B,S,H,dh) f32; log_i, log_f (B,S,H) f32."""
    di, H, dh = mlstm_dims(cfg)
    B, S = xm.shape[:2]
    q = (xc @ params["wq"]).reshape(B, S, H, dh).astype(jnp.float32)
    k = (xc @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32) / (dh ** 0.5)
    v = (xm @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    log_i = (xm @ params["w_i"] + params["b_i"]).astype(jnp.float32)          # pre-act
    log_f = jax.nn.log_sigmoid((xm @ params["w_f"] + params["b_f"]).astype(jnp.float32))
    return q, k, v, log_i, log_f


def _mlstm_qkv_gates(params, x, cfg):
    """x: (B,S,d) -> qkv/gates + z (B,S,di) + xm (for the conv cache)."""
    up = x @ params["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_conv4(xm, params["conv_w"], params["conv_b"]))
    q, k, v, log_i, log_f = _mlstm_from_parts(params, xm, xc, cfg)
    return q, k, v, log_i, log_f, z, xm


def mlstm_forward(params, x, cfg, *, q_chunk: int = 512,
                  part: Partitioner = Partitioner(), return_state: bool = False):
    """Stabilized parallel mLSTM.  x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, H, dh = mlstm_dims(cfg)
    q, k, v, log_i, log_f, z, xm = _mlstm_qkv_gates(params, x, cfg)
    cum = jnp.cumsum(log_f, axis=1)                           # (B,S,H)

    qc = min(q_chunk, S)
    assert S % qc == 0

    def chunk_fn(_, ci):
        q0 = ci * qc
        qi = jax.lax.dynamic_slice_in_dim(q, q0, qc, 1)        # (B,qc,H,dh)
        cum_i = jax.lax.dynamic_slice_in_dim(cum, q0, qc, 1)   # (B,qc,H)
        # log decay matrix: cum_i - cum_j + log_i_j, causal.
        logD = (cum_i[:, :, None] - cum[:, None, :] + log_i[:, None, :, :])  # (B,qc,S,H)
        pos_q = q0 + jnp.arange(qc)
        causal = pos_q[:, None] >= jnp.arange(S)[None, :]
        logD = jnp.where(causal[None, :, :, None], logD, -jnp.inf)
        m = jnp.max(logD, axis=2)                              # (B,qc,H)
        Dmat = jnp.exp(logD - m[:, :, None])                   # (B,qc,S,H)
        scores = jnp.einsum("bqhd,bshd->bqsh", qi, k) * Dmat
        num = jnp.einsum("bqsh,bshd->bqhd", scores, v)
        denom = jnp.maximum(jnp.abs(scores.sum(2)), jnp.exp(-m))  # (B,qc,H)
        return None, num / denom[..., None]

    n_chunks = S // qc
    if n_chunks == 1:
        _, h = chunk_fn(None, jnp.int32(0))
        h = h[None]
    else:
        _, h = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
    h = jnp.moveaxis(h, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = L.rmsnorm(h.reshape(B, S, H, dh), params["h_norm"].reshape(H, dh)).reshape(B, S, di)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    if return_state:
        # Closed-form final recurrent state (prefill handoff): weights
        # w_j = exp(cum_S - cum_j + log_i_j - m_S), m_S = max_j (.).
        logw = cum[:, -1:, :] - cum + log_i                    # (B,S,H)
        m_S = jnp.max(logw, axis=1)                            # (B,H)
        w = jnp.exp(logw - m_S[:, None])
        C = jnp.einsum("bsh,bshv,bshk->bhvk", w, v, k)
        n = jnp.einsum("bsh,bshk->bhk", w, k)
        return out, {"C": C, "n": n, "m": m_S, "conv": xm[:, -3:]}
    return out


def init_mlstm_cache(cfg, batch: int, dtype):
    di, H, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype),   # last 3 pre-conv xm values
    }


def mlstm_cache_specs(cfg, sc: ShardCtx, dp):
    col = _hcol(cfg, sc)
    return {"C": P(dp, col, None, None), "n": P(dp, col, None), "m": P(dp, col),
            "conv": P(dp, None, col)}


def mlstm_decode(params, x, cache, cfg):
    """One-token stabilized recurrence.  x: (B,1,d)."""
    B = x.shape[0]
    di, H, dh = mlstm_dims(cfg)
    up = x[:, 0] @ params["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)                          # (B,di)
    window = jnp.concatenate([cache["conv"].astype(xm.dtype), xm[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, params["conv_w"])
                     + params["conv_b"])
    q, k, v, log_i, log_f = _mlstm_from_parts(
        params, xm[:, None], xc[:, None], cfg)
    z = z[:, None]                                             # (B,1,di)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                        # (B,H,dh)
    log_i, log_f = log_i[:, 0], log_f[:, 0]                    # (B,H)
    m_new = jnp.maximum(log_f + cache["m"], log_i)
    f_eff = jnp.exp(log_f + cache["m"] - m_new)
    i_eff = jnp.exp(log_i - m_new)
    C = f_eff[..., None, None] * cache["C"] + i_eff[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )                                                          # (B,H,dh_v,dh_k)
    n = f_eff[..., None] * cache["n"] + i_eff[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = (num / denom[..., None]).reshape(B, 1, di).astype(x.dtype)
    h = L.rmsnorm(h.reshape(B, 1, H, dh), params["h_norm"].reshape(H, dh)).reshape(B, 1, di)
    y = (h * jax.nn.silu(z)) @ params["w_down"]
    return y, {"C": C, "n": n, "m": m_new, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_dims(cfg):
    H = cfg.num_heads
    assert cfg.d_model % H == 0
    return H, cfg.d_model // H


def init_slstm(ini: L.Initializer, cfg, sc: ShardCtx = ShardCtx()):
    d = cfg.d_model
    H, dh = slstm_dims(cfg)
    col = _hcol(cfg, sc)
    f34 = -(-int(8 * d / 3) // 64) * 64   # GLU up width, 4/3 * 2 * d rounded
    params = {
        "w": ini.dense((4, d, d)),                 # z, i, f, o input weights
        "r": ini.dense((4, H, dh, dh), fan_in=dh),  # block-diagonal recurrent
        "b": ini.zeros((4, d)),
        "h_norm": ini.zeros((d,)),
        "up": ini.dense((d, f34)),
        "down": ini.dense((f34 // 2, d), fan_in=f34 // 2),
    }
    specs = {
        "w": P(None, sc.data(d), None),
        "r": P(None, col, None, None),
        "b": P(None, None),
        "h_norm": P(None),
        "up": P(sc.data(d), sc.col(f34)),
        "down": P(sc.col(f34 // 2), sc.data(d)),
    }
    return params, specs


def _slstm_step(params, x_t, state, H, dh):
    """x_t: (B,d); state: (c, n, h, m) each (B,H,dh) / (B,H) for m."""
    c, n, h, m = state
    B = x_t.shape[0]
    wx = jnp.einsum("bd,gde->gbe", x_t.astype(jnp.float32), params["w"].astype(jnp.float32))
    rh = jnp.einsum("bhe,ghef->gbhf", h, params["r"].astype(jnp.float32))
    pre = wx.reshape(4, B, H, dh) + rh + params["b"].astype(jnp.float32).reshape(4, 1, H, dh)
    z_t = jnp.tanh(pre[0])
    log_i = pre[1].mean(-1)                     # per-head scalar gates
    log_f = jax.nn.log_sigmoid(pre[2].mean(-1))
    o_t = jax.nn.sigmoid(pre[3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_eff = jnp.exp(log_i - m_new)[..., None]
    f_eff = jnp.exp(log_f + m - m_new)[..., None]
    c = f_eff * c + i_eff * z_t
    n = f_eff * n + i_eff
    h = o_t * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new)


def slstm_forward(params, x, cfg, *, part: Partitioner = Partitioner(),
                  return_state: bool = False):
    """x: (B,S,d) -> (B,S,d).  Sequential scan (no parallel form exists)."""
    B, S, d = x.shape
    H, dh = slstm_dims(cfg)
    state0 = (
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), -jnp.inf, jnp.float32),
    )

    def step(state, x_t):
        state = _slstm_step(params, x_t, state, H, dh)
        return state, state[2]

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = L.rmsnorm(h, params["h_norm"])
    u = h @ params["up"]
    a, g = jnp.split(u, 2, axis=-1)
    out = (a * jax.nn.gelu(g)) @ params["down"]
    if return_state:
        c, n, hh, m = state
        return out, {"c": c, "n": n, "h": hh, "m": m}
    return out


def init_slstm_cache(cfg, batch: int, dtype):
    H, dh = slstm_dims(cfg)
    del dtype
    return {
        "c": jnp.zeros((batch, H, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "h": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def slstm_cache_specs(cfg, sc: ShardCtx, dp):
    col = _hcol(cfg, sc)
    return {"c": P(dp, col, None), "n": P(dp, col, None),
            "h": P(dp, col, None), "m": P(dp, col)}


def slstm_decode(params, x, cache, cfg):
    H, dh = slstm_dims(cfg)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state = _slstm_step(params, x[:, 0], state, H, dh)
    c, n, h, m = state
    B, d = x.shape[0], x.shape[2]
    hx = L.rmsnorm(h.reshape(B, 1, d).astype(x.dtype), params["h_norm"])
    u = hx @ params["up"]
    a, g = jnp.split(u, 2, axis=-1)
    y = (a * jax.nn.gelu(g)) @ params["down"]
    return y, {"c": c, "n": n, "h": h, "m": m}
