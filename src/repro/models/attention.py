"""GQA attention: training/prefill (chunked-query flash-style) and decode
(ring-buffer caches, sequence-sharded long-context path).

Variants (selected per layer by ``kind``):
  attn     — global causal, RoPE (theta_global if configured, else theta)
  local    — sliding-window causal (gemma3), window-limited KV
  chunked  — chunk-local causal (llama4), chunk-limited KV
  nope     — global causal, no positional encoding (llama4 global layers)
  enc      — bidirectional (whisper encoder)
  cross    — encoder-decoder cross attention (no causal mask, no RoPE)

Decode caches are ring buffers sized to what the variant actually needs:
full S for global layers, ``window`` for local, ``chunk_size`` for chunked —
this is what makes long_500k affordable for gemma3/llama4 (DESIGN.md §4).
Global-layer caches can be sequence-sharded across mesh axes; the partial
softmax results are merged with log-sum-exp weights via psum/pmax
(`sharded_decode_attention`), the same math as kernels/decode_attention.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models import layers as L
from repro.sharding import ShardCtx


SEQ_SHARD_MIN = 8192   # decode caches at least this long get sequence-sharded


class MeshInfo(NamedTuple):
    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...]      # batch axes
    sp_axes: tuple[str, ...]      # sequence axes for long-context decode


def init_attention(ini: L.Initializer, cfg, sc: ShardCtx = ShardCtx(), *, cross: bool = False):
    D, H, KV, Hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    params = {
        "wq": ini.dense((D, H * Hd)),
        "wk": ini.dense((D, KV * Hd)),
        "wv": ini.dense((D, KV * Hd)),
        "wo": ini.dense((H * Hd, D), fan_in=H * Hd),
    }
    if sc.attn_tp(H, KV):
        # Megatron TP: heads column-parallel; KV heads sharded only when the
        # KV count itself divides the axis (GQA with few KV heads replicates).
        kvc = sc.kv_col(KV, Hd)
        specs = {
            "wq": P(sc.data(D), sc.col(H * Hd)),
            "wk": P(sc.data(D), "model" if kvc else None),
            "wv": P(sc.data(D), "model" if kvc else None),
            "wo": P(sc.col(H * Hd), sc.data(D)),
        }
        bq_spec = sc.vec(H * Hd)
        bkv_spec = P("model" if kvc else None)
    else:
        # Sequence-parallel attention (heads not divisible by the model axis):
        # weights replicated on "model", FSDP on "data"; the S dim of the
        # activations carries the model-axis sharding instead (transformer.py).
        specs = {
            "wq": sc.replicated_fsdp(D),
            "wk": sc.replicated_fsdp(D),
            "wv": sc.replicated_fsdp(D),
            "wo": sc.replicated_fsdp(H * Hd),
        }
        bq_spec = P(None)
        bkv_spec = P(None)
    if cfg.qkv_bias:
        params.update({"bq": ini.zeros((H * Hd,)), "bk": ini.zeros((KV * Hd,)),
                       "bv": ini.zeros((KV * Hd,))})
        specs.update({"bq": bq_spec, "bk": bkv_spec, "bv": bkv_spec})
    if cfg.qk_norm:
        params.update({"q_norm": ini.zeros((Hd,)), "k_norm": ini.zeros((Hd,))})
        specs.update({"q_norm": P(None), "k_norm": P(None)})
    return params, specs


def _theta(cfg, kind: str) -> float:
    if kind == "attn" and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _project_qkv(params, x, kv_x, cfg):
    B = x.shape[0]
    H, KV, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, -1, H, Hd)
    k = k.reshape(B, -1, KV, Hd)
    v = v.reshape(B, -1, KV, Hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, params["q_norm"])
        k = L.rmsnorm(k, params["k_norm"])
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B, qc, H, Hd); k/v: (B, Sk, KV, Hd); mask: (B or 1, qc, Sk)."""
    B, qc, H, Hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, qc, KV, G, Hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / (Hd ** 0.5)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, qc, H * Hd)


def attend_full(params, x, cfg, kind: str, *, kv_x=None, q_chunk: int = 1024):
    """Full-sequence attention (train / prefill).  Returns (y, (k, v)) where
    (k, v) is the post-RoPE cacheable KV for the whole sequence."""
    B, S, _ = x.shape
    cross = kind == "cross"
    kv_in = kv_x if cross else x
    q, k, v = _project_qkv(params, x, kv_in, cfg)
    Sk = k.shape[1]

    if kind not in ("nope", "cross", "enc") and cfg.rope:
        theta = _theta(cfg, kind)
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        q = L.apply_rope(q, pos, theta)
        k = L.apply_rope(k, jnp.arange(Sk, dtype=jnp.int32)[None], theta)

    qc = min(q_chunk, S)
    while S % qc:            # largest chunk <= q_chunk dividing S (e.g. 1500 -> 750)
        qc -= 1
    n_chunks = S // qc

    def chunk_fn(_, ci):
        q0 = ci * qc
        qi = jax.lax.dynamic_slice_in_dim(q, q0, qc, axis=1)
        qpos = q0 + jnp.arange(qc)
        kpos = jnp.arange(Sk)
        if kind in ("enc", "cross"):
            mask = jnp.ones((1, qc, Sk), bool)
        else:
            mask = qpos[:, None] >= kpos[None, :]
            if kind == "local" and cfg.window:
                mask &= qpos[:, None] - kpos[None, :] < cfg.window
            elif kind == "chunked" and cfg.chunk_size:
                mask &= (qpos[:, None] // cfg.chunk_size) == (kpos[None, :] // cfg.chunk_size)
            mask = mask[None]
        return None, _sdpa(qi, k, v, mask)

    if n_chunks == 1:
        _, y = chunk_fn(None, jnp.int32(0))
        y = y[None]
    else:
        _, y = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, -1)
    return y @ params["wo"], (k, v)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_capacity(cfg, kind: str, seq_len: int) -> int:
    if kind == "local" and cfg.window:
        return min(cfg.window, seq_len)
    if kind == "chunked" and cfg.chunk_size:
        return min(cfg.chunk_size, seq_len)
    return seq_len


def ring_from_full(k, capacity: int):
    """Pack full-sequence KV (B, S, KV, Hd) into a ring buffer of the given
    capacity: slot(p) = p % capacity for the last ``capacity`` positions;
    shorter sequences are right-padded (slots to be filled by decode)."""
    import numpy as np
    B, S = k.shape[:2]
    if S <= capacity:
        return jnp.pad(k, ((0, 0), (0, capacity - S)) + ((0, 0),) * (k.ndim - 2))
    pos = np.arange(S - capacity, S)
    perm = np.empty(capacity, np.int64)
    perm[pos % capacity] = pos
    return k[:, perm]


def _decode_math(q, kc, vc, valid, pos_offset=None):
    """Single-token attention returning (acc, m, l) for LSE merging.
    q: (B, H, Hd); kc/vc: (B, C, KV, Hd); valid: (B, C) bool."""
    B, H, Hd = q.shape
    KV = kc.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, kc).astype(jnp.float32) / (Hd ** 0.5)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(jnp.isneginf(m)[..., None], 0.0, p)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vc.dtype), vc).astype(jnp.float32)
    return acc, m, l


def sharded_decode_attention(q, kc, vc, length, mesh_info: MeshInfo):
    """Decode attention with the KV cache sequence-sharded over sp_axes.

    Each shard computes a partial (acc, m, l) over its local slice of the
    cache; partials are merged with log-sum-exp weights via pmax/psum —
    collective volume is O(B*H*Hd), independent of S.
    """
    mesh, dp, sp = mesh_info
    n_sp = 1
    for a in sp:
        n_sp *= mesh.shape[a]
    C = kc.shape[1]
    C_local = C // n_sp

    def f(q, kc, vc, length):
        idx = jnp.int32(0)
        for a in sp:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * C_local
        kpos = offset + jnp.arange(C_local)
        valid = (kpos[None, :] < length)
        acc, m, l = _decode_math(q, kc, vc, valid)
        M = jax.lax.pmax(m, sp)
        w = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - M))
        l_g = jax.lax.psum(l * w, sp)
        acc_g = jax.lax.psum(acc * w[..., None], sp)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(q.shape).astype(q.dtype)

    dp_entry = tuple(dp) if dp else None
    sp_entry = tuple(sp)
    return shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P(dp_entry, None, None),
            P(dp_entry, sp_entry, None, None),
            P(dp_entry, sp_entry, None, None),
            P(),
        ),
        out_specs=P(dp_entry, None, None),
        check_vma=False,
    )(q, kc, vc, length)


def attend_decode(params, x, cache, length, cfg, kind: str,
                  mesh_info: Optional[MeshInfo] = None):
    """One-token decode.  x: (B, 1, D); cache: {"k","v"} ring buffers of
    capacity C; length: scalar count of tokens already in context.
    Returns (y (B,1,D), new_cache)."""
    B = x.shape[0]
    H, KV, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(params, x, x, cfg)   # (B,1,H,Hd), (B,1,KV,Hd)
    if kind not in ("nope", "cross") and cfg.rope:
        theta = _theta(cfg, kind)
        pos = length[None, None] if length.ndim == 0 else length[:, None]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(1, 1), (1, 1))
        q = L.apply_rope(q, pos, theta)
        k = L.apply_rope(k, pos, theta)

    C = cache["k"].shape[1]
    slot = jnp.mod(length, C)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_cache = {"k": kc, "v": vc}
    q1 = q[:, 0]

    # Absolute position held by each ring slot after this write:
    # key_pos(s) = p - ((p - s) mod C), where p = length (the new token).
    slots = jnp.arange(C)
    key_pos = length - jnp.mod(length - slots, C)
    if kind == "chunked" and cfg.chunk_size:
        lo = (length // cfg.chunk_size) * cfg.chunk_size
    elif kind == "local" and cfg.window:
        lo = jnp.maximum(0, length - cfg.window + 1)
    else:
        lo = 0

    if mesh_info is not None and kind in ("attn", "nope") and C >= SEQ_SHARD_MIN:
        out = sharded_decode_attention(q1, kc, vc, length + 1, mesh_info)
    else:
        valid = (key_pos >= lo) & (key_pos <= length) & (key_pos >= 0)
        acc, m, l = _decode_math(q1, kc, vc, valid[None])
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, H * Hd).astype(x.dtype)

    y = out.reshape(B, 1, H * Hd) @ params["wo"]
    return y, new_cache


def attend_cross_decode(params, x, cross_cache, cfg):
    """Cross-attention during decode: q from x, KV precomputed from the
    encoder output at prefill (no cache update).  x: (B, 1, D)."""
    B = x.shape[0]
    H, Hd = cfg.num_heads, cfg.head_dim
    q, _, _ = _project_qkv(params, x, x, cfg)
    kc, vc = cross_cache["k"], cross_cache["v"]       # (B, T_enc, KV, Hd)
    valid = jnp.ones((B, kc.shape[1]), bool)
    acc, m, l = _decode_math(q[:, 0], kc, vc, valid)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, 1, H * Hd).astype(x.dtype)
    return out @ params["wo"]
