"""Model assembly: pre-norm residual blocks, scan-over-layers stacking,
encoder-decoder support, prefill-cache handoff and single-token decode.

Layer layout (configs/base.py): ``cfg.layer_pattern`` is one *period*;
weights for each pattern position are stacked over ``scan_periods`` and the
stack is consumed by one ``lax.scan`` (O(1) HLO size for 88-layer granite).
Layers past the last full period ("tail") are unrolled.

Params tree:
  embed / frontend? / encoder? / blocks (tuple per pattern position, leaves
  stacked over periods) / tail (tuple per tail layer) / final_norm

Cache tree mirrors blocks/tail; attention caches are ring buffers sized by
``cache_capacity`` (window for 'local', chunk for 'chunked', S for global),
SSM/xLSTM caches are O(1) recurrent states.  Global-attention caches with
capacity > SEQ_SHARD_MIN are sequence-sharded over the mesh and decoded via
LSE-merge (attention.sharded_decode_attention).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import frontends as F
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.sharding import Partitioner, ShardCtx

SEQ_SHARD_MIN = A.SEQ_SHARD_MIN   # decode caches >= this get sequence-sharded

ATTN_KINDS = ("attn", "local", "chunked", "nope", "enc")
MIXER_FFN_NONE = ("mlstm", "slstm")   # blocks that carry their own FFN


class BlockKind(NamedTuple):
    mixer: str    # attn | local | chunked | nope | mamba | mlstm | slstm
    ffn: str      # dense | moe | none


def block_kinds(cfg) -> tuple[BlockKind, ...]:
    """Per-pattern-position block structure (one period)."""
    out = []
    for pos, mixer in enumerate(cfg.layer_pattern):
        if mixer in MIXER_FFN_NONE:
            ffn = "none"
        elif cfg.moe is not None and pos % cfg.moe.every == cfg.moe.every - 1:
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        out.append(BlockKind(mixer, ffn))
    if cfg.moe is not None:
        assert len(cfg.layer_pattern) % cfg.moe.every == 0, (
            "MoE periodicity must divide the layer pattern")
    return tuple(out)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(ini: L.Initializer, cfg, bk: BlockKind, sc: ShardCtx,
                *, cross: bool = False):
    params: dict = {}
    specs: dict = {}
    params["norm1"], specs["norm1"] = L.init_norm(ini, cfg.d_model, cfg.norm)
    if bk.mixer in ATTN_KINDS:
        params["mixer"], specs["mixer"] = A.init_attention(ini, cfg, sc)
    elif bk.mixer == "mamba":
        params["mixer"], specs["mixer"] = S.init_mamba(ini, cfg, sc)
    elif bk.mixer == "mlstm":
        params["mixer"], specs["mixer"] = X.init_mlstm(ini, cfg, sc)
    elif bk.mixer == "slstm":
        params["mixer"], specs["mixer"] = X.init_slstm(ini, cfg, sc)
    else:
        raise ValueError(bk.mixer)
    if cfg.post_norm:
        params["post_norm1"], specs["post_norm1"] = L.init_norm(ini, cfg.d_model, cfg.norm)
    if cross:
        params["norm_cross"], specs["norm_cross"] = L.init_norm(ini, cfg.d_model, cfg.norm)
        params["cross"], specs["cross"] = A.init_attention(ini, cfg, sc, cross=True)
    if bk.ffn != "none":
        params["norm2"], specs["norm2"] = L.init_norm(ini, cfg.d_model, cfg.norm)
        if bk.ffn == "dense":
            params["ffn"], specs["ffn"] = L.init_mlp(ini, cfg.d_model, cfg.d_ff, cfg.mlp, sc)
        else:
            params["ffn"], specs["ffn"] = M.init_moe(ini, cfg.d_model, cfg.moe, sc)
        if cfg.post_norm:
            params["post_norm2"], specs["post_norm2"] = L.init_norm(ini, cfg.d_model, cfg.norm)
    return params, specs


def _stack_specs(specs):
    return jax.tree.map(lambda s: P(None, *s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def init_params(cfg, key: jax.Array, sc: ShardCtx = ShardCtx()):
    """Returns (params, specs): two pytrees of identical structure."""
    dtype = jnp.dtype(cfg.dtype)
    ini = L.Initializer(key, dtype)
    params: dict = {}
    specs: dict = {}

    params["embed"], specs["embed"] = L.init_embed(
        ini, padded_vocab(cfg, sc), cfg.d_model, cfg.tie_embeddings, sc)
    fp, fs = F.init_frontend(ini, cfg, sc)
    if fp:
        params["frontend"], specs["frontend"] = fp, fs

    kinds = block_kinds(cfg)
    periods = cfg.scan_periods
    cross = cfg.encoder_layers > 0

    blocks, bspecs = [], []
    for pos, bk in enumerate(kinds):
        cap = {}

        def stacked_init(k, _bk=bk, _cap=cap):
            p, s = _init_block(L.Initializer(k, dtype), cfg, _bk, sc, cross=cross)
            _cap["specs"] = s   # static python, identical across the vmap
            return p

        stacked = jax.vmap(stacked_init)(jax.random.split(ini.next_key(), periods))
        blocks.append(stacked)
        bspecs.append(_stack_specs(cap["specs"]))
    params["blocks"] = tuple(blocks)
    specs["blocks"] = tuple(bspecs)

    tails, tspecs = [], []
    for bk in tail_kinds_of(cfg):
        p, s = _init_block(L.Initializer(ini.next_key(), dtype), cfg, bk, sc, cross=cross)
        tails.append(p)
        tspecs.append(s)
    params["tail"] = tuple(tails)
    specs["tail"] = tuple(tspecs)

    params["final_norm"], specs["final_norm"] = L.init_norm(ini, cfg.d_model, cfg.norm)

    if cfg.encoder_layers > 0:
        enc_blocks, enc_specs = [], []
        for _ in range(cfg.encoder_layers):
            p, s = _init_block(L.Initializer(ini.next_key(), dtype), cfg,
                               BlockKind("enc", "dense"), sc)
            enc_blocks.append(p)
            enc_specs.append(s)
        params["encoder"] = {"blocks": tuple(enc_blocks)}
        specs["encoder"] = {"blocks": tuple(enc_specs)}
        params["encoder"]["final_norm"], specs["encoder"]["final_norm"] = (
            L.init_norm(ini, cfg.d_model, cfg.norm))
    return params, specs


def padded_vocab(cfg, sc: ShardCtx) -> int:
    """Vocab rounded up so the model axis divides it (whisper's 51865)."""
    m = max(sc.tp, 1) * 128
    return -(-cfg.vocab_size // m) * m


def tail_kinds_of(cfg) -> tuple[BlockKind, ...]:
    kinds = block_kinds(cfg)
    n_tail = cfg.num_layers - cfg.scan_periods * len(cfg.layer_pattern)
    return kinds[:n_tail]


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_mixer_full(params, x, cfg, bk: BlockKind, part, q_chunk,
                      return_cache, capacity_len):
    if bk.mixer in ATTN_KINDS:
        y, (k, v) = A.attend_full(params, x, cfg, bk.mixer, q_chunk=q_chunk)
        if not return_cache:
            return y, None
        C = A.cache_capacity(cfg, bk.mixer, capacity_len)
        return y, {"k": A.ring_from_full(k, C), "v": A.ring_from_full(v, C)}
    if bk.mixer == "mamba":
        return (S.mamba_forward(params, x, cfg, part=part, return_state=return_cache)
                if return_cache else (S.mamba_forward(params, x, cfg, part=part), None))
    if bk.mixer == "mlstm":
        return (X.mlstm_forward(params, x, cfg, part=part, return_state=return_cache)
                if return_cache else (X.mlstm_forward(params, x, cfg, part=part), None))
    if bk.mixer == "slstm":
        return (X.slstm_forward(params, x, cfg, part=part, return_state=return_cache)
                if return_cache else (X.slstm_forward(params, x, cfg, part=part), None))
    raise ValueError(bk.mixer)


def _apply_block_full(params, x, cfg, bk: BlockKind, part: Partitioner,
                      *, enc_out=None, q_chunk=1024, return_cache=False,
                      capacity_len=None):
    """One block, full-sequence.  Returns (x, (cache, moe_aux))."""
    capacity_len = capacity_len or x.shape[1]
    sp_attn = (bk.mixer in ATTN_KINDS and part.mesh is not None
               and not part.sc.attn_tp(cfg.num_heads, cfg.num_kv_heads))
    h = L.apply_norm(params["norm1"], x, cfg.norm)
    if sp_attn:
        h = part.hidden_sp(h)   # sequence-parallel attention region
    y, cache = _apply_mixer_full(params["mixer"], h, cfg, bk, part, q_chunk,
                                 return_cache, capacity_len)
    if cfg.post_norm:
        y = L.apply_norm(params["post_norm1"], y, cfg.norm)
    x = x + y
    x = part.hidden(x)

    if "cross" in params:
        h = L.apply_norm(params["norm_cross"], x, cfg.norm)
        y, (ck, cv) = A.attend_full(params["cross"], h, cfg, "cross", kv_x=enc_out,
                                    q_chunk=q_chunk)
        x = x + y
        if return_cache:
            cache = {"self": cache, "cross": {"k": ck, "v": cv}}

    aux = None
    if bk.ffn != "none":
        h = L.apply_norm(params["norm2"], x, cfg.norm)
        if bk.ffn == "dense":
            y = L.apply_mlp(params["ffn"], h, cfg.mlp)
            y = part.hidden(y)
        else:
            y, aux = M.apply_moe(params["ffn"], h, cfg.moe, group=cfg.moe_group,
                                 part=part)
        if cfg.post_norm:
            y = L.apply_norm(params["post_norm2"], y, cfg.norm)
        x = x + y
        x = part.hidden(x)
    return x, (cache, aux)


def encode(params, cfg, frames, part: Partitioner = Partitioner()):
    """Whisper-style encoder over precomputed (stub) frames (B, T, D)."""
    x = F.apply_frontend(params["frontend"], frames).astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])
    x = x + L.sinusoidal(pos, cfg.d_model)[None].astype(x.dtype)
    for bp in params["encoder"]["blocks"]:
        x, _ = _apply_block_full(bp, x, cfg, BlockKind("enc", "dense"), part)
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


def embed_input(params, cfg, batch, part: Partitioner = Partitioner()):
    """Token embedding + modality splice (vlm) + absolute positions."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg.d_model)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    if cfg.frontend == "vision" and "patches" in batch:
        pe = F.apply_frontend(params["frontend"], batch["patches"]).astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    if not cfg.rope:
        pos = batch.get("positions", jnp.arange(x.shape[1]))
        x = x + L.sinusoidal(pos, cfg.d_model)[None].astype(x.dtype)
    return part.hidden(x)


def forward(params, cfg, batch, *, part: Partitioner = Partitioner(),
            remat: str = "block", q_chunk: int = 1024, return_cache: bool = False,
            capacity_len: int = 0, unroll: bool = False):
    """Full-sequence forward.  Returns (hidden (B,S,D), caches|None, moe_aux).

    batch: {"tokens": (B,S) int32, optional "frames" (B,T,D), "patches"}.
    ``capacity_len``: decode-cache sizing horizon (prefill for a longer
    conversation allocates rings for the full context, not just the prompt).
    """
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = encode(params, cfg, batch["frames"], part)

    x = embed_input(params, cfg, batch, part)
    kinds = block_kinds(cfg)
    capacity_len = capacity_len or batch["tokens"].shape[1]

    def period_fn(x, block_params):
        caches, auxes = [], []
        for pos, bk in enumerate(kinds):
            x, (c, a) = _apply_block_full(block_params[pos], x, cfg, bk, part,
                                          enc_out=enc_out, q_chunk=q_chunk,
                                          return_cache=return_cache,
                                          capacity_len=capacity_len)
            caches.append(c)
            auxes.append(a)
        aux = [a.load_balance_loss for a in auxes if a is not None]
        return x, (tuple(caches), jnp.stack(aux).sum() if aux else jnp.zeros(()))

    if remat == "block":      # full recompute (lowest memory)
        period_fn = jax.checkpoint(period_fn,
                                   policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":     # save matmul outputs, recompute elementwise
        period_fn = jax.checkpoint(period_fn,
                                   policy=jax.checkpoint_policies.dots_saveable)

    x, (caches, lb) = jax.lax.scan(period_fn, x, params["blocks"],
                                   unroll=cfg.scan_periods if unroll else 1)
    moe_loss = lb.sum()

    tail_caches = []
    for tp, bk in zip(params["tail"], tail_kinds_of(cfg)):
        x, (c, a) = _apply_block_full(tp, x, cfg, bk, part, enc_out=enc_out,
                                      q_chunk=q_chunk, return_cache=return_cache,
                                      capacity_len=capacity_len)
        tail_caches.append(c)
        if a is not None:
            moe_loss = moe_loss + a.load_balance_loss

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    cache = None
    if return_cache:
        cache = {"blocks": caches, "tail": tuple(tail_caches)}
        if enc_out is not None:
            cache["enc_out"] = enc_out
    return x, cache, moe_loss


def unembed_logits(params, cfg, hidden):
    return L.unembed(params["embed"], hidden, cfg.tie_embeddings)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, seq_len: int, sc: ShardCtx = ShardCtx(),
               dp=None, enc_len: int = 0):
    """Zero decode cache + matching PartitionSpec tree.

    dp: batch placement (axis tuple or None); sequence-sharded global caches
    use the model axis (plus data when the batch is replicated).
    """
    dtype = jnp.dtype(cfg.dtype)
    kinds = block_kinds(cfg)
    kvc = sc.kv_col(cfg.num_kv_heads, cfg.head_dim)
    sp_axes = seq_shard_axes(cfg, batch, seq_len, sc, dp)

    def one(bk: BlockKind):
        if bk.mixer in ATTN_KINDS:
            C = A.cache_capacity(cfg, bk.mixer, seq_len)
            shardable = bk.mixer in ("attn", "nope")   # LSE-merge decode path
            seq_spec = sp_axes if (shardable and C >= SEQ_SHARD_MIN and sp_axes) else None
            cache = {
                "k": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.head_dim), dtype),
            }
            spec = {"k": P(dp, seq_spec, kvc, None), "v": P(dp, seq_spec, kvc, None)}
        elif bk.mixer == "mamba":
            cache = S.init_mamba_cache(cfg, batch, dtype)
            spec = S.mamba_cache_specs(cfg, sc, dp)
        elif bk.mixer == "mlstm":
            cache = X.init_mlstm_cache(cfg, batch, dtype)
            spec = X.mlstm_cache_specs(cfg, sc, dp)
        elif bk.mixer == "slstm":
            cache = X.init_slstm_cache(cfg, batch, dtype)
            spec = X.slstm_cache_specs(cfg, sc, dp)
        else:
            raise ValueError(bk.mixer)
        if cfg.encoder_layers > 0:
            cross = {
                "k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            }
            cspec = {"k": P(dp, None, kvc, None), "v": P(dp, None, kvc, None)}
            return ({"self": cache, "cross": cross},
                    {"self": spec, "cross": cspec})
        return cache, spec

    periods = cfg.scan_periods
    blocks, bspecs = [], []
    for bk in kinds:
        c, s = one(bk)
        blocks.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (periods,) + a.shape), c))
        bspecs.append(jax.tree.map(lambda sp: P(None, *sp), s,
                                   is_leaf=lambda sp: isinstance(sp, P)))
    tails, tspecs = [], []
    for bk in tail_kinds_of(cfg):
        c, s = one(bk)
        tails.append(c)
        tspecs.append(s)
    cache = {"blocks": tuple(blocks), "tail": tuple(tails)}
    specs = {"blocks": tuple(bspecs), "tail": tuple(tspecs)}
    return cache, specs


def seq_shard_axes(cfg, batch: int, seq_len: int, sc: ShardCtx, dp):
    """Mesh axes used to shard long decode caches over the sequence dim."""
    axes = []
    if sc.tp > 1:
        axes.append("model")
    if dp is None and sc.dp > 1:
        axes.append("data")   # batch replicated (long_500k) -> data shards S too
    return tuple(axes)


def decode_step(params, cfg, cache, tokens, length, *,
                part: Partitioner = Partitioner(), mesh_info=None,
                unroll: bool = False):
    """One decoding step for every sequence in the batch.

    tokens: (B, 1) int32; length: scalar int32 tokens already in context.
    Returns (logits (B, vocab_padded), new_cache).
    """
    x = embed_input(params, cfg, {"tokens": tokens,
                                  "positions": length[None] if length.ndim == 0 else length},
                    part)
    kinds = block_kinds(cfg)

    def apply_one(bp, bc, bk: BlockKind, x):
        self_cache = bc["self"] if cfg.encoder_layers > 0 else bc
        h = L.apply_norm(bp["norm1"], x, cfg.norm)
        if bk.mixer in ATTN_KINDS:
            y, new_self = A.attend_decode(bp["mixer"], h, self_cache, length, cfg,
                                          bk.mixer, mesh_info=mesh_info)
        elif bk.mixer == "mamba":
            y, new_self = S.mamba_decode(bp["mixer"], h, self_cache, cfg)
        elif bk.mixer == "mlstm":
            y, new_self = X.mlstm_decode(bp["mixer"], h, self_cache, cfg)
        elif bk.mixer == "slstm":
            y, new_self = X.slstm_decode(bp["mixer"], h, self_cache, cfg)
        else:
            raise ValueError(bk.mixer)
        if cfg.post_norm:
            y = L.apply_norm(bp["post_norm1"], y, cfg.norm)
        x = x + y
        if "cross" in bp:
            h = L.apply_norm(bp["norm_cross"], x, cfg.norm)
            x = x + A.attend_cross_decode(bp["cross"], h, bc["cross"], cfg)
        if bk.ffn != "none":
            h = L.apply_norm(bp["norm2"], x, cfg.norm)
            if bk.ffn == "dense":
                y = L.apply_mlp(bp["ffn"], h, cfg.mlp)
            else:
                y, _ = M.apply_moe(bp["ffn"], h, cfg.moe, group=cfg.moe_group,
                                   part=part)
            if cfg.post_norm:
                y = L.apply_norm(bp["post_norm2"], y, cfg.norm)
            x = x + y
        new_cache = ({"self": new_self, "cross": bc["cross"]}
                     if cfg.encoder_layers > 0 else new_self)
        return x, new_cache

    def period_fn(x, scanned):
        bps, bcs = scanned
        new_caches = []
        for pos, bk in enumerate(kinds):
            x, nc = apply_one(bps[pos], bcs[pos], bk, x)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_block_caches = jax.lax.scan(
        period_fn, x, (params["blocks"], cache["blocks"]),
        unroll=cfg.scan_periods if unroll else 1)

    new_tail = []
    for tp, tc, bk in zip(params["tail"], cache["tail"], tail_kinds_of(cfg)):
        x, nc = apply_one(tp, tc, bk, x)
        new_tail.append(nc)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed_logits(params, cfg, x)[:, 0]
    new_cache = dict(cache)
    new_cache["blocks"] = new_block_caches
    new_cache["tail"] = tuple(new_tail)
    return logits, new_cache
