"""The warm compiled-executable cache of the serving layer (DESIGN.md §8).

An executor is the callable the service launches once per record point:
``chunk_fn(op, b, x, picks) -> (x_next, resid)`` — the engine's
``sequential_chunk`` with the batch's statics bound.  Two batches with the
same ``ExecKey`` reuse the same executor object, and therefore the same
underlying jit executable: the key carries exactly the attributes that
feed a static argument or an array shape, nothing else.

The cache is a service-level object (not jax's internal jit cache) so the
service can *count* — the hit/miss counters are how tests prove that N
concurrent tenants produced one compiled batch pipeline, and how the
benchmark separates warmup cost from steady-state latency.
"""
from __future__ import annotations

import threading
from typing import NamedTuple


class ExecKey(NamedTuple):
    """Everything that selects a distinct compiled chunk executable."""

    format: str              # operator class name ("CsrOp", "DenseOp", ...)
    action: str              # "gs" | "rk"
    shape: tuple             # operator (rows, cols) — the padded shape bucket
    k_bucket: int            # padded RHS width (bucketing.bucket_rhs)
    storage_dtype: str | None
    compress: str            # wire codec ("none" for the sequential service)
    record_every: int        # chunk length (static in the chunk executable)
    fused: bool


class ExecutorCache:
    """Thread-safe ``ExecKey -> chunk_fn`` cache with hit/miss counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._fns: dict[ExecKey, object] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: ExecKey, builder):
        """The cached executor for ``key``, building it on first use."""
        with self._lock:
            fn = self._fns.get(key)
            if fn is None:
                self.misses += 1
                fn = self._fns[key] = builder()
            else:
                self.hits += 1
            return fn

    def __len__(self) -> int:
        with self._lock:
            return len(self._fns)

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._fns)}
