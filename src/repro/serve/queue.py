"""Thread-safe request queue + completion tickets (DESIGN.md §8).

Tenants call ``SolverService.submit`` from arbitrary threads; the service
loop drains pending requests in arrival order and batches them onto the
engine's multi-RHS axis.  A ``Ticket`` is the caller's handle: it blocks
on ``result()``, and receives streamed partial iterates (one per record
point the request was still in flight at) via ``partials`` /
``on_progress``.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, NamedTuple

_ids = itertools.count()


@dataclass
class Request:
    """One tenant's solve ask against a registered problem."""

    problem: str            # registered problem name
    b: object               # (n, c) RHS block (c >= 1 columns)
    tol: object             # (c,) absolute residual target per column
    deadline: float | None  # absolute time.monotonic() cutoff, or None
    submitted: float = field(default_factory=time.monotonic)
    on_progress: Callable | None = None
    id: int = field(default_factory=lambda: next(_ids))


class Partial(NamedTuple):
    """A streamed in-flight snapshot at a record point."""

    iters: int     # iterations executed when the snapshot was taken
    x: object      # (n, c) partial iterate (bucket padding stripped)
    resid: object  # (c,) current residual per column


class RequestResult(NamedTuple):
    x: object             # (n, c) final iterate for this request's columns
    resid: object         # (c,) final residual per column
    rounds: object        # (c,) record chunks each column needed
    converged: object     # (c,) bool per column
    iters_run: int        # iterations this request's batch executed for it
    latency_s: float      # submit -> completion wall time


class Ticket:
    """Completion handle handed back by ``submit``."""

    def __init__(self, request: Request):
        self.request = request
        self.partials: list[Partial] = []
        self._event = threading.Event()
        self._result: RequestResult | None = None

    def push_partial(self, partial: Partial) -> None:
        self.partials.append(partial)
        if self.request.on_progress is not None:
            self.request.on_progress(partial)

    def complete(self, result: RequestResult) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> RequestResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.id} not complete within {timeout}s")
        return self._result


class RequestQueue:
    """FIFO of ``(Request, Ticket)`` pairs with a batching drain."""

    def __init__(self):
        self._cv = threading.Condition()
        self._items: deque = deque()

    def submit(self, request: Request) -> Ticket:
        ticket = Ticket(request)
        with self._cv:
            self._items.append((request, ticket))
            self._cv.notify_all()
        return ticket

    def drain(self, max_requests: int, *, wait_s: float = 0.05,
              window_s: float = 0.0) -> list:
        """Up to ``max_requests`` pending pairs, in arrival order.

        Blocks up to ``wait_s`` for the first arrival; once something is
        pending, waits a further ``window_s`` so concurrent tenants land
        in the same batch (the continuous-batching admission window).
        """
        with self._cv:
            if not self._items:
                self._cv.wait(wait_s)
            if not self._items:
                return []
        if window_s > 0:
            time.sleep(window_s)
        with self._cv:
            out = []
            while self._items and len(out) < max_requests:
                out.append(self._items.popleft())
            return out

    def __len__(self) -> int:
        with self._cv:
            return len(self._items)
