"""Shape buckets for the serving layer (DESIGN.md §8).

The engine's compiled executables are shape-specialized: a solve over a
``(n, k)`` RHS block compiles once per distinct ``k``.  A service that
launched one executable per observed request-batch width would recompile
constantly under mixed traffic, so batches are padded up to a small set of
RHS-width buckets — the compiled-executable cache is keyed by the bucket,
not the raw width, and the padding is stripped again on exit.

Zero-padding the RHS axis is EXACT for both engine actions: columns are
independent (every update's ``gamma`` is computed per column), and a zero
column solves ``A x = 0`` from ``x0 = 0`` — every update is exactly zero,
so padded columns stay identically zero and never perturb real columns.
A request's columns therefore take bitwise the trajectory they would have
taken unpadded, which tests/test_serve.py pins.
"""
from __future__ import annotations

import jax.numpy as jnp

#: RHS-width buckets: powers of two up to the default max batch.  Widths
#: beyond the top bucket round up to a multiple of it.
RHS_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def bucket_rhs(k: int, buckets=RHS_BUCKETS) -> int:
    """Smallest bucket >= ``k`` (beyond the top: next multiple of it)."""
    if k <= 0:
        raise ValueError(f"k must be > 0 (got {k})")
    for cap in buckets:
        if k <= cap:
            return cap
    top = buckets[-1]
    return -(-k // top) * top


def pad_columns(b, k_bucket: int):
    """Zero-pad ``b``'s RHS axis ``(n, k) -> (n, k_bucket)``."""
    n, k = b.shape
    if k > k_bucket:
        raise ValueError(f"cannot pad {k} columns into a {k_bucket} bucket")
    if k == k_bucket:
        return b
    return jnp.concatenate(
        [b, jnp.zeros((n, k_bucket - k), b.dtype)], axis=1)


def unpad_columns(x, k: int):
    """Strip bucket padding: the first ``k`` columns are the real ones."""
    return x[:, :k]
