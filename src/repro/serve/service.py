"""The persistent solver service (DESIGN.md §8): continuous batching of
concurrent tenants' RHS columns onto the engine's multi-RHS axis.

The paper's premise is throughput under concurrency — processors make
progress without waiting on each other.  The serving layer applies the
same idea one level up: independent in-flight *requests* share the
iterate machinery the way independent workers share the iterate.  Columns
of a batched solve are independent under both engine actions, so packing
N tenants' RHS columns into one ``(n, k)`` block and running ONE chunked
solve gives every tenant bitwise the trajectory of a solo solve — at one
launch's cost per record point instead of N.

Mechanics per batch: drain the queue (admission window ``batch_window_s``),
group by registered problem, concatenate columns, pad to the RHS bucket
(``serve.bucketing``), fetch the warm chunk executable from the
``ExecutorCache``, and drive ``core.engine.solve_batched`` with
heterogeneous per-column tolerances.  At every record point the service
streams partial iterates to in-flight tickets, completes tenants whose
columns converged (their round count is theirs alone — a loose-tolerance
tenant exits early while the batch keeps iterating for the others), and
enforces per-request deadlines (a past-deadline tenant gets its partial
iterate, marked unconverged).  Joins happen at batch boundaries, leaves
at record points, so the tail latency a tenant pays for batching is
bounded by ``record_every`` iterations plus the admission window.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    draw_picks, resolve_record_every, sequential_chunk, solve_batched)
from repro.core.operators import as_operator
from repro.serve.bucketing import bucket_rhs, pad_columns, unpad_columns
from repro.serve.executor import ExecKey, ExecutorCache
from repro.serve.queue import (
    Partial, Request, RequestQueue, RequestResult, Ticket)
from repro.tune import runtime as tune_runtime


@dataclass
class RegisteredProblem:
    """A named operator tenants can submit RHS against."""

    name: str
    op: object
    action: str
    format: str
    storage_dtype: str | None
    key: jax.Array        # pick-stream key, fixed per problem (deterministic)
    beta: float
    num_iters: int
    record_every: int


@dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    chunk_launches: int = 0
    deadline_expired: int = 0
    #: per-batch RHS widths (real columns, before bucket padding)
    batch_widths: list = field(default_factory=list)


class SolverService:
    """A persistent solver wrapping ``solve_batched`` behind a queue.

    Use as a context manager (``with SolverService(...) as svc``) or call
    ``start()`` / ``stop()`` explicitly.  ``max_batch`` caps how many
    requests one batch admits; ``batch_window_s`` is how long the loop
    lingers after the first arrival so concurrent tenants share a launch.
    """

    def __init__(self, *, num_iters: int = 4096, record_every: int = 64,
                 max_batch: int = 32, batch_window_s: float = 0.002,
                 fused: bool | str = False,
                 cache: ExecutorCache | None = None):
        resolve_record_every(num_iters, record_every)  # fail fast, once
        self.num_iters = num_iters
        self.record_every = record_every
        self.max_batch = max_batch
        self.batch_window_s = batch_window_s
        self.fused = fused
        self.executors = cache if cache is not None else ExecutorCache()
        self.stats = ServiceStats()
        self._queue = RequestQueue()
        self._problems: dict[str, RegisteredProblem] = {}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- registration -------------------------------------------------------

    def register(self, name: str, A, *, action: str = "gs",
                 format: str = "dense", storage_dtype=None, seed: int = 0,
                 beta: float = 1.0, num_iters: int | None = None,
                 record_every: int | None = None, warmup_buckets=(),
                 **op_kwargs) -> RegisteredProblem:
        """Register operator ``A`` under ``name`` (built once, kept warm).

        The pick-stream key derives from ``seed`` alone, so every batch
        against this problem replays the same direction stream — a
        tenant's result is a pure function of its RHS and tolerance,
        independent of which batch it landed in.  ``warmup_buckets``
        pre-compiles the chunk executable for the given RHS buckets.
        """
        num_iters = self.num_iters if num_iters is None else num_iters
        record_every = (self.record_every if record_every is None
                        else record_every)
        resolve_record_every(num_iters, record_every)
        op = as_operator(A, format, storage_dtype=storage_dtype, **op_kwargs)
        reg = RegisteredProblem(
            name=name, op=op, action=action, format=format,
            storage_dtype=storage_dtype, key=jax.random.key(seed), beta=beta,
            num_iters=num_iters, record_every=record_every)
        self._problems[name] = reg
        for kb in warmup_buckets:
            chunk_fn = self._executor(reg, bucket_rhs(kb))
            n_b = op.shape[0]
            zeros_b = jnp.zeros((n_b, bucket_rhs(kb)), jnp.float32)
            n_x = op.shape[0] if action == "gs" else op.shape[1]
            picks = jnp.zeros((reg.record_every,), jnp.int32)
            jax.block_until_ready(chunk_fn(
                op, zeros_b, jnp.zeros((n_x, bucket_rhs(kb)), jnp.float32),
                picks))
        if warmup_buckets:
            # The full pick stream is drawn once per batch; its sampler
            # compiles per (num_iters, format) — pull that compile out of
            # the first batch's measured latency too.
            jax.block_until_ready(
                draw_picks(op, action, reg.key, reg.num_iters))
        return reg

    # -- submission ---------------------------------------------------------

    def submit(self, name: str, b, *, tol=None, rtol: float | None = None,
               deadline_s: float | None = None,
               on_progress=None) -> Ticket:
        """Enqueue RHS ``b`` (``(n,)`` or ``(n, c)``) against ``name``.

        ``tol`` is an absolute per-column residual target (scalar or
        ``(c,)``); ``rtol`` instead scales each column's ``||b||_2``
        (default ``rtol=1e-3`` when neither is given).  ``deadline_s`` is
        a relative wall-clock budget: a request past its deadline is
        completed with its current partial iterate, ``converged=False``.
        """
        reg = self._problems[name]
        b = jnp.asarray(b)
        if b.ndim == 1:
            b = b[:, None]
        if b.shape[0] != reg.op.shape[0]:
            raise ValueError(
                f"RHS has {b.shape[0]} rows; problem {name!r} expects "
                f"{reg.op.shape[0]}")
        if tol is None:
            rtol = 1e-3 if rtol is None else rtol
            tol = rtol * np.linalg.norm(np.asarray(b), axis=0)
        tol = np.broadcast_to(
            np.asarray(tol, np.float32), (b.shape[1],)).copy()
        deadline = (None if deadline_s is None
                    else time.monotonic() + deadline_s)
        req = Request(problem=name, b=b, tol=tol, deadline=deadline,
                      on_progress=on_progress)
        return self._queue.submit(req)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "SolverService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="solver-service", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "SolverService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self._queue.drain(self.max_batch, wait_s=0.05,
                                      window_s=self.batch_window_s)
            if not batch:
                continue
            by_problem: dict[str, list] = {}
            for pair in batch:
                by_problem.setdefault(pair[0].problem, []).append(pair)
            for name, items in by_problem.items():
                self._execute(self._problems[name], items)

    # -- batch execution ----------------------------------------------------

    def _fused_for(self, reg: RegisteredProblem) -> bool:
        """The service's ``fused`` setting resolved per problem:
        ``"auto"`` asks the tuning table for this operator's measured
        fused-vs-scan winner (missing entry -> scan, today's default), so
        the warm executables are compiled for the tuned choice — the
        resolution happens HERE, before the ``ExecKey`` is built, keeping
        the cache keyed by what actually runs."""
        return tune_runtime.resolve_fused(self.fused, reg.op, reg.action)

    def _executor(self, reg: RegisteredProblem, k_bucket: int):
        fused = self._fused_for(reg)
        exec_key = ExecKey(
            format=type(reg.op).__name__, action=reg.action,
            shape=tuple(reg.op.shape), k_bucket=k_bucket,
            storage_dtype=reg.storage_dtype, compress="none",
            record_every=reg.record_every, fused=fused)
        return self.executors.get(exec_key, lambda: functools.partial(
            sequential_chunk, action=reg.action, beta=reg.beta, block=1,
            fused=fused))

    def _execute(self, reg: RegisteredProblem, items: list) -> None:
        """One continuous batch: concat -> pad -> chunked solve -> unpad."""
        rec = reg.record_every
        spans, cols, tols = [], [], []
        start = 0
        for req, _ in items:
            c = req.b.shape[1]
            spans.append(slice(start, start + c))
            cols.append(req.b)
            tols.append(req.tol)
            start += c
        k = start
        kb = bucket_rhs(k)
        B = pad_columns(jnp.concatenate(cols, axis=1).astype(jnp.float32), kb)
        # Padded columns get +inf tolerance: their residual is exactly 0
        # (zero column, zero iterate), so they never gate the early exit.
        tol_full = np.full((kb,), np.inf, np.float32)
        tol_full[:k] = np.concatenate(tols)
        chunk_fn = self._executor(reg, kb)

        active = [True] * len(items)
        first_chunk = np.zeros((kb,), np.int32)

        def finish(i, x_np, resid_np, conv_cols, chunks_done):
            req, ticket = items[i]
            s = spans[i]
            rounds = np.where(first_chunk[s] > 0, first_chunk[s],
                              chunks_done).astype(np.int32)
            # Un-pad on exit: a request's columns live inside the real
            # [0, k) region of the bucket, so its span slice IS the unpad.
            ticket.complete(RequestResult(
                x=unpad_columns(x_np, k)[:, s],
                resid=resid_np[s].copy(), rounds=rounds,
                converged=conv_cols.copy(), iters_run=chunks_done * rec,
                latency_s=time.monotonic() - req.submitted))
            active[i] = False

        def on_record(ci, x, resid, conv):
            self.stats.chunk_launches += 1
            newly = conv & (first_chunk == 0)
            first_chunk[newly] = ci + 1
            now = time.monotonic()
            x_np = resid_np = None
            for i, (req, ticket) in enumerate(items):
                if not active[i]:
                    continue
                if x_np is None:
                    x_np, resid_np = np.asarray(x), np.asarray(resid)
                s = spans[i]
                conv_cols = conv[s]
                expired = req.deadline is not None and now >= req.deadline
                if conv_cols.all() or expired:
                    if expired and not conv_cols.all():
                        self.stats.deadline_expired += 1
                    finish(i, x_np, resid_np, conv_cols, ci + 1)
                else:
                    ticket.push_partial(Partial(
                        iters=(ci + 1) * rec, x=x_np[:, s].copy(),
                        resid=resid_np[s].copy()))
            return any(active)

        res = solve_batched(
            reg.op, B, action=reg.action, key=reg.key,
            num_iters=reg.num_iters, record_every=rec, tol=tol_full,
            beta=reg.beta, fused=self._fused_for(reg), chunk_fn=chunk_fn,
            on_record=on_record)

        # Anyone still active hit the iteration cap: complete with finals.
        if any(active):
            x_np = np.asarray(res.x)
            resid_np = np.asarray(res.resid)
            conv_np = np.asarray(res.converged)
            chunks_done = res.iters_run // rec
            for i in range(len(items)):
                if active[i]:
                    finish(i, x_np, resid_np, conv_np[spans[i]], chunks_done)

        self.stats.requests += len(items)
        self.stats.batches += 1
        self.stats.batch_widths.append(k)
