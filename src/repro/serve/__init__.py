"""Solver-as-a-service: the persistent serving layer over ``core.engine``
(DESIGN.md §8).

``SolverService`` wraps the engine's chunked batched entry
(``solve_batched``) behind a thread-safe request queue: concurrent
tenants' RHS columns are batched onto the engine's multi-RHS axis, padded
to shape buckets so the warm ``ExecutorCache`` reuses compiled chunk
executables, solved with heterogeneous per-column tolerances, and
un-padded on exit — with per-request deadlines, early exit at record
points, and streamed partial iterates.
"""
from repro.serve.bucketing import (
    RHS_BUCKETS, bucket_rhs, pad_columns, unpad_columns)
from repro.serve.executor import ExecKey, ExecutorCache
from repro.serve.loadgen import LoadReport, open_loop_load, percentile
from repro.serve.queue import (
    Partial, Request, RequestQueue, RequestResult, Ticket)
from repro.serve.service import RegisteredProblem, ServiceStats, SolverService

__all__ = [
    "ExecKey",
    "ExecutorCache",
    "LoadReport",
    "Partial",
    "RHS_BUCKETS",
    "RegisteredProblem",
    "Request",
    "RequestQueue",
    "RequestResult",
    "ServiceStats",
    "SolverService",
    "Ticket",
    "bucket_rhs",
    "open_loop_load",
    "pad_columns",
    "percentile",
    "unpad_columns",
]
