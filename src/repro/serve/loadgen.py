"""Synthetic open-loop load generator for the serving layer.

Open-loop means arrivals are scheduled from a fixed process (seeded
exponential inter-arrival gaps at ``rate_hz``), NOT gated on completions
— the honest way to measure a service's latency under load, because a
closed loop would slow the arrival rate down exactly when the service
struggles.  Requests draw mixed RHS widths from ``rhs_widths`` so the
bucketer and executor cache see realistic shape diversity.

Everything is host-side and deterministic given ``seed``; latency is
measured per request from submission to ticket completion (the service
stamps it), and throughput as completed requests over the span from first
submission to last completion.
"""
from __future__ import annotations

import random
import time
from typing import NamedTuple

import numpy as np


class LoadReport(NamedTuple):
    requests: int
    qps: float                # completed requests / makespan
    p50_ms: float
    p99_ms: float
    mean_ms: float
    makespan_s: float
    converged: int            # requests with every column converged
    rounds_per_request: list  # max record chunks any of a request's columns took
    latencies_ms: list


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an ascending list (q in [0, 100])."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def make_rhs(n: int, width: int, rng: np.random.Generator) -> np.ndarray:
    """A dense ``(n, width)`` Gaussian RHS block."""
    return rng.standard_normal((n, width)).astype(np.float32)


def open_loop_load(service, problem: str, *, requests: int, rate_hz: float,
                   rhs_widths=(1,), rtol: float = 1e-3, seed: int = 0,
                   deadline_s: float | None = None,
                   timeout_s: float = 300.0) -> LoadReport:
    """Drive ``service`` with an open-loop request stream; gather stats."""
    reg = service._problems[problem]
    n = reg.op.shape[0]
    gaps = random.Random(seed)
    rng = np.random.default_rng(seed + 1)
    plan = [(make_rhs(n, gaps.choice(list(rhs_widths)), rng),
             gaps.expovariate(rate_hz)) for _ in range(requests)]

    tickets = []
    t_start = time.monotonic()
    for b, gap in plan:
        time.sleep(gap)
        tickets.append(service.submit(problem, b, rtol=rtol,
                                      deadline_s=deadline_s))
    results = [t.result(timeout=timeout_s) for t in tickets]
    makespan = time.monotonic() - t_start

    lat = sorted(float(r.latency_s) * 1e3 for r in results)
    return LoadReport(
        requests=requests,
        qps=requests / makespan,
        p50_ms=percentile(lat, 50),
        p99_ms=percentile(lat, 99),
        mean_ms=float(np.mean(lat)),
        makespan_s=makespan,
        converged=sum(bool(np.asarray(r.converged).all()) for r in results),
        rounds_per_request=[int(np.asarray(r.rounds).max())
                            for r in results],
        latencies_ms=lat,
    )
