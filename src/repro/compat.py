"""jax version-compatibility shims.

The solver and model code targets the modern jax API: ``jax.shard_map`` at
the top level, the varying-manual-axes type system (``jax.lax.pvary``), and
mesh axis types (``jax.sharding.AxisType``).  Older jax releases (0.4.x,
which some CPU-only CI images pin) ship ``shard_map`` under
``jax.experimental``, spell the replication-check kwarg ``check_rep``, and
have neither ``pvary`` nor ``AxisType``.  Every call site imports from this
module so exactly one place owns the fallbacks.
"""
from __future__ import annotations

import jax

try:  # modern jax: top-level shard_map with the VMA type system
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # jax < 0.6: experimental home, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the replication/VMA check spelled portably.

    Defaults to ``check_vma=False`` (legacy semantics): the solvers return
    post-all-gather replicas whose bitwise equality across workers the type
    system cannot prove, and old-jax ``check_rep`` rejects exactly those.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )


def pvary(x, axis_names):
    """Mark ``x`` device-varying along ``axis_names`` (no-op on old jax)."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_names) if fn is not None else x


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version.

    Old jax returns a one-element list of per-program dicts; modern jax
    returns the dict directly.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the install has them.

    Falls back through: axis-typed make_mesh (modern) -> plain make_mesh
    (>= 0.4.35) -> mesh_utils.create_device_mesh + Mesh (older 0.4.x,
    where jax.make_mesh does not exist yet).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(axis_type.Auto,) * len(axis_names))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(axis_shapes)
    return jax.sharding.Mesh(devices, axis_names)
