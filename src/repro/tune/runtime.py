"""Process-wide active tuning table + the dispatch-seam lookups.

The engine's dispatch seams (``solve_sequential`` / ``solve_distributed``
/ ``sequential_chunk`` fused-vs-scan, ``CsrOp.matvec`` variant selection,
``solve``'s ``rows_per_panel`` default) call the helpers here instead of
hardcoding a pick.  Resolution order, everywhere:

1. an explicit caller choice (``fused=True/False``, ``variant=...``,
   ``skip_empty=True/False``, an integer ``rows_per_panel``) is FORCED —
   bitwise-pinned to the pre-autotune behavior, never overridden;
2. otherwise the active table's entry for the site's ``TuneKey`` wins;
3. no entry (or no table) -> today's hardcoded default, bitwise-unchanged.

The active table defaults to the committed ``TUNE_<backend>.json`` for
the current backend (lazily loaded once; missing file -> no table).  The
``REPRO_TUNE_TABLE`` environment variable overrides: a path loads that
file, ``off``/``none``/``0`` disables lookups entirely.  Tests and the
autotuner swap tables with ``use_table`` / ``set_active_table``.

Every lookup reads only static operator metadata (``shape``, leaf
*dtypes*, class name), so the helpers are safe under ``jax.jit`` tracing
— they never concretize a leaf.
"""
from __future__ import annotations

import contextlib
import os

from repro.tune.table import (
    TuneKey, TuningTable, default_path, shape_bucket, storage_key)

_ENV_VAR = "REPRO_TUNE_TABLE"
_UNSET = object()           # "never set: lazily load the committed default"
_active = _UNSET
_default_cache = _UNSET     # memoized committed-table load (None = no file)


def _load_default() -> TuningTable | None:
    global _default_cache
    if _default_cache is _UNSET:
        env = os.environ.get(_ENV_VAR, "")
        if env.lower() in ("off", "none", "0"):
            _default_cache = None
        else:
            path = env or default_path()
            try:
                _default_cache = TuningTable.load(path)
            except (OSError, ValueError):
                _default_cache = None
    return _default_cache


def active_table() -> TuningTable | None:
    """The table lookups consult; None disables every lookup (pure
    hardcoded-default behavior, the pre-autotune engine)."""
    if _active is _UNSET:
        return _load_default()
    return _active


def set_active_table(table: TuningTable | None):
    """Install ``table`` process-wide; returns the previous setting (which
    may be the internal "unset" sentinel — pass it back to restore)."""
    global _active
    prev = _active
    _active = table
    return prev


@contextlib.contextmanager
def use_table(table: TuningTable | None):
    """Scoped ``set_active_table`` (tests, the autotuner's forced runs)."""
    prev = set_active_table(table)
    try:
        yield table
    finally:
        set_active_table(prev)


# -- key derivation from live operators -------------------------------------

def _op_storage_key(op) -> str:
    """'f32'/'bf16' from the operator's stored coefficient dtype (dtype is
    static metadata — present on tracers, so this never concretizes)."""
    for attr in ("data", "vals", "A_bands", "A"):
        leaf = getattr(op, attr, None)
        if leaf is not None:
            return storage_key(leaf.dtype)
    return "f32"


def sweep_key(op, action: str) -> TuneKey:
    return TuneKey("sweep", type(op).__name__, action,
                   shape_bucket(op.shape[0]), _op_storage_key(op))


def matvec_key(op) -> TuneKey:
    return TuneKey("matvec", type(op).__name__, "-",
                   shape_bucket(op.shape[0]), _op_storage_key(op))


def panel_key(m: int, storage_dtype=None) -> TuneKey:
    return TuneKey("panel", "CsrOp", "-", shape_bucket(m),
                   storage_key(storage_dtype))


# -- dispatch-seam lookups ---------------------------------------------------

def fused_choice(op, action: str) -> str | None:
    """The table's fused-vs-scan winner ("fused"/"scan") or None."""
    t = active_table()
    return None if t is None else t.lookup(sweep_key(op, action))


def resolve_fused(fused, op, action: str) -> bool:
    """Resolve a ``Schedule.fused`` value at a dispatch seam.

    Explicit booleans pass through untouched (the bitwise pin);
    ``"auto"`` returns the table's measured winner, or False — today's
    default engine — when no entry exists.
    """
    if fused == "auto":
        return fused_choice(op, action) == "fused"
    return bool(fused)


def matvec_variant(op) -> str | None:
    """The table's CSR matvec variant for ``op``'s bucket, or None."""
    t = active_table()
    return None if t is None else t.lookup(matvec_key(op))


def tuned_rows_per_panel(m: int, storage_dtype=None) -> int | None:
    """The table's ``rows_per_panel`` winner for an m-row CSR build, or
    None (-> the caller's hardcoded default)."""
    t = active_table()
    choice = None if t is None else t.lookup(panel_key(m, storage_dtype))
    return None if choice is None else int(choice)
