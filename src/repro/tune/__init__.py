"""``repro.tune`` — the kernel autotuner and measured auto-dispatch.

``table``   — the versioned, backend-keyed ``TUNE_<backend>.json`` schema
              (``TuningTable`` / ``TuneKey`` / shape bucketing);
``runtime`` — the process-wide active table and the lookups the dispatch
              seams call (``resolve_fused``, ``matvec_variant``,
              ``tuned_rows_per_panel``);
``autotune``— the sweep/show/diff CLI
              (``python -m repro.tune.autotune``).

Contract (DESIGN.md §9): explicit caller choices are bitwise-pinned and
never overridden; a missing table entry falls back to today's hardcoded
defaults, bitwise-unchanged — the table only chooses *which*
already-pinned implementation runs.
"""
from repro.tune.table import TuneKey, TuningTable, shape_bucket
from repro.tune.runtime import (
    active_table, matvec_variant, resolve_fused, set_active_table,
    tuned_rows_per_panel, use_table)

__all__ = [
    "TuneKey",
    "TuningTable",
    "active_table",
    "matvec_variant",
    "resolve_fused",
    "set_active_table",
    "shape_bucket",
    "tuned_rows_per_panel",
    "use_table",
]
