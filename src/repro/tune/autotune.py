"""The autotuner CLI: measure the tunable axes, persist the winners.

    PYTHONPATH=src python -m repro.tune.autotune sweep --n 1024
    PYTHONPATH=src python -m repro.tune.autotune show  [TUNE_cpu.json]
    PYTHONPATH=src python -m repro.tune.autotune diff  A.json B.json

``sweep`` times every candidate of every tunable axis on reference
problems at the requested shape — min-of-``--repeats`` wall clock via
``benchmarks/common.timed`` (one-sided noise, the same statistic the
BENCH trail trusts) — and writes the winners to a ``TuningTable``
(default: the committed ``TUNE_<backend>.json``; ``--merge`` folds the
new bucket's entries into an existing file so one table accumulates
buckets across runs).  Axes swept per kernel entry point:

* ``matvec`` — the CSR variant family (``sliced`` / ``sliced_prefetch``
  / ``segsum`` / ``segsum_prefetch``): the ``skip_empty`` on/off axis is
  the ``*_prefetch`` twins, timed on both a dense-panel and a half-empty
  ("patchy") pattern, winner by total time across the two (one entry per
  bucket must serve both; the patchy pattern is where predication pays);
* ``sweep`` — fused Pallas sweep vs per-step scan inner loops, per
  (format x action) row of the sequential engine (banded GS, CSR/ELL
  GS and RK), through ``solve_sequential`` both ways;
* ``panel`` — CSR ``rows_per_panel`` candidates (the layout the sliced
  matvec and the sweep kernels stream).  ``block`` (banded) and
  ``row_cap`` are *structural* on the current formats — the block size
  must match the matrix blocking and ``row_cap`` is the stored pattern's
  max row occupancy — so they are recorded as swept-shape metadata, not
  tuned.

``show`` prints a table's identity and per-key choices; ``diff`` exits
nonzero iff two tables disagree on any shared key or cover different
keys — the CI round-trip gate (write -> load -> identical choices).
"""
from __future__ import annotations

import argparse
import sys

from repro.tune.table import (
    MATVEC_VARIANTS, REPO_ROOT, TuningTable, default_path, shape_bucket)

#: CSR rows_per_panel candidates ("panel" axis)
PANEL_CANDIDATES = (4, 8, 16)


def _timed():
    """``benchmarks/common.timed`` — the benchmarks package lives at the
    repo root (not under src/), so running the tuner from elsewhere needs
    the root on sys.path before the import resolves."""
    try:
        from benchmarks.common import timed
    except ImportError:
        sys.path.insert(0, str(REPO_ROOT))
        from benchmarks.common import timed
    return timed


def _patchy(A, rows_per_panel: int):
    """Zero every other row panel — the half-empty pattern a norm-balanced
    partition of banded structure produces (the skip_empty design case)."""
    import numpy as np
    Ap = np.array(A)
    R = rows_per_panel
    for p in range(0, Ap.shape[0] // R, 2):
        Ap[p * R:(p + 1) * R] = 0.0
    return Ap


def sweep_matvec(table: TuningTable, *, n: int, k: int, row_nnz: int,
                 repeats: int, storage_dtype=None, seed: int = 0) -> None:
    import jax.numpy as jnp
    from repro.core import CsrOp, random_sparse_spd
    from repro.tune import runtime
    timed = _timed()
    prob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=k, seed=seed)
    cop = CsrOp.from_dense(prob.A, storage_dtype=storage_dtype)
    pop = CsrOp.from_dense(jnp.asarray(_patchy(prob.A, cop.rows_per_panel)),
                           storage_dtype=storage_dtype)
    x = prob.x_star
    wall: dict[str, float] = {}
    with runtime.use_table(None):      # forced variants: no table recursion
        for v in MATVEC_VARIANTS:
            us = sum(
                timed(lambda op=op, v=v: op.matvec(x, variant=v),
                      iters=repeats, stat="min")
                for op in (cop, pop)) * 1e6
            wall[v] = us
            print(f"[tune] matvec/{v:<16s} {us:10.0f} us "
                  f"(dense+patchy, n={n})")
    choice = min(wall, key=wall.get)
    table.record(runtime.matvec_key(cop), choice, wall)
    print(f"[tune] matvec winner @ {shape_bucket(n)}: {choice}")


def sweep_panels(table: TuningTable, *, n: int, k: int, row_nnz: int,
                 repeats: int, storage_dtype=None, seed: int = 0) -> None:
    from repro.core import CsrOp, random_sparse_spd
    from repro.tune import runtime
    timed = _timed()
    prob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=k, seed=seed)
    x = prob.x_star
    wall: dict[str, float] = {}
    with runtime.use_table(None):
        for R in PANEL_CANDIDATES:
            op = CsrOp.from_dense(prob.A, rows_per_panel=R,
                                  storage_dtype=storage_dtype)
            us = timed(lambda op=op: op.matvec(x),
                       iters=repeats, stat="min") * 1e6
            wall[str(R)] = us
            print(f"[tune] panel/rows_per_panel={R:<3d} {us:10.0f} us")
    choice = min(wall, key=wall.get)
    table.record(runtime.panel_key(n, storage_dtype), choice, wall)
    print(f"[tune] panel winner @ {shape_bucket(n)}: rows_per_panel={choice}")


def sweep_engines(table: TuningTable, *, n: int, k: int, row_nnz: int,
                  steps: int, repeats: int, storage_dtype=None,
                  seed: int = 0) -> None:
    """Fused-vs-scan per sequential (format x action) row (the bench
    ``sweeps`` section's cases, measured for dispatch instead of report)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import (BlockBandedOp, CsrOp, EllOp, block_banded_spd,
                            random_sparse_spd)
    from repro.core.engine import solve_sequential
    from repro.tune import runtime
    timed = _timed()
    block = max(min(n // 8, 64), 1)
    bprob = block_banded_spd(n, block=block, bands=1, n_rhs=k, seed=seed)
    bop = BlockBandedOp.from_dense(bprob.A, block=block, bands=1,
                                   storage_dtype=storage_dtype)
    sprob = random_sparse_spd(n, row_nnz=row_nnz, n_rhs=k, seed=seed + 1)
    ewidth = int((np.asarray(sprob.A) != 0).sum(1).max())
    cop = CsrOp.from_dense(sprob.A, storage_dtype=storage_dtype)
    eop = EllOp.from_dense(sprob.A, width=ewidth, storage_dtype=storage_dtype)
    cases = [(bop, bprob, "gs"), (cop, sprob, "gs"), (cop, sprob, "rk"),
             (eop, sprob, "gs"), (eop, sprob, "rk")]
    with runtime.use_table(None):      # forced engines: no table recursion
        for op, prob, action in cases:
            x0 = jnp.zeros_like(prob.b)
            wall = {}
            for name, fused in (("scan", False), ("fused", True)):
                us = timed(
                    lambda f=fused, op=op, prob=prob, action=action, x0=x0:
                        solve_sequential(op, prob.b, x0, prob.x_star,
                                         action=action,
                                         key=jax.random.key(2),
                                         num_iters=steps, record_every=steps,
                                         fused=f).x,
                    iters=repeats, stat="min") * 1e6
                wall[name] = us
            choice = min(wall, key=wall.get)
            key = runtime.sweep_key(op, action)
            table.record(key, choice, wall)
            print(f"[tune] {key.render():<40s} scan={wall['scan']:.0f}us "
                  f"fused={wall['fused']:.0f}us -> {choice}")


def run_sweep(args) -> TuningTable:
    out = args.out or default_path()
    if args.merge:
        try:
            table = TuningTable.load(out)
        except (OSError, ValueError):
            table = TuningTable.fresh()
    else:
        table = TuningTable.fresh()
    dt = args.storage_dtype
    kw = dict(n=args.n, k=args.k, row_nnz=args.row_nnz,
              repeats=args.repeats, storage_dtype=dt, seed=args.seed)
    sweep_matvec(table, **kw)
    sweep_panels(table, **kw)
    sweep_engines(table, steps=args.steps, **kw)
    path = table.save(out)
    print(f"[tune] wrote {path} ({len(table.entries)} entries, "
          f"backend={table.backend}, interpret={table.interpret_mode})")
    return table


def run_show(args) -> int:
    table = TuningTable.load(args.path or default_path())
    print(f"backend={table.backend} device_kind={table.device_kind} "
          f"interpret_mode={table.interpret_mode} "
          f"jax={table.jax_version} version={table.version}")
    for key, choice in table.choices().items():
        walls = table.entries[key]["wall_us"]
        detail = " ".join(f"{c}={us:.0f}us" for c, us in walls.items())
        print(f"  {key:<42s} -> {choice:<16s} ({detail})")
    return 0


def run_diff(args) -> int:
    a = TuningTable.load(args.a)
    b = TuningTable.load(args.b or default_path())
    ca, cb = a.choices(), b.choices()
    drift = 0
    for key in sorted(set(ca) | set(cb)):
        va, vb = ca.get(key), cb.get(key)
        if va != vb:
            drift += 1
            print(f"  {key}: {va or '<missing>'} != {vb or '<missing>'}")
    if drift:
        print(f"[tune] {drift} key(s) differ")
        return 1
    print(f"[tune] identical choices ({len(ca)} keys)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.tune.autotune",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sw = sub.add_parser("sweep", help="measure candidates, persist winners")
    sw.add_argument("--n", type=int, default=1024)
    sw.add_argument("--k", type=int, default=8)
    sw.add_argument("--row-nnz", type=int, default=16)
    sw.add_argument("--steps", type=int, default=256,
                    help="inner-loop length for the fused-vs-scan sweep")
    sw.add_argument("--repeats", type=int, default=3,
                    help="timing repetitions; winners are min-of-N")
    sw.add_argument("--storage-dtype", choices=("float32", "bfloat16"),
                    default=None)
    sw.add_argument("--seed", type=int, default=0)
    sw.add_argument("--out", default=None,
                    help="output path (default: TUNE_<backend>.json at the "
                         "repo root)")
    sw.add_argument("--merge", action="store_true",
                    help="fold the new bucket's entries into an existing "
                         "table instead of starting fresh")
    sh = sub.add_parser("show", help="print a table's entries")
    sh.add_argument("path", nargs="?", default=None)
    df = sub.add_parser("diff", help="compare two tables' choices "
                                     "(exit 1 on drift)")
    df.add_argument("a")
    df.add_argument("b", nargs="?", default=None,
                    help="default: the committed TUNE_<backend>.json")
    args = ap.parse_args(argv)
    if args.cmd == "sweep":
        run_sweep(args)
        return 0
    if args.cmd == "show":
        return run_show(args)
    return run_diff(args)


if __name__ == "__main__":
    sys.exit(main())
