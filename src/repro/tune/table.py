"""The persisted tuning table: measured dispatch choices, backend-keyed.

Every dispatch decision the engine makes among *interchangeable pinned
implementations* — fused sweep vs per-step scan, sliced vs segment-sum vs
prefetch-predicated CSR matvec, ``rows_per_panel`` — used to be a
hardcoded default.  The recorded CPU interpret-mode numbers invert the
kernels' TPU design point (ROADMAP: banded GS fused is ~4x *slower* than
the scan there), so a constant can never be right on more than one
backend.  This module holds the Triton-style answer: measure once per
(kernel, format, action, shape-bucket, storage dtype) on the backend at
hand, persist the winners to ``TUNE_<backend>.json`` at the repo root,
and let the dispatch seams look the choice up at solve time.

Schema (``SCHEMA_VERSION``):

.. code-block:: json

    {
      "version": 1,
      "backend": "cpu",
      "device_kind": "...",
      "interpret_mode": true,
      "jax_version": "0.x",
      "entries": {
        "sweep/BlockBandedOp/gs/n1024/f32":
            {"choice": "scan", "wall_us": {"scan": 3821.0, "fused": 23987.0}}
      }
    }

Key axes (``TuneKey``): ``kernel`` is the tunable entry point ("sweep" =
fused-vs-scan inner loop, "matvec" = the CSR matvec variant family,
"panel" = the CSR ``rows_per_panel`` layout); ``format`` is the operator
class name; ``action`` is "gs"/"rk" ("-" where the kernel has no action
axis); ``bucket`` buckets the row count to the next power of two (shapes
within a bucket share a winner — the same coarsening every shape-keyed
autotuner applies so one sweep covers a neighborhood of shapes);
``storage_dtype`` is "f32"/"bf16".  The backend/device kind live at the
table level: one file per backend, so interpret-mode CPU timings can
never steer a TPU run.

The fallback contract (DESIGN.md §9): a missing entry means the caller
runs today's hardcoded default, bitwise-unchanged — the table only ever
chooses *which* already-pinned implementation runs, never new arithmetic.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import NamedTuple

SCHEMA_VERSION = 1

#: Repo root — ``TUNE_<backend>.json`` lands next to the BENCH_*.json
#: trail (src/repro/tune/table.py -> three parents up).
REPO_ROOT = Path(__file__).resolve().parents[3]

#: the CSR matvec variant vocabulary ("matvec" kernel choices): the
#: sliced-ELL gather-accumulate kernel, its empty-panel-predicated twin,
#: and the legacy one-hot segment-sum pair kept as the measured contrast
MATVEC_VARIANTS = ("sliced", "sliced_prefetch", "segsum", "segsum_prefetch")

#: the fused-vs-scan vocabulary ("sweep" kernel choices)
SWEEP_ENGINES = ("scan", "fused")


class TuneKey(NamedTuple):
    """One tunable dispatch site: kernel x format x action x shape x dtype."""
    kernel: str         # "sweep" | "matvec" | "panel"
    format: str         # operator class name, e.g. "CsrOp"
    action: str         # "gs" | "rk" | "-" (kernel has no action axis)
    bucket: str         # shape bucket, e.g. "n1024"
    storage_dtype: str  # "f32" | "bf16"

    def render(self) -> str:
        return "/".join(self)

    @classmethod
    def parse(cls, s: str) -> "TuneKey":
        parts = s.split("/")
        if len(parts) != 5:
            raise ValueError(f"malformed tune key: {s!r}")
        return cls(*parts)


def shape_bucket(m: int) -> str:
    """Power-of-two row-count bucket: n=1000 and n=1024 share "n1024".

    Rounding *up* means a bucket's winner was measured at the bucket's
    most expensive shape — conservative for everything else it covers.
    """
    m = max(int(m), 1)
    b = 1
    while b < m:
        b <<= 1
    return f"n{b}"


def storage_key(dtype) -> str:
    """'bf16' for bfloat16 coefficient storage, 'f32' otherwise."""
    return "bf16" if "bfloat16" in str(dtype) else "f32"


def backend_key() -> str:
    """The table file's backend axis (``TUNE_<backend>.json``)."""
    import jax
    return jax.default_backend()


def default_path(backend: str | None = None) -> Path:
    return REPO_ROOT / f"TUNE_{backend or backend_key()}.json"


@dataclass
class TuningTable:
    """In-memory form of ``TUNE_<backend>.json`` (see module docstring)."""

    backend: str = ""
    device_kind: str = ""
    interpret_mode: bool = False
    jax_version: str = ""
    version: int = SCHEMA_VERSION
    #: rendered ``TuneKey`` -> {"choice": str, "wall_us": {candidate: us}}
    entries: dict = field(default_factory=dict)

    @classmethod
    def fresh(cls) -> "TuningTable":
        """An empty table stamped with the current backend identity."""
        import jax
        from repro.kernels.ops import interpret_default
        return cls(backend=backend_key(),
                   device_kind=jax.devices()[0].device_kind,
                   interpret_mode=interpret_default(),
                   jax_version=jax.__version__)

    # -- entry access -------------------------------------------------------

    def record(self, key: TuneKey, choice: str, wall_us: dict) -> None:
        self.entries[key.render()] = {
            "choice": choice,
            "wall_us": {str(k): float(v) for k, v in wall_us.items()}}

    def lookup(self, key: TuneKey) -> str | None:
        """The measured winner for ``key``, or None (-> caller's default)."""
        e = self.entries.get(key.render())
        return None if e is None else e["choice"]

    def choices(self) -> dict[str, str]:
        """key-string -> choice, for round-trip / diff comparisons."""
        return {k: v["choice"] for k, v in sorted(self.entries.items())}

    def merge(self, other: "TuningTable") -> None:
        """Fold ``other``'s entries in (other wins on key collisions)."""
        self.entries.update(other.entries)

    # -- persistence --------------------------------------------------------

    def save(self, path: str | Path | None = None) -> Path:
        path = Path(path) if path is not None else default_path(self.backend)
        payload = {"version": self.version, "backend": self.backend,
                   "device_kind": self.device_kind,
                   "interpret_mode": self.interpret_mode,
                   "jax_version": self.jax_version,
                   "entries": {k: self.entries[k]
                               for k in sorted(self.entries)}}
        path.write_text(json.dumps(payload, indent=2) + "\n")
        return path

    @classmethod
    def load(cls, path: str | Path) -> "TuningTable":
        """Load a persisted table; entries from a different schema version
        are dropped (the keys' meaning may have changed), leaving an empty
        table — which the fallback contract turns into today's defaults.
        """
        raw = json.loads(Path(path).read_text())
        version = int(raw.get("version", 0))
        entries = raw.get("entries", {}) if version == SCHEMA_VERSION else {}
        for k in entries:
            TuneKey.parse(k)  # malformed keys fail loudly at load time
        return cls(backend=raw.get("backend", ""),
                   device_kind=raw.get("device_kind", ""),
                   interpret_mode=bool(raw.get("interpret_mode", False)),
                   jax_version=raw.get("jax_version", ""),
                   version=version, entries=entries)
