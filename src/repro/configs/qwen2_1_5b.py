"""qwen2-1.5b [dense] — 28L d1536 12H (GQA kv=2) ff8960 vocab 151936.

GQA with QKV bias, tied embeddings, RoPE theta 1e6.
[arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    subquadratic=False,
)

RUN = RunConfig(optimizer="adamw", learning_rate=3e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=96, num_heads=3, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512, dtype="float32",
)
