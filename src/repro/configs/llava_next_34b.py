"""llava-next-34b [vlm] — 60L d7168 56H (GQA kv=8) ff20480 vocab 64000.

Yi-34B-style decoder backbone; anyres vision tiling is a STUB:
``input_specs()`` provides (B, 2880, d) precomputed patch embeddings
(24x24 x 5 tiles) spliced over the first positions of the sequence; patch
positions carry no LM target.  56 heads are not divisible by the 16-wide
model axis -> sequence-parallel attention (DESIGN.md §5).
[hf:llava-hf/llava-v1.6-mistral-7b-hf (family); unverified]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    layer_pattern=("attn",),
    rope_theta=5_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    frontend="vision",
    frontend_len=2880,
    subquadratic=False,
)

RUN = RunConfig(optimizer="adafactor", learning_rate=1.5e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512, frontend_len=16, dtype="float32",
)
