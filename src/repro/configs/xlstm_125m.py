"""xlstm-125m [ssm] — 12L d768 4H ff0 vocab 50304; sLSTM + mLSTM blocks.

Block pattern mLSTM:sLSTM = 3:1 with sLSTM at layers [2, 6, 10]
(xLSTM[7:1]-style mostly-mLSTM recipe scaled to 12 layers — DESIGN.md §4).
Blocks carry their own projections (d_ff = 0).  Recurrent state is O(1) in
sequence length -> the arch runs the long_500k cell.
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=("mlstm", "mlstm", "slstm", "mlstm"),
    xlstm_proj_factor=2.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    subquadratic=True,
)

RUN = RunConfig(optimizer="adamw", learning_rate=6e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    vocab_size=512, dtype="float32",
)
