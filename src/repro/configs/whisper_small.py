"""whisper-small [audio] — enc-dec, 12+12L d768 12H (kv=12) ff3072 vocab 51865.

Conv/log-mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed (B, 1500, 768) frame embeddings; a linear adapter marks
the interface.  Decoder uses absolute sinusoidal positions (the published
arch uses learned absolute — sinusoidal avoids a 32k-row table for the
stress shapes; documented deviation, DESIGN.md §4).  prefill/decode at 32k
exceed the published 448 positions and are treated as backbone stress
shapes.  Vocab 51865 is padded to the model axis (DESIGN.md §5).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,             # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern=("attn",),
    rope=False,
    mlp="gelu",
    norm="layernorm",
    encoder_layers=12,
    encoder_len=1500,
    frontend="audio",
    subquadratic=False,
)

RUN = RunConfig(optimizer="adamw", learning_rate=3e-4)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512, encoder_layers=2, encoder_len=64, dtype="float32",
)
