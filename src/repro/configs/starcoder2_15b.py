"""starcoder2-15b [dense] — 40L d6144 48H (GQA kv=4) ff24576 vocab 49152.

GQA + RoPE, non-gated GeLU MLP with biases, LayerNorm (the published arch).
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    layer_pattern=("attn",),
    rope_theta=100_000.0,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
    subquadratic=False,   # published config is full attention -> skip long_500k
)

RUN = RunConfig(optimizer="adafactor", learning_rate=2e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, dtype="float32",
)
