"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden width
    every: int = 1             # MoE FFN on layers where (idx % every == every-1)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router: str = "softmax"    # softmax (top-k renormalized) | sigmoid (llama4)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int                  # dense-FFN hidden (0 => blocks carry their own)
    vocab_size: int

    # Per-layer block kinds, cycled over num_layers (remainder layers are the
    # pattern prefix, unrolled after the scan).  Kinds:
    #   attn | local | chunked | nope | mamba | mlstm | slstm
    layer_pattern: tuple[str, ...] = ("attn",)

    # attention details
    window: int = 0            # sliding window for 'local'
    chunk_size: int = 0        # chunk width for 'chunked'
    rope: bool = True          # False -> absolute sinusoidal at the embedding
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0   # gemma3 dual-theta ('attn' layers)
    qkv_bias: bool = False
    qk_norm: bool = False

    # block / MLP style
    mlp: str = "swiglu"        # swiglu | gelu
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    post_norm: bool = False    # gemma3: extra norms after attn/mlp
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma3: x *= sqrt(d_model) after embedding
    moe: Optional[MoEConfig] = None
    moe_group: int = 512       # token-group size for capacity dispatch

    # ssm (mamba) block
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # xlstm block
    xlstm_proj_factor: float = 2.0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_len: int = 0       # frontend sequence length (e.g. 1500 frames)
    max_position: int = 0      # learned absolute positions if > 0

    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    frontend_len: int = 0      # patches per image (vlm)

    dtype: str = "bfloat16"

    # Whether the arch supports the long_500k cell (sub-quadratic decode).
    subquadratic: bool = False

    def kinds(self) -> tuple[str, ...]:
        """Explicit per-layer block kinds of length num_layers."""
        p = self.layer_pattern
        reps = -(-self.num_layers // len(p))
        return (p * reps)[: self.num_layers]

    @property
    def scan_periods(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def tail_kinds(self) -> tuple[str, ...]:
        return self.kinds()[self.scan_periods * len(self.layer_pattern):]

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch x shape) matrix."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Training-run hyperparameters (launcher-level)."""
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | adamw_bf16 | adafactor
    fsdp: bool = True                # ZeRO-style weight sharding over "data"
    pure_dp: bool = False            # fold the model axis into data (no TP):
                                     # right-sizes parallelism for <~3B models
    microbatches: int = 1            # gradient-accumulation splits
    remat: str = "block"             # none | block
    q_chunk: int = 1024              # attention query-chunk (flash-style)
    loss_chunk: int = 512            # xent chunk (bounds the logits slab)
    scan_unroll: bool = False        # unroll scan-over-layers (dry-run cost fidelity)
    moe_loss_weight: float = 0.01
    # The paper's technique at trainer level: bounded-staleness async DP.
    async_tau: int = 0               # 0 = synchronous
    staleness_damping: bool = True   # apply beta~ = 1/(1+2*rho_hat*tau) LR scale
    grad_compression: str = "none"   # none | int8
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    seed: int = 0

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
