from repro.configs.base import ModelConfig, MoEConfig, RunConfig, SHAPES, ShapeConfig
from repro.configs.registry import (
    ARCH_NAMES,
    Cell,
    all_cells,
    cell,
    get_config,
    get_run_config,
    get_smoke_config,
)

__all__ = [
    "ARCH_NAMES", "Cell", "ModelConfig", "MoEConfig", "RunConfig", "SHAPES",
    "ShapeConfig", "all_cells", "cell", "get_config", "get_run_config",
    "get_smoke_config",
]
