"""granite-34b [dense] — 88L d6144 48H (MQA kv=1) ff24576 vocab 49152.

Llama-architecture code model (GQA degenerate to MQA), full attention.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    layer_pattern=("attn",),
    rope_theta=10_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    subquadratic=False,   # pure full attention -> long_500k skipped (DESIGN.md)
)

RUN = RunConfig(optimizer="adafactor", learning_rate=1.5e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=384, vocab_size=512, dtype="float32",
)
