"""gemma3-1b [dense] — 26L d1152 4H (kv=1, head_dim 256) ff6912 vocab 262144.

5:1 local(1024-window):global attention, dual RoPE theta (10k local / 1M
global), qk-norm, post-norms, tied + scaled embeddings.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig, RunConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    post_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
    subquadratic=True,    # 5:1 local; global layers decode via sharded LSE merge
)

RUN = RunConfig(optimizer="adamw", learning_rate=3e-4)

SMOKE = CONFIG.with_(
    num_layers=8, d_model=96, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512, window=16, dtype="float32",
)
