"""jamba-v0.1-52b [hybrid] — 32L d4096 32H (GQA kv=8) ff14336 vocab 65536,
MoE 16e top-2.

Mamba + attention 1:7 interleave (one attention layer per 8-layer period),
MoE on every 2nd layer (published Jamba block structure); Mamba d_state 16,
d_conv 4, expand 2.  Mamba state is O(1) -> runs long_500k.
[arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, RunConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336, every=2),
    rope=False,            # Jamba uses no positional encoding (Mamba carries order)
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mlp="swiglu",
    norm="rmsnorm",
    subquadratic=True,
)

RUN = RunConfig(optimizer="adafactor", learning_rate=1.5e-4)

SMOKE = CONFIG.with_(
    num_layers=8, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, every=2, capacity_factor=8.0),
    ssm_state=4, dtype="float32",
)
