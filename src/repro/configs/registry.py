"""Architecture registry: ``--arch <id>`` -> (ModelConfig, RunConfig, smoke).

Also owns the (arch x shape) cell matrix with per-cell applicability
(DESIGN.md §4: long_500k runs only for sub-quadratic archs; every assigned
arch has a decoder, so decode shapes always run).
"""
from __future__ import annotations

import importlib
from typing import NamedTuple, Optional

from repro.configs.base import ModelConfig, RunConfig, SHAPES, ShapeConfig

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "granite-34b": "granite_34b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2-1.5b": "qwen2_1_5b",
    "xlstm-125m": "xlstm_125m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-small": "whisper_small",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llava-next-34b": "llava_next_34b",
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_run_config(name: str) -> RunConfig:
    return _module(name).RUN


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


class Cell(NamedTuple):
    arch: str
    shape: ShapeConfig
    runnable: bool
    skip_reason: Optional[str]


def cell(arch: str, shape_name: str) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return Cell(arch, shape, False,
                    "pure full attention — no sub-quadratic mechanism "
                    "(DESIGN.md §4 long-context table)")
    return Cell(arch, shape, True, None)


def all_cells() -> list[Cell]:
    return [cell(a, s) for a in ARCH_NAMES for s in SHAPES]
