"""llama4-maverick-400b-a17b [moe] — 48L d5120 40H (GQA kv=8) ff8192
vocab 202048, MoE 128e top-1 + shared expert, MoE every 2nd layer.

Same attention layout as scout (chunked 3:1 NoPE-global).  128 experts on
alternating layers + dense layers in between ≈ 400B total / ~17B active.
Optimizer = Adafactor with bf16 momentum (AdamW fp32 state would exceed the
16 GB/chip pod budget — DESIGN.md §5).
[hf:meta-llama/Llama-4-Scout-17B-16E (family); unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, RunConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("chunked", "chunked", "chunked", "nope"),
    chunk_size=8192,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192, every=2,
                  shared_expert=True, router="sigmoid"),
    rope_theta=500_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    subquadratic=True,
)

RUN = RunConfig(optimizer="adafactor", learning_rate=1.5e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512, chunk_size=32,
    moe=MoEConfig(num_experts=8, top_k=1, d_ff=128, every=2,
                  shared_expert=True, router="sigmoid", capacity_factor=8.0),
    dtype="float32",
)
