"""llama4-scout-17b-a16e [moe] — 48L d5120 40H (GQA kv=8) ff8192
vocab 202048, MoE 16e top-1 + shared expert, sigmoid router.

Chunked local attention (8192-token chunks) on 3/4 of layers, NoPE global on
every 4th — global layers decode against a sequence-sharded KV cache, so the
arch runs long_500k (DESIGN.md §4).  Early-fusion vision tower is out of
backbone scope (text cells only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, RunConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("chunked", "chunked", "chunked", "nope"),
    chunk_size=8192,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, every=1,
                  shared_expert=True, router="sigmoid"),
    rope_theta=500_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    subquadratic=True,
)

RUN = RunConfig(optimizer="adafactor", learning_rate=2e-4)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512, chunk_size=32,
    moe=MoEConfig(num_experts=4, top_k=1, d_ff=128, every=1,
                  shared_expert=True, router="sigmoid", capacity_factor=8.0),
    dtype="float32",
)
