"""Gradient compression codec: int8 quantization with per-block scales.

Used as an optional wire format for the cross-pod gradient exchange (the
"pod" axis rides DCN, ~25x slower than ICI): quantize -> all-reduce in low
precision -> dequantize.  The codec is error-feedback-free but unbiased-ish
(symmetric stochastic-free rounding); an error-feedback accumulator is
provided for drift-free long runs.

Under pjit we expose the codec as a pair of pure functions applied around
the gradient all-reduce point; the roundtrip is also used by tests to bound
the quantization error (property test: |dequant(quant(g)) - g| <= scale/2).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: Any          # int8 pytree (padded to BLOCK multiples, flattened)
    scales: Any     # fp32 per-block scales
    shapes: Any     # static: original shapes


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def quantize(tree) -> Compressed:
    def leaf(g):
        flat = g.astype(jnp.float32).reshape(-1)
        pad = _pad_len(flat.size)
        flat = jnp.pad(flat, (0, pad - flat.size)).reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        return q, scale[:, 0]

    qs = jax.tree.map(lambda g: leaf(g)[0], tree)
    ss = jax.tree.map(lambda g: leaf(g)[1], tree)
    shapes = jax.tree.map(lambda g: g.shape, tree)
    return Compressed(q=qs, scales=ss, shapes=shapes)


def dequantize(c: Compressed, like):
    def leaf(q, s, g):
        flat = q.astype(jnp.float32) * s[:, None]
        return flat.reshape(-1)[: g.size].reshape(g.shape).astype(g.dtype)

    return jax.tree.map(leaf, c.q, c.scales, like)


def roundtrip(tree):
    """quantize -> dequantize (what the wire does to a gradient)."""
    return dequantize(quantize(tree), tree)


class ErrorFeedback(NamedTuple):
    residual: Any


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_with_feedback(tree, ef: ErrorFeedback):
    """Error-feedback compression: quantize (g + residual), carry the
    quantization error into the next step (Karimireddy et al. style)."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, tree, ef.residual)
    sent = roundtrip(corrected)
    residual = jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, sent)
    return sent, ErrorFeedback(residual=residual)
