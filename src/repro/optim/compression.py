"""Gradient/delta compression codec: int8 quantization with per-block scales.

Used as an optional wire format for two exchanges:

* the cross-pod gradient all-reduce (the "pod" axis rides DCN, ~25x slower
  than ICI): quantize -> all-reduce in low precision -> dequantize;
* the solver engine's compressed collective payloads
  (``core.engine.Schedule(compress=...)``): the RK round delta and the
  banded halo edges travel the wire as int8 blocks + f32 scales (or as a
  plain bf16 round) via the per-array helpers below.

The codec is error-feedback-free but unbiased-ish (symmetric
stochastic-free rounding); an error-feedback accumulator is provided for
drift-free long runs.

Under pjit we expose the codec as a pair of pure functions applied around
the gradient all-reduce point; the roundtrip is also used by tests to bound
the quantization error (property test: |dequant(quant(g)) - g| <= scale/2).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: Any          # int8 pytree (padded to BLOCK multiples, flattened)
    scales: Any     # fp32 per-block scales
    shapes: Any     # static: original shapes


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def _quantize_leaf(g):
    """(q, scales) of one array: int8 blocks of BLOCK with f32 scales."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    flat = jnp.pad(flat, (0, pad - flat.size)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def quantize(tree) -> Compressed:
    """One pass per leaf: q and scales come out of a single ``tree.map``
    (the old two-``tree.map`` form ran ``_quantize_leaf`` twice per leaf,
    doubling the quantization work)."""
    leaves, treedef = jax.tree.flatten(tree)
    pairs = [_quantize_leaf(g) for g in leaves]
    qs = jax.tree.unflatten(treedef, [q for q, _ in pairs])
    ss = jax.tree.unflatten(treedef, [s for _, s in pairs])
    shapes = jax.tree.map(lambda g: g.shape, tree)
    return Compressed(q=qs, scales=ss, shapes=shapes)


def dequantize(c: Compressed, like):
    def leaf(q, s, g):
        flat = q.astype(jnp.float32) * s[:, None]
        return flat.reshape(-1)[: g.size].reshape(g.shape).astype(g.dtype)

    return jax.tree.map(leaf, c.q, c.scales, like)


def roundtrip(tree):
    """quantize -> dequantize (what the wire does to a gradient)."""
    return dequantize(quantize(tree), tree)


# ---------------------------------------------------------------------------
# Per-array helpers — the engine's compressed-sync wire format
# ---------------------------------------------------------------------------
# The distributed engine compresses a single (rows, k) payload inside a jit
# region; these are the single-leaf forms of the codec above (same BLOCK,
# same scale rule) plus the measured error bound theory.py consumes.

def quantize_array(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int8 blocks + f32 per-block scales of one array."""
    return _quantize_leaf(g)


def dequantize_array(q: jax.Array, scales: jax.Array, *, shape,
                     dtype=jnp.float32) -> jax.Array:
    flat = q.astype(jnp.float32) * scales[:, None]
    size = 1
    for d in shape:
        size *= d
    return flat.reshape(-1)[:size].reshape(shape).astype(dtype)


def roundtrip_array(g: jax.Array) -> jax.Array:
    """What the int8 wire does to one array (quantize -> dequantize)."""
    q, s = _quantize_leaf(g)
    return dequantize_array(q, s, shape=g.shape, dtype=g.dtype)


def bf16_roundtrip_array(g: jax.Array) -> jax.Array:
    """What a bf16 wire does to one array (round to bf16, widen back)."""
    return g.astype(jnp.bfloat16).astype(g.dtype)


def quantization_error_bound(g: jax.Array) -> jax.Array:
    """Elementwise worst-case int8 roundtrip error of ``g``: half the
    largest per-block scale (|dequant(quant(g)) - g| <= scale/2).  This is
    the measured bound ``theory.perturbed_factor`` turns into a predicted
    rate penalty."""
    _, scales = _quantize_leaf(g)
    return jnp.max(scales) * 0.5


class ErrorFeedback(NamedTuple):
    residual: Any


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_with_feedback(tree, ef: ErrorFeedback):
    """Error-feedback compression: quantize (g + residual), carry the
    quantization error into the next step (Karimireddy et al. style)."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, tree, ef.residual)
    sent = roundtrip(corrected)
    residual = jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, sent)
    return sent, ErrorFeedback(residual=residual)
