"""Optimizers as pure functions over parameter pytrees.

* AdamW — fp32 (or bf16, for the 400B-class configs) first/second moments.
* Adafactor — factored second moment (rank-1 row/col statistics) for the
  >=100B MoE archs where full AdamW state would not fit a v5e pod
  (DESIGN.md §5 memory budget).

Every optimizer exposes the same triple:
    init(params)                      -> state
    update(grads, state, params, lr)  -> (new_params, new_state)
    state_specs(param_specs)          -> state spec pytree  (for pjit)
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (params, state)
    state_specs: Callable     # (param_specs, abstract_params) -> state specs


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


class _Out(NamedTuple):
    """Per-leaf multi-output marker; never appears inside params trees, so
    tree.map(is_leaf=_Out) can unzip without colliding with tuple nodes."""
    items: tuple


def _unzip(out, n):
    pick = lambda i: jax.tree.map(lambda t: t.items[i], out,
                                  is_leaf=lambda t: isinstance(t, _Out))
    return tuple(pick(i) for i in range(n))


def adamw(*, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(grads, state, params, lr):
        c = state.count + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def leaf(g, m, v, p):
            g = g.astype(state_dtype)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            upd = upd + weight_decay * p.astype(state_dtype)
            new_p = (p.astype(jnp.float32) - lr * upd.astype(jnp.float32)).astype(p.dtype)
            return _Out((new_p, m, v))

        out = jax.tree.map(leaf, grads, state.m, state.v, params)
        new_p, new_m, new_v = _unzip(out, 3)
        return new_p, AdamWState(count=c, m=new_m, v=new_v)

    def state_specs(param_specs, abstract_params=None):
        del abstract_params
        return AdamWState(count=P(), m=param_specs, v=param_specs)

    return Optimizer(init, update, state_specs)


class AdafactorState(NamedTuple):
    count: jax.Array
    m: Any        # bf16 momentum (or None-leaves when disabled)
    vr: Any       # row statistics  shape[:-1]
    vc: Any       # col statistics  shape[:-2] + shape[-1:]
    v: Any        # unfactored second moment for rank<2 leaves


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adafactor(*, b2_decay=0.8, eps=1e-30, clip_threshold=1.0,
              momentum=0.9, momentum_dtype=jnp.bfloat16,
              weight_decay=0.0) -> Optimizer:
    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros((0,))
        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((0,)))
        def vf(p):
            return jnp.zeros((0,)) if _factored(p) else jnp.zeros(p.shape, jnp.float32)
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return AdafactorState(count=jnp.zeros((), jnp.int32), m=m,
                              vr=jax.tree.map(vr, params),
                              vc=jax.tree.map(vc, params),
                              v=jax.tree.map(vf, params))

    def update(grads, state, params, lr):
        c = state.count + 1
        beta2t = 1.0 - c.astype(jnp.float32) ** (-b2_decay)

        def leaf(g, m, vr, vc, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta2t * vr + (1 - beta2t) * g2.mean(-1)
                vc = beta2t * vc + (1 - beta2t) * g2.mean(-2)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                upd = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + eps)
            else:
                v = beta2t * v + (1 - beta2t) * g2
                upd = g32 / (jnp.sqrt(v) + eps)
            rms = jnp.sqrt(jnp.mean(upd * upd) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if momentum:
                m = (momentum * m.astype(jnp.float32) + (1 - momentum) * upd).astype(momentum_dtype)
                upd = m.astype(jnp.float32)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return _Out(((p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, vr, vc, v))

        out = jax.tree.map(leaf, grads, state.m, state.vr, state.vc, state.v, params)
        new_p, new_m, new_vr, new_vc, new_v = _unzip(out, 5)
        return new_p, AdafactorState(count=c, m=new_m, vr=new_vr, vc=new_vc, v=new_v)

    def state_specs(param_specs, abstract_params):
        def vr_spec(s, p):
            return P(*s[:-1]) if _factored(p) else P(None)
        def vc_spec(s, p):
            return P(*(s[:-2] + s[-1:])) if _factored(p) else P(None)
        def v_spec(s, p):
            return P(None) if _factored(p) else s
        as_p = lambda f: jax.tree.map(f, param_specs, abstract_params,
                                      is_leaf=lambda s: isinstance(s, P))
        return AdafactorState(
            count=P(),
            m=param_specs,
            vr=as_p(vr_spec),
            vc=as_p(vc_spec),
            v=as_p(v_spec),
        )

    return Optimizer(init, update, state_specs)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


def warmup_cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = lr * jnp.minimum(1.0, s / max(warmup, 1))
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(s < warmup, warm, cos)
    return schedule
