"""Optimizers + the paper's bounded-staleness asynchronous update."""
from repro.optim.adamw import (
    AdamWState,
    AdafactorState,
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)
from repro.optim.async_update import (
    AsyncGradState,
    async_state_specs,
    init_async_grads,
    push_pop,
    staleness_beta,
)
from repro.optim import compression

__all__ = [
    "AdamWState", "AdafactorState", "Optimizer", "adafactor", "adamw",
    "clip_by_global_norm", "global_norm", "warmup_cosine",
    "AsyncGradState", "async_state_specs", "init_async_grads", "push_pop",
    "staleness_beta", "compression",
]
