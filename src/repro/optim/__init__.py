"""Wire codecs for the distributed solver's sync payloads.

``compression`` holds the block-wise int8 quantizer (+ error feedback)
and bf16 round-to-nearest codec behind ``Schedule.compress``.  The
LLM-template optimizers (adamw/adafactor) and the trainer-level
bounded-staleness gradient ring that used to live here were pruned in
PR 8 — they were unreachable from the solver entry points (see
DESIGN.md "Invariants & static analysis", checker DM1).
"""
from repro.optim import compression

__all__ = ["compression"]
