"""Bounded-staleness asynchronous update — the paper's technique at the
trainer level (DESIGN.md §2, integration level 3).

The paper's scheme: updates computed against an iterate that is at most tau
steps stale still converge, at a rate damped by beta~ = 1/(1 + 2 rho tau)
(Sec. 5).  At cluster scale the analogous mechanism is *delayed gradient
application*: the all-reduce of step t's gradient overlaps the compute of
steps t+1..t+tau, and the (now stale) gradient is applied tau steps late
with a staleness-damped learning rate.  This is the Hogwild lineage the
paper descends from, with the paper's two improvements mapped onto it:

* staleness is *scheduled* (tau is exact, not a measured upper bound), so
  the damping factor is computable in closed form;
* the damping rule is the paper's beta~ with rho replaced by an estimated
  gradient-coupling coefficient rho_hat (default 0.5 — the theoretical
  worst case for normalized gradient cross-correlation).

State carries a tau-slot ring of gradient pytrees (tau is small: 1-4).
``push_pop`` returns the gradient to apply now (the one from tau steps ago)
and the updated ring.  For steps < tau the popped slot is zeros — the
cold-start steps apply nothing, exactly like a pipeline fill.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AsyncGradState(NamedTuple):
    step: jax.Array       # int32
    ring: Any             # pytree with leading tau dim on every leaf


def staleness_beta(tau: int, rho_hat: float = 0.5) -> float:
    """Paper Sec. 5: beta~ = 1/(1 + 2 rho tau)."""
    return 1.0 / (1.0 + 2.0 * rho_hat * tau)


def init_async_grads(params, tau: int) -> AsyncGradState:
    ring = jax.tree.map(
        lambda p: jnp.zeros((tau,) + p.shape, p.dtype), params)
    return AsyncGradState(step=jnp.zeros((), jnp.int32), ring=ring)


def async_state_specs(param_specs, tau: int):
    import jax.sharding as shd
    P = shd.PartitionSpec
    ring = jax.tree.map(lambda s: P(None, *s), param_specs,
                        is_leaf=lambda s: isinstance(s, P))
    return AsyncGradState(step=P(), ring=ring)


def push_pop(state: AsyncGradState, grads):
    """Insert ``grads`` into the ring; return the gradient that is now tau
    steps old (zeros during cold start) and the new state."""
    tau = jax.tree.leaves(state.ring)[0].shape[0]
    slot = jnp.mod(state.step, tau)
    popped = jax.tree.map(lambda r: jax.lax.dynamic_index_in_dim(r, slot, 0, False),
                          state.ring)
    cold = state.step < tau
    popped = jax.tree.map(lambda g: jnp.where(cold, jnp.zeros_like(g), g), popped)
    ring = jax.tree.map(
        lambda r, g: jax.lax.dynamic_update_index_in_dim(r, g.astype(r.dtype), slot, 0),
        state.ring, grads)
    return popped, AsyncGradState(step=state.step + 1, ring=ring)
