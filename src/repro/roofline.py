"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (EXPERIMENTS.md §Roofline):

    T_comp = HLO_FLOPs_per_device / peak_FLOPs
    T_mem  = HLO_bytes_per_device / HBM_bw
    T_coll = sum over collectives of wire_bytes_per_device / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` on the SPMD-partitioned
module (already per-device).  Collective bytes are NOT in cost_analysis —
we parse ``compiled.as_text()`` and model per-device wire traffic of ring
algorithms (g = replica-group size, O = per-device buffer bytes):

    all-gather          O * (g-1)         (O = output bytes / g)
    reduce-scatter      O * (g-1)         (O = output bytes)
    all-reduce          2 * O * (g-1)/g   (reduce-scatter + all-gather)
    all-to-all          O * (g-1)/g
    collective-permute  O

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The roofline table is single-pod (all collectives ride ICI); the multi-pod
dry-run only proves the pod axis shards (DESIGN.md §6).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples sum their components."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)   # replica_groups=[n_groups,group_size]
    if m:
        return int(m.group(2))
    return 1


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                       # per device, ring model
    by_kind: dict = field(default_factory=dict)   # kind -> (count, wire_bytes)

    def add(self, kind: str, wire: float):
        self.wire_bytes += wire
        c, b = self.by_kind.get(kind, (0, 0.0))
        self.by_kind[kind] = (c + 1, b + wire)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum modeled per-device wire bytes over every collective op."""
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue   # -start carries the shapes; -done would double count
        type_str, kind = m.group(1), m.group(2)
        out_bytes = shape_bytes(type_str)
        g = _group_size(line)
        if g <= 1:
            if kind != "collective-permute":
                continue
        if kind == "all-gather":
            wire = out_bytes * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * (g - 1) / max(g, 1)
        elif kind == "all-to-all":
            wire = out_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = out_bytes
        stats.add(kind, wire)
    return stats


@dataclass
class Roofline:
    flops: float              # per device
    mem_bytes: float          # per device
    coll: CollectiveStats
    t_comp: float
    t_mem: float
    t_coll: float
    bottleneck: str
    model_flops: float = 0.0  # 6*N*D (useful) across all devices
    chips: int = 1

    @property
    def t_max(self):
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def useful_ratio(self):
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self):
        """How close the dominant term says we are to the hardware roof for
        the *useful* work: useful_time_at_peak / modeled_step_time."""
        if self.t_max <= 0:
            return 0.0
        useful_t = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_t / self.t_max


def analyze(cost: dict, hlo_text: str, *, chips: int, model_flops: float = 0.0
            ) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    t_comp = flops / PEAK_FLOPS
    t_mem = mem / HBM_BW
    t_coll = coll.wire_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops=flops, mem_bytes=mem, coll=coll, t_comp=t_comp,
                    t_mem=t_mem, t_coll=t_coll, bottleneck=bottleneck,
                    model_flops=model_flops, chips=chips)


def model_flops_train(n_active_params: int, tokens: int) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, batch: int) -> float:
    return 2.0 * n_active_params * batch
