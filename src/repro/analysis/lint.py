"""repro-lint runner: ``python -m repro.analysis.lint``.

Runs every checker over ``src/repro/**`` (the bitwise-pin checker also
covers ``tests/``), diffs the findings against the checked-in baseline
(``lint-baseline.json`` at the repo root), and reports.

Modes
-----
* default            — print every finding (baselined ones marked), exit 0;
* ``--fail-on-new``  — the CI gate: exit 1 iff a finding's key is not in
  the baseline.  Keys are ``code:path:symbol`` (no line numbers), so the
  baseline survives unrelated edits;
* ``--write-baseline`` — regenerate the baseline from the current tree
  (use after fixing findings, to shrink it — never to bury new ones);
* ``--json``         — machine-readable finding dump.

The baseline is for *grandfathered* findings only; each entry carries a
justification string that must explain why the finding is accepted.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import (
    bitwise_pin, dead_modules, dispatch, kernel_precision, pytree_purity,
    trace_safety)
from repro.analysis.common import (
    Finding, iter_py_files, parse_file, rel, repo_root)

#: per-file checkers and the top-level directories they walk
FILE_CHECKERS = (
    (kernel_precision, ("src",)),
    (trace_safety, ("src",)),
    (pytree_purity, ("src",)),
    (bitwise_pin, ("src", "tests")),
)
#: whole-tree checkers (import graphs, cross-file table consistency)
REPO_CHECKERS = (dispatch, dead_modules)

BASELINE_FILE = "lint-baseline.json"


def parse_tree(root: str) -> dict[str, dict[str, tuple]]:
    """``{"src": {relpath: (tree, source)}, "tests": {...}}``."""
    out: dict[str, dict[str, tuple]] = {}
    for top, sub in (("src", os.path.join("src", "repro")), ("tests", "tests")):
        files: dict[str, tuple] = {}
        full = os.path.join(root, sub)
        if os.path.isdir(full):
            for path in iter_py_files(full):
                r = rel(root, path)
                try:
                    files[r] = parse_file(path)
                except SyntaxError as e:  # a syntax error is a finding, not a crash
                    files[r] = (None, "")
                    print(f"repro-lint: cannot parse {r}: {e}", file=sys.stderr)
        out[top] = files
    return out


def run_checkers(root: str) -> list[Finding]:
    trees = parse_tree(root)
    findings: list[Finding] = []
    for checker, scopes in FILE_CHECKERS:
        for scope in scopes:
            for path, (tree, source) in sorted(trees[scope].items()):
                if tree is None:
                    continue
                findings.extend(checker.check_file(path, tree, source))
    src_parsed = {p: ts for p, ts in trees["src"].items() if ts[0] is not None}
    for checker in REPO_CHECKERS:
        findings.extend(checker.check_repo(root, src_parsed))
    findings.sort(key=lambda f: (f.path, f.line, f.code, f.symbol))
    return findings


def load_baseline(path: str) -> dict[str, str]:
    """key -> justification."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return {e["key"]: e.get("justification", "") for e in data["findings"]}


def write_baseline(path: str, findings: list[Finding],
                   old: dict[str, str]) -> None:
    entries = []
    seen = set()
    for f in findings:
        if f.key in seen:
            continue
        seen.add(f.key)
        entries.append({
            "key": f.key,
            "justification": old.get(f.key, "TODO: justify or fix"),
            "message": f.message,
        })
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"findings": sorted(entries, key=lambda e: e["key"])},
                  fh, indent=2, sort_keys=True)
        fh.write("\n")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="solver-aware static analysis for the repro engine")
    ap.add_argument("--root", default=None,
                    help="repository root (default: auto-detected)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline path (default: <root>/{BASELINE_FILE})")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 if any finding is not in the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from the current tree")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else repo_root()
    baseline_path = args.baseline or os.path.join(root, BASELINE_FILE)
    baseline = load_baseline(baseline_path)

    findings = run_checkers(root)

    if args.write_baseline:
        write_baseline(baseline_path, findings, baseline)
        print(f"repro-lint: wrote {len({f.key for f in findings})} baseline "
              f"entries to {rel(root, baseline_path)}")
        return 0

    if args.as_json:
        print(json.dumps([{
            "code": f.code, "path": f.path, "line": f.line,
            "symbol": f.symbol, "message": f.message, "key": f.key,
            "baselined": f.key in baseline,
        } for f in findings], indent=2))
    else:
        for f in findings:
            mark = " [baselined]" if f.key in baseline else ""
            print(f.render() + mark)

    new = [f for f in findings if f.key not in baseline]
    stale = sorted(set(baseline) - {f.key for f in findings})
    if not args.as_json:
        print(f"repro-lint: {len(findings)} finding(s), {len(new)} new, "
              f"{len(findings) - len(new)} baselined, "
              f"{len(stale)} stale baseline entr(y/ies)")
        for key in stale:
            print(f"repro-lint: stale baseline entry (no longer fires, "
                  f"remove it): {key}")

    if args.fail_on_new and new:
        print(f"repro-lint: FAIL — {len(new)} new finding(s) not in "
              f"{rel(root, baseline_path)}; fix them or (with justification) "
              "baseline them", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
