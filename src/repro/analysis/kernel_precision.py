"""Checker KP — the kernel accumulation contract (DESIGN.md §7).

The mixed-precision storage axis (PR 7) holds operator coefficient panels
in bf16 and gather indices in int16, while the iterate, RHS, row norms and
**every accumulator stay f32**.  Inside a Pallas kernel that contract is a
set of local conventions this checker enforces mechanically:

* KP1 — a load from a *coefficient* ref (``vals_ref``/``a_ref``/
  ``tiles_ref``/``data_ref``/``ab_ref``: the possibly-bf16 operand
  stream) reaches arithmetic (``+``/``-``/``*``/``/``/``@``,
  ``jnp.einsum``) without an ``.astype(jnp.float32)`` upcast;
* KP2 — ``jnp.dot`` inside a kernel body without
  ``preferred_element_type=jnp.float32`` (the MXU accumulates in the
  operand dtype otherwise — silent bf16 accumulation);
* KP3 — an explicit low-precision accumulator: ``.astype`` to
  bf16/f16, or ``jnp.zeros(...)`` with a low-precision dtype, written
  into an output ref or used in arithmetic (``.astype(o_ref.dtype)``
  stays legal: the final write-back cast to the iterate's dtype);
* KP4 — a load from an *index* ref (``cols_ref``/``indices_ref``: the
  possibly-int16 column stream) used as a gather index (subscript or
  ``jnp.take``) without an ``.astype(jnp.int32)`` widen.

A "kernel body" is any function passed (directly or through
``functools.partial``) to ``pl.pallas_call`` in the same module, plus the
``pl.when``-decorated closures nested inside it.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.common import Finding, call_name, dotted_name

NAME = "kernel-precision"

COEFF_REF = re.compile(r"^(a|ab|vals?|tiles?|data)_ref$")
INDEX_REF = re.compile(r"^(cols?|indices)_ref$")
LOW_FLOAT_DTYPES = {"jnp.bfloat16", "jnp.float16", "np.float16"}
F32_DTYPES = {"jnp.float32", "np.float32"}
I32_DTYPES = {"jnp.int32", "np.int32"}

# Value tags for the local abstract interpretation.
F32, I32, TAINT_VAL, TAINT_IDX, LOWP, OTHER = (
    "f32", "i32", "taint-val", "taint-idx", "lowp", "other")


def _kernel_functions(tree: ast.AST) -> list[ast.FunctionDef]:
    """Functions handed to ``pl.pallas_call`` in this module.

    Handles the repo's two idioms: ``pl.pallas_call(kernel, ...)`` and
    ``pl.pallas_call(functools.partial(kernel, ...), ...)``.
    """
    names: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) in ("pl.pallas_call", "pallas_call")):
            continue
        if not node.args:
            continue
        fn = node.args[0]
        if (isinstance(fn, ast.Call)
                and call_name(fn) in ("functools.partial", "partial")
                and fn.args):
            fn = fn.args[0]
        name = dotted_name(fn)
        if name:
            names.add(name.split(".")[-1])
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef) and n.name in names]


def _astype_dtype(node: ast.Call) -> str | None:
    """The dotted dtype of an ``<expr>.astype(dtype)`` call, else None."""
    if (isinstance(node.func, ast.Attribute) and node.func.attr == "astype"
            and len(node.args) == 1):
        return dotted_name(node.args[0]) or "<dynamic>"
    return None


class _KernelChecker(ast.NodeVisitor):
    def __init__(self, path: str, fn: ast.FunctionDef):
        self.path = path
        self.fn = fn
        self.findings: list[Finding] = []
        self.env: dict[str, str] = {}
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if COEFF_REF.match(a.arg):
                self.env[a.arg] = TAINT_VAL
            elif INDEX_REF.match(a.arg):
                self.env[a.arg] = TAINT_IDX

    def report(self, code: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.path, line=getattr(node, "lineno", 0),
            symbol=self.fn.name, message=message))

    # -- expression tagging --------------------------------------------
    def tag(self, node: ast.AST) -> str:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, OTHER)
        if isinstance(node, ast.Call):
            dt = _astype_dtype(node)
            if dt is not None:
                # .astype() overrides whatever is inside — but still walk
                # the inner expression for independent violations.
                self.tag_operand_uses(node.func.value)
                if dt in F32_DTYPES:
                    return F32
                if dt in I32_DTYPES:
                    return I32
                if dt in LOW_FLOAT_DTYPES:
                    return LOWP
                return OTHER  # symbolic (o_ref.dtype) — the write-back cast
            return self.visit_call(node)
        if isinstance(node, ast.Subscript):
            # vals[:, None] keeps vals' taint; ref[...] loads the ref's tag.
            base = self.tag(node.value)
            self.check_index(node.slice, node)
            return base
        if isinstance(node, ast.BinOp):
            return self.tag_binop(node)
        if isinstance(node, ast.UnaryOp):
            return self.tag(node.operand)
        if isinstance(node, ast.Compare):
            for c in (node.left, *node.comparators):
                self.tag(c)
            return OTHER
        if isinstance(node, ast.Constant):
            return OTHER
        if isinstance(node, (ast.Tuple, ast.List)):
            for e in node.elts:
                self.tag(e)
            return OTHER
        return OTHER

    def tag_operand_uses(self, node: ast.AST) -> None:
        """Visit an expression for side findings without consuming it as
        an arithmetic operand."""
        if isinstance(node, ast.Subscript):
            self.tag(node)
        elif isinstance(node, (ast.Call, ast.BinOp)):
            self.tag(node)

    def tag_binop(self, node: ast.BinOp) -> str:
        lt, rt = self.tag(node.left), self.tag(node.right)
        for side, t in ((node.left, lt), (node.right, rt)):
            if t == TAINT_VAL:
                self.report(
                    "KP1", side,
                    "coefficient-ref value reaches arithmetic without "
                    ".astype(jnp.float32) — bf16 storage would accumulate "
                    "in bf16")
            if t == LOWP:
                self.report(
                    "KP3", side,
                    "explicitly low-precision value used in arithmetic — "
                    "kernel accumulators must stay f32")
        if LOWP in (lt, rt):
            return LOWP
        if F32 in (lt, rt):
            return F32
        return OTHER

    def check_index(self, index_expr: ast.AST, ctx: ast.AST) -> None:
        for sub in ast.walk(index_expr if isinstance(index_expr, ast.AST)
                            else ast.Constant(value=None)):
            if isinstance(sub, ast.Name) and self.env.get(sub.id) == TAINT_IDX:
                self.report(
                    "KP4", ctx,
                    f"index-ref value {sub.id!r} used as a gather index "
                    "without .astype(jnp.int32) — int16 storage must widen "
                    "before indexing")

    def visit_call(self, node: ast.Call) -> str:
        name = call_name(node)
        if name in ("jnp.dot", "jax.numpy.dot"):
            pet = next((kw.value for kw in node.keywords
                        if kw.arg == "preferred_element_type"), None)
            if pet is None or dotted_name(pet) not in F32_DTYPES:
                self.report(
                    "KP2", node,
                    "jnp.dot inside a kernel without preferred_element_type="
                    "jnp.float32 — the MXU would accumulate in the operand "
                    "dtype")
            for a in node.args:
                t = self.tag(a)
                if t == LOWP:
                    self.report("KP3", a,
                                "explicitly low-precision jnp.dot operand")
            return F32
        if name in ("jnp.einsum", "jax.numpy.einsum"):
            for a in node.args[1:]:
                if self.tag(a) == TAINT_VAL:
                    self.report(
                        "KP1", a,
                        "coefficient-ref value reaches jnp.einsum without "
                        ".astype(jnp.float32)")
            return F32
        if name in ("jnp.take", "jax.numpy.take"):
            if node.args:
                self.tag(node.args[0])
            if len(node.args) > 1:
                self.check_index(node.args[1], node)
                self.tag(node.args[1])
            return OTHER
        if name in ("jnp.zeros", "jnp.full", "jnp.ones", "jnp.empty"):
            dts = [dotted_name(kw.value) for kw in node.keywords
                   if kw.arg == "dtype"]
            dts += [dotted_name(a) for a in node.args[1:]]
            if any(dt in LOW_FLOAT_DTYPES for dt in dts):
                return LOWP
            return OTHER
        for a in (*node.args, *(kw.value for kw in node.keywords)):
            self.tag(a)
        return OTHER

    # -- statements ----------------------------------------------------
    def run(self) -> list[Finding]:
        self.block(self.fn.body)
        return self.findings

    def block(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            self.stmt(st)

    def stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                and isinstance(st.targets[0], ast.Name):
            self.env[st.targets[0].id] = self.tag(st.value)
        elif isinstance(st, ast.Assign) and len(st.targets) == 1 \
                and isinstance(st.targets[0], ast.Subscript):
            self.assign_subscript(st.targets[0], st.value)
        elif isinstance(st, ast.AugAssign):
            t = self.tag(st.value)
            if isinstance(st.target, ast.Name):
                cur = self.env.get(st.target.id, OTHER)
                if TAINT_VAL in (cur, t):
                    self.report(
                        "KP1", st,
                        "augmented accumulate with a coefficient-ref operand "
                        "lacking .astype(jnp.float32)")
                if LOWP in (cur, t):
                    self.report("KP3", st,
                                "augmented accumulate on a low-precision value")
            elif isinstance(st.target, ast.Subscript):
                self.assign_subscript(st.target, st.value)
        elif isinstance(st, ast.For):
            self.tag(st.iter)
            self.block(st.body)
        elif isinstance(st, (ast.If, ast.While)):
            self.tag(st.test)
            self.block(st.body)
            self.block(st.orelse)
        elif isinstance(st, ast.FunctionDef):
            # pl.when closures share the enclosing env.
            self.block(st.body)
        elif isinstance(st, ast.Expr):
            self.tag(st.value)
        elif isinstance(st, ast.Return) and st.value is not None:
            self.tag(st.value)

    def assign_subscript(self, target: ast.Subscript, value: ast.AST) -> None:
        t = self.tag(value)
        self.check_index(target.slice, target)
        if t == LOWP:
            self.report(
                "KP3", target,
                "write of an explicitly low-precision value into a kernel "
                "output ref — accumulators and outputs must stay f32 (cast "
                "with .astype(o_ref.dtype) only)")
        if t == TAINT_VAL:
            self.report(
                "KP1", target,
                "raw coefficient-ref value written to an output ref without "
                ".astype(jnp.float32)")


def check_file(path: str, tree: ast.AST, source: str) -> list[Finding]:
    findings: list[Finding] = []
    for fn in _kernel_functions(tree):
        findings.extend(_KernelChecker(path, fn).run())
    return findings
