"""Checker PT — registered-pytree aux-data purity.

jax hashes a pytree's aux_data to decide whether two trees share a
treedef (and hence whether a jitted call hits the compile cache).  The
operator classes therefore must keep *static* metadata (shapes, band
offsets, panel geometry) in aux_data and *array* payloads in the leaves
— never the other way around:

* PT1 — a class decorated with ``@register_pytree_node_class`` missing
  ``tree_flatten`` or ``tree_unflatten`` (or a class defining both but
  never registered);
* PT2 — an unhashable literal (list / dict / set display) in the
  aux_data position of ``tree_flatten``'s return;
* PT3 — an array constructor (``jnp.*`` / ``np.array`` / ``np.asarray``
  / ``np.zeros`` …) feeding aux_data: arrays are unhashable, and a
  traced value there leaks tracers out of jit;
* PT4 — the same ``self.<attr>`` appearing in both the leaves and the
  aux_data of one ``tree_flatten`` (double-counted state: unflatten
  cannot round-trip it consistently).
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, call_name, dotted_name

NAME = "pytree-purity"

ARRAY_CALL_PREFIXES = ("jnp.", "jax.numpy.", "jax.")
ARRAY_NP_CALLS = {
    "np.array", "np.asarray", "np.zeros", "np.ones", "np.full", "np.empty",
    "np.arange", "numpy.array", "numpy.asarray",
}


def _registered_classes(tree: ast.AST) -> tuple[list[ast.ClassDef], list[ast.ClassDef]]:
    """(registered, defines-flatten-but-unregistered) class defs."""
    registered, unregistered = [], []
    explicit: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and call_name(node) in ("register_pytree_node",
                                        "jax.tree_util.register_pytree_node",
                                        "tree_util.register_pytree_node") \
                and node.args:
            name = dotted_name(node.args[0])
            if name:
                explicit.add(name.split(".")[-1])
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        decos = {dotted_name(d) for d in node.decorator_list}
        decos |= {call_name(d) for d in node.decorator_list
                  if isinstance(d, ast.Call)}
        is_reg = bool(decos & {"register_pytree_node_class",
                               "jax.tree_util.register_pytree_node_class",
                               "tree_util.register_pytree_node_class"}) \
            or node.name in explicit
        has_flatten = any(isinstance(m, ast.FunctionDef)
                          and m.name == "tree_flatten" for m in node.body)
        if is_reg:
            registered.append(node)
        elif has_flatten:
            unregistered.append(node)
    return registered, unregistered


def _self_attrs(node: ast.AST) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) \
                and isinstance(sub.value, ast.Name) and sub.value.id == "self":
            out.add(sub.attr)
    return out


def _resolve(name_node: ast.AST, env: dict[str, ast.AST]) -> ast.AST:
    seen = set()
    while isinstance(name_node, ast.Name) and name_node.id in env \
            and name_node.id not in seen:
        seen.add(name_node.id)
        name_node = env[name_node.id]
    return name_node


class _FlattenChecker:
    def __init__(self, path: str, cls: ast.ClassDef, fn: ast.FunctionDef):
        self.path, self.cls, self.fn = path, cls, fn
        self.findings: list[Finding] = []

    def report(self, code: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.path, line=getattr(node, "lineno", 0),
            symbol=f"{self.cls.name}.tree_flatten", message=message))

    def run(self) -> list[Finding]:
        env: dict[str, ast.AST] = {}
        returns: list[ast.Return] = []
        for st in ast.walk(self.fn):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name):
                env[st.targets[0].id] = st.value
            elif isinstance(st, ast.Return) and st.value is not None:
                returns.append(st)
        for ret in returns:
            val = _resolve(ret.value, env)
            if not (isinstance(val, ast.Tuple) and len(val.elts) == 2):
                continue
            leaves = _resolve(val.elts[0], env)
            aux = _resolve(val.elts[1], env)
            self.check_aux(aux, env)
            both = _self_attrs(leaves) & _self_attrs(aux)
            for attr in sorted(both):
                self.report(
                    "PT4", ret,
                    f"self.{attr} appears in both the leaves and the "
                    "aux_data — unflatten cannot round-trip double-counted "
                    "state")
        return self.findings

    def check_aux(self, aux: ast.AST, env: dict[str, ast.AST]) -> None:
        nodes = [aux]
        if isinstance(aux, ast.Tuple):
            nodes = [_resolve(e, env) for e in aux.elts]
        for el in nodes:
            for sub in ast.walk(el):
                if isinstance(sub, (ast.List, ast.Dict, ast.Set)):
                    self.report(
                        "PT2", sub,
                        "unhashable literal (list/dict/set) in aux_data — "
                        "jax hashes aux_data for treedef equality and the "
                        "jit cache; use tuples")
                elif isinstance(sub, ast.Call):
                    cn = call_name(sub) or ""
                    if cn in ARRAY_NP_CALLS or any(
                            cn.startswith(p) for p in ARRAY_CALL_PREFIXES):
                        self.report(
                            "PT3", sub,
                            f"array constructor {cn}() feeding aux_data — "
                            "arrays are unhashable and traced values there "
                            "leak tracers; arrays belong in the leaves")


def check_file(path: str, tree: ast.AST, source: str) -> list[Finding]:
    findings: list[Finding] = []
    registered, unregistered = _registered_classes(tree)
    for cls in registered:
        methods = {m.name: m for m in cls.body
                   if isinstance(m, ast.FunctionDef)}
        missing = [m for m in ("tree_flatten", "tree_unflatten")
                   if m not in methods]
        if missing:
            findings.append(Finding(
                code="PT1", path=path, line=cls.lineno, symbol=cls.name,
                message=("registered pytree class is missing "
                         + " and ".join(missing))))
        if "tree_flatten" in methods:
            findings.extend(
                _FlattenChecker(path, cls, methods["tree_flatten"]).run())
    for cls in unregistered:
        findings.append(Finding(
            code="PT1", path=path, line=cls.lineno, symbol=cls.name,
            message=("class defines tree_flatten but is never registered "
                     "(missing @register_pytree_node_class?) — jit would "
                     "treat instances as static leaves")))
    return findings
