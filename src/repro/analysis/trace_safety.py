"""Checker TS — trace safety inside jitted / shard_mapped regions.

A "traced region" is any function that jax will trace: decorated with
``@jax.jit`` / ``@partial(jax.jit, ...)``, passed to ``jax.jit(...)`` or
``shard_map(...)``, or handed to a ``lax`` control-flow combinator
(``scan`` / ``fori_loop`` / ``while_loop`` / ``cond`` / ``switch``) —
plus every function nested inside one (workers, scan bodies).

* TS1 — host clock inside a traced region (``time.time()``,
  ``time.perf_counter()``, ``datetime.now()`` …): the value is burned in
  at trace time and silently constant afterwards;
* TS2 — host RNG inside a traced region (legacy global ``np.random.*``
  or an unseeded ``np.random.default_rng()``): same burn-in problem,
  plus nondeterminism across processes — solvers must thread
  ``jax.random`` keys or seeded host generators built *outside* jit;
* TS3 — a Python ``if``/``while`` on a traced value: the branch is
  resolved once at trace time.  Values are *static* when they derive
  from ``static_argnames`` parameters, module constants, shape/dtype
  attributes, ``is None`` / ``isinstance`` / ``hasattr`` / ``len`` /
  ``callable`` tests, or literals; everything reachable from a
  non-static parameter is traced.

TS3 deliberately whitelists the engine's established static patterns
(``if xs_full is not None``, ``if have_xs``, ``isinstance(op, EllOp)``)
by propagating staticness through local assignments.
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, call_name, dotted_name

NAME = "trace-safety"

CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.process_time",
    "time.time_ns", "time.perf_counter_ns", "time.monotonic_ns",
    "datetime.now", "datetime.datetime.now", "datetime.utcnow",
    "datetime.datetime.utcnow",
}
JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}
SHARD_MAP_NAMES = {"shard_map", "jax.experimental.shard_map.shard_map"}
LAX_BODY_TAKERS = {
    "lax.scan": (0,), "jax.lax.scan": (0,),
    "lax.fori_loop": (2,), "jax.lax.fori_loop": (2,),
    "lax.while_loop": (0, 1), "jax.lax.while_loop": (0, 1),
    "lax.cond": (1, 2), "jax.lax.cond": (1, 2),
    "lax.switch": (1,), "jax.lax.switch": (1,),
    "lax.map": (0,), "jax.lax.map": (0,),
}
# Tests on a value that are static even when the value is traced.
STATIC_TESTS = {"isinstance", "hasattr", "len", "callable", "type", "getattr"}
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "at"}


def _module_str_tuples(tree: ast.AST) -> dict[str, set[str]]:
    """Module-level ``NAME = ("a", "b", ...)`` constants (jit wrappers
    share static_argnames through them)."""
    out: dict[str, set[str]] = {}
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            vals = {e.value for e in node.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)}
            if vals:
                out[node.targets[0].id] = vals
    return out


def _static_argnames(deco: ast.Call, consts: dict[str, set[str]]) -> set[str]:
    for kw in deco.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            v = kw.value
            if isinstance(v, ast.Name):
                return set(consts.get(v.id, ()))
            names: set[str] = set()
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    names.add(e.value)
            return names
    return set()


def _jit_regions(tree: ast.AST) -> list[tuple[ast.FunctionDef, set[str]]]:
    """(function, static-param-names) for every traced-region root."""
    regions: dict[str, tuple[ast.FunctionDef, set[str]]] = {}
    consts = _module_str_tuples(tree)
    by_name = {n.name: n for n in ast.walk(tree)
               if isinstance(n, ast.FunctionDef)}

    for fn in by_name.values():
        for deco in fn.decorator_list:
            d = dotted_name(deco)
            if d in JIT_NAMES:
                regions[fn.name] = (fn, set())
            elif isinstance(deco, ast.Call):
                cd = call_name(deco)
                if cd in JIT_NAMES:
                    regions[fn.name] = (fn, _static_argnames(deco, consts))
                elif cd in ("functools.partial", "partial") and deco.args \
                        and dotted_name(deco.args[0]) in JIT_NAMES:
                    regions[fn.name] = (fn, _static_argnames(deco, consts))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        cn = call_name(node)
        if cn in JIT_NAMES and node.args:
            target = dotted_name(node.args[0])
            if target in by_name:
                regions[target] = (by_name[target],
                                   _static_argnames(node, consts))
        elif cn in SHARD_MAP_NAMES and node.args:
            target = dotted_name(node.args[0])
            if target in by_name:
                regions[target] = (by_name[target], set())
        elif cn in LAX_BODY_TAKERS:
            for i in LAX_BODY_TAKERS[cn]:
                if i < len(node.args):
                    target = dotted_name(node.args[i])
                    if target in by_name:
                        regions[target] = (by_name[target], set())
    return list(regions.values())


class _RegionChecker(ast.NodeVisitor):
    """Walks one traced-region function (and everything nested in it)."""

    def __init__(self, path: str, fn: ast.FunctionDef, static: set[str]):
        self.path = path
        self.fn = fn
        self.findings: list[Finding] = []
        args = fn.args
        params = [a.arg for a in
                  (args.posonlyargs + args.args + args.kwonlyargs)]
        self.traced: set[str] = {p for p in params if p not in static}

    def report(self, code: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.path, line=getattr(node, "lineno", 0),
            symbol=self.fn.name, message=message))

    # -- staticness ----------------------------------------------------
    def is_static(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id not in self.traced
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Compare):
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return True  # `x is None` is a trace-time structural test
            return all(self.is_static(n)
                       for n in (node.left, *node.comparators))
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BinOp):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.Attribute):
            return node.attr in STATIC_ATTRS or self.is_static(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value)
        if isinstance(node, ast.Call):
            cn = call_name(node)
            if cn is None:
                return False
            if cn.split(".")[-1] in STATIC_TESTS:
                return True
            # a method call on a traced object (x.sum(), x.any()) is traced
            # no matter its arguments
            if isinstance(node.func, ast.Attribute) \
                    and not self.is_static(node.func.value):
                return False
            return all(self.is_static(a) for a in node.args)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self.is_static(e) for e in node.elts)
        return False

    # -- traversal -----------------------------------------------------
    def run(self) -> list[Finding]:
        self.block(self.fn.body)
        return self.findings

    def block(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            self.stmt(st)

    def stmt(self, st: ast.stmt) -> None:
        self.scan_calls(st)
        if isinstance(st, ast.Assign):
            static = self.is_static(st.value)
            for tgt in st.targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        if static:
                            self.traced.discard(n.id)
                        else:
                            self.traced.add(n.id)
        elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
            if st.value is not None and isinstance(st.target, ast.Name) \
                    and not self.is_static(st.value):
                self.traced.add(st.target.id)
        elif isinstance(st, (ast.If, ast.While)):
            if not self.is_static(st.test):
                kind = "while" if isinstance(st, ast.While) else "if"
                self.report(
                    "TS3", st,
                    f"Python `{kind}` on a traced value inside a traced "
                    "region — the branch is resolved once at trace time; "
                    "use lax.cond/jnp.where or hoist the decision to a "
                    "static argument")
            self.block(st.body)
            self.block(st.orelse)
        elif isinstance(st, ast.For):
            # Python loops over traced values fail loudly in jax; loops
            # over ranges are static unrolls.  Only recurse.
            self.block(st.body)
        elif isinstance(st, ast.FunctionDef):
            for a in (st.args.posonlyargs + st.args.args
                      + st.args.kwonlyargs):
                self.traced.add(a.arg)  # nested fns get traced operands
            self.block(st.body)
        elif isinstance(st, (ast.With,)):
            self.block(st.body)
        elif isinstance(st, ast.Try):
            self.block(st.body)
            for h in st.handlers:
                self.block(h.body)
            self.block(st.orelse)
            self.block(st.finalbody)

    def scan_calls(self, st: ast.stmt) -> None:
        if isinstance(st, (ast.If, ast.While, ast.For, ast.FunctionDef,
                           ast.With, ast.Try)):
            # bodies handled by recursion; only look at the header expr
            headers: list[ast.AST] = []
            if isinstance(st, (ast.If, ast.While)):
                headers = [st.test]
            elif isinstance(st, ast.For):
                headers = [st.iter]
            elif isinstance(st, ast.With):
                headers = [it.context_expr for it in st.items]
            nodes: list[ast.AST] = []
            for h in headers:
                nodes.extend(ast.walk(h))
        else:
            nodes = list(ast.walk(st))
        for node in nodes:
            if not isinstance(node, ast.Call):
                continue
            cn = call_name(node)
            if cn in CLOCK_CALLS:
                self.report(
                    "TS1", node,
                    f"host clock {cn}() inside a traced region — the value "
                    "is captured once at trace time; time outside jit and "
                    "pass it in")
            elif cn and cn.startswith("np.random.") or \
                    cn and cn.startswith("numpy.random."):
                fn_leaf = cn.split(".")[-1]
                if fn_leaf == "default_rng" and node.args:
                    continue  # seeded generator construction is fine
                self.report(
                    "TS2", node,
                    f"host RNG {cn}() inside a traced region — burned in "
                    "at trace time and nondeterministic across processes; "
                    "thread a jax.random key (or a seeded Generator built "
                    "outside jit)")


def check_file(path: str, tree: ast.AST, source: str) -> list[Finding]:
    findings: list[Finding] = []
    for fn, static in _jit_regions(tree):
        findings.extend(_RegionChecker(path, fn, static).run())
    return findings
