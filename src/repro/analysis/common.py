"""Shared infrastructure for the repro-lint checkers.

A checker is a module exposing

* ``NAME``   — the checker's slug (finding codes are ``<NAME><digit>``);
* ``check_file(path, tree, source) -> list[Finding]`` for per-file
  checkers, and/or ``check_repo(root) -> list[Finding]`` for whole-tree
  checkers (import graphs, cross-file table consistency);

and a :class:`Finding` is one violation.  Findings carry a *stable key*
(checker code + path + symbol, no line numbers) so the checked-in
baseline survives unrelated edits to the same file.

Everything here is stdlib-only on purpose: the lint pass must run in the
bare CI lint job (no jax), and importing the solver would defeat the
point of analyzing it statically.
"""
from __future__ import annotations

import ast
import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation.

    ``symbol`` names the offending definition (function, class, module,
    table entry) — together with ``code`` and ``path`` it forms the
    baseline key, deliberately excluding line numbers so a baseline entry
    survives edits elsewhere in the file.
    """
    code: str        # e.g. "KP1"
    path: str        # repo-relative, forward slashes
    line: int        # 1-based, for display only (not part of the key)
    symbol: str      # owning function/class/module
    message: str

    @property
    def key(self) -> str:
        return f"{self.code}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{self.symbol}] {self.message}"


def repo_root(start: str | None = None) -> str:
    """The repository root: the nearest ancestor holding ``src/repro``.

    Walks up from ``start`` (default: this file's location), so the lint
    pass finds its tree whether invoked from the repo root, from ``src``,
    or as an installed module in a checkout.
    """
    d = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise FileNotFoundError(
                "could not locate the repository root (no src/repro above "
                f"{start or os.path.dirname(__file__)!r})")
        d = parent


def rel(root: str, path: str) -> str:
    return os.path.relpath(path, root).replace(os.sep, "/")


def iter_py_files(*dirs: str) -> list[str]:
    """All ``.py`` files under the given directories, sorted, skipping
    caches and hidden directories."""
    out = []
    for d in dirs:
        for base, subdirs, files in os.walk(d):
            subdirs[:] = sorted(s for s in subdirs
                                if s != "__pycache__" and not s.startswith("."))
            out.extend(os.path.join(base, f) for f in sorted(files)
                       if f.endswith(".py"))
    return out


def parse_file(path: str) -> tuple[ast.AST, str]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    return ast.parse(source, filename=path), source


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def const_str_tuple(node: ast.AST) -> tuple[str, ...] | None:
    """The value of a tuple/list/set display of string constants, or a
    ``frozenset({...})`` / ``frozenset((...))`` call around one."""
    if (isinstance(node, ast.Call) and call_name(node) == "frozenset"
            and len(node.args) == 1):
        node = node.args[0]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        vals = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant) and isinstance(elt.value, str)):
                return None
            vals.append(elt.value)
        return tuple(vals)
    return None


def module_name_for(root: str, path: str) -> str | None:
    """Dotted module name of a file under ``<root>/src``."""
    r = rel(root, path)
    if not r.startswith("src/"):
        return None
    mod = r[len("src/"):-len(".py")].replace("/", ".")
    if mod.endswith(".__init__"):
        mod = mod[: -len(".__init__")]
    return mod


def direct_imports(tree: ast.AST, package_prefix: str = "repro") -> set[str]:
    """Every ``package_prefix``-rooted module a tree imports.

    ``from repro.x import y`` contributes ``repro.x`` and — because ``y``
    may itself be a submodule — ``repro.x.y``; the graph consumer keeps
    only names that exist as modules.
    """
    found: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == package_prefix:
                    found.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] == package_prefix:
                found.add(node.module)
                for alias in node.names:
                    found.add(f"{node.module}.{alias.name}")
    return found
