"""Checker DM — modules unreachable from the solver entry points.

The repo began from an LLM-training template; PRs 1-7 grew the solver
(core / kernels / launch.solve / launch.lsq / optim.compression) while
the template's ``models/`` / ``train/`` / ``data/`` stack sat untouched.
Dead modules are not free: they import-cycle into real code during
refactors, show up in grep, and rot silently (the PR-6 crash sweep
started in exactly such a leftover).

Reachability is computed over the ``repro.*`` import graph:

* roots — the solver surface (``repro.core``, ``repro.kernels``,
  ``repro.launch.solve``, ``repro.launch.lsq``, ``repro.launch.mesh``,
  ``repro.launch.serve``, ``repro.serve``, ``repro.optim``,
  ``repro.compat``, ``repro.analysis.lint``, ``repro.tune.autotune``
  — the autotune CLI is the sweep entry point) **plus**
  every ``repro.*`` module imported by ``benchmarks/`` or ``examples/``
  scripts — including imports inside their embedded subprocess-script
  strings (the product surface keeps a module alive; tests do *not* —
  a module only tests import is dead code with a test suite attached);
* an edge ``a -> b`` when module ``a`` imports ``b`` (``import`` /
  ``from`` forms, including ``from pkg import submodule``); importing a
  package reaches its ``__init__``.

* DM1 — a ``src/repro`` module not reachable from any root.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.common import (
    Finding, direct_imports, iter_py_files, module_name_for, parse_file, rel)

NAME = "dead-modules"

ROOT_MODULES = (
    "repro.core",
    "repro.kernels",
    "repro.launch.solve",
    "repro.launch.lsq",
    "repro.launch.mesh",
    "repro.launch.serve",
    "repro.serve",
    "repro.optim",
    "repro.compat",
    "repro.analysis.lint",
    "repro.tune.autotune",
)
SCRIPT_DIRS = ("benchmarks", "examples")

#: imports inside embedded subprocess-script strings (the forced-device
#: benchmark pattern pipes `from repro import roofline` through a string)
_STR_IMPORT = re.compile(
    r"^\s*(?:from\s+(repro(?:\.\w+)*)\s+import\s+([\w, ]+)"
    r"|import\s+(repro(?:\.\w+)*))", re.MULTILINE)


def _string_imports(tree: ast.AST) -> set[str]:
    found: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and "repro" in node.value:
            for m in _STR_IMPORT.finditer(node.value):
                if m.group(3):
                    found.add(m.group(3))
                else:
                    found.add(m.group(1))
                    for name in m.group(2).split(","):
                        name = name.strip().split(" as ")[0]
                        if name.isidentifier():
                            found.add(f"{m.group(1)}.{name}")
    return found


def _resolve(name: str, modules: set[str]) -> str | None:
    """Map an imported dotted name to an existing module (walking up
    through attribute accesses: ``repro.core.engine.solve`` -> engine)."""
    parts = name.split(".")
    while parts:
        cand = ".".join(parts)
        if cand in modules:
            return cand
        parts.pop()
    return None


def check_repo(root: str, parsed: dict[str, tuple[ast.AST, str]]
               ) -> list[Finding]:
    modules: dict[str, str] = {}   # dotted name -> repo-relative path
    imports: dict[str, set[str]] = {}
    for path, (tree, _src) in parsed.items():
        mod = module_name_for(root, os.path.join(root, path))
        if mod is None:
            continue
        modules[mod] = path
        imports[mod] = direct_imports(tree)

    known = set(modules)
    graph: dict[str, set[str]] = {}
    for mod, raw in imports.items():
        edges = set()
        for name in raw:
            tgt = _resolve(name, known)
            if tgt is not None:
                edges.add(tgt)
        # a submodule implicitly executes its package __init__ chain
        parts = mod.split(".")
        for i in range(1, len(parts)):
            pkg = ".".join(parts[:i])
            if pkg in known:
                edges.add(pkg)
        graph[mod] = edges

    roots: set[str] = set()
    for r in ROOT_MODULES:
        if r in known:
            roots.add(r)
    for d in SCRIPT_DIRS:
        full = os.path.join(root, d)
        if not os.path.isdir(full):
            continue
        for path in iter_py_files(full):
            try:
                tree, _src = parse_file(path)
            except SyntaxError:
                continue
            for name in direct_imports(tree) | _string_imports(tree):
                tgt = _resolve(name, known)
                if tgt is not None:
                    roots.add(tgt)

    reached: set[str] = set()
    frontier = sorted(roots)
    while frontier:
        mod = frontier.pop()
        if mod in reached:
            continue
        reached.add(mod)
        frontier.extend(graph.get(mod, ()))
        # reaching a package reaches its __init__ only; reaching a module
        # also reaches its enclosing packages (handled via graph edges).

    findings = []
    for mod in sorted(known - reached):
        findings.append(Finding(
            code="DM1", path=modules[mod], line=1, symbol=mod,
            message=(f"module {mod} is unreachable from the solver entry "
                     f"points ({', '.join(r for r in ROOT_MODULES if r in known)}) "
                     "and from benchmarks/ and examples/ — prune it or wire "
                     "it into the product surface")))
    return findings


def unreachable_modules(root: str | None = None) -> list[str]:
    """Convenience API for tests: the dotted names DM1 would flag."""
    from repro.analysis.common import repo_root
    root = root or repo_root()
    parsed = {}
    for path in iter_py_files(os.path.join(root, "src", "repro")):
        try:
            tree, src = parse_file(path)
        except SyntaxError:
            continue
        parsed[rel(root, path)] = (tree, src)
    return [f.symbol for f in check_repo(root, parsed)]
