"""repro-lint: solver-aware static analysis for the engine's contracts.

Seven PRs in, the engine's correctness rests on conventions — f32
accumulation inside every Pallas kernel, exhaustive strategy-table
coverage, pytree aux-data purity, trace safety inside jitted regions,
"bitwise-pinned" test claims — that used to be enforced only by review.
This package checks them mechanically (DESIGN.md §8):

* ``repro.analysis.lint``          — the runner (``python -m
  repro.analysis.lint``), baseline handling, ``--fail-on-new`` CI gate;
* ``repro.analysis.kernel_precision`` — kernel accumulation contract;
* ``repro.analysis.dispatch``      — strategy-table exhaustiveness and
  single-source-of-truth capability sets;
* ``repro.analysis.pytree_purity`` — registered-pytree aux-data purity;
* ``repro.analysis.trace_safety``  — no host time / host RNG / Python
  branches on traced values inside jitted or shard_mapped code;
* ``repro.analysis.bitwise_pin``   — tests claiming "bitwise" must
  compare exactly, not via ``allclose``;
* ``repro.analysis.dead_modules``  — modules unreachable from the solver
  entry points.

The checkers are pure-AST (no jax import, no code execution), so the
pass runs anywhere Python runs — including the bare CI lint job.
"""
from repro.analysis.common import Finding

__all__ = ["Finding"]
