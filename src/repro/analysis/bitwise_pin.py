"""Checker BP — the bitwise-pin contract.

The engine's refactors are routinely *pinned bitwise* against a
reference implementation (fused sweep vs scan engine, overlap vs plain
sync, a2a vs psum).  A test that says "bitwise" but compares with
``allclose`` would silently keep passing after the pin is broken —
precisely the drift the pin exists to catch:

* BP1 — a test whose name or docstring claims "bitwise" calls
  ``allclose`` / ``assert_allclose`` with nonzero tolerances (no
  ``rtol=0, atol=0``);
* BP2 — a bitwise-claiming test with no exact comparison at all (no
  ``array_equal`` / ``assert_array_equal`` / ``==``-on-arrays reduction
  anywhere, including inside embedded subprocess script strings).

Tolerance-zero ``allclose(..., rtol=0, atol=0)`` is accepted: it *is*
exact equality (modulo NaN, which the pinned paths never produce).
"""
from __future__ import annotations

import ast
import re

from repro.analysis.common import Finding, call_name

NAME = "bitwise-pin"

BITWISE = re.compile(r"bitwise|bit-for-bit|bit_for_bit", re.IGNORECASE)
EXACT_CALLS = {"array_equal", "assert_array_equal", "array_equiv"}
CLOSE_CALLS = {"allclose", "assert_allclose", "isclose"}
EXACT_TEXT = re.compile(
    r"array_equal|assert_array_equal|rtol=0[^.]|atol=0[^.]|\)\s*==\s*|==\s*\(")


def _claims_bitwise(fn: ast.FunctionDef) -> bool:
    if BITWISE.search(fn.name):
        return True
    doc = ast.get_docstring(fn)
    return bool(doc and BITWISE.search(doc))


def _zero_tolerances(call: ast.Call) -> bool:
    tol = {kw.arg: kw.value for kw in call.keywords
           if kw.arg in ("rtol", "atol")}
    if not tol:
        return False
    return all(isinstance(v, ast.Constant) and v.value == 0
               for v in tol.values())


def _module_strings(tree: ast.AST) -> dict[str, tuple[str, int]]:
    """Module-level ``NAME = \"...\"`` script constants (forced-device
    tests keep their subprocess body in one): name -> (text, line)."""
    out = {}
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, str) \
                and len(node.value.value) > 40:
            out[node.targets[0].id] = (node.value.value, node.lineno)
    return out


def check_file(path: str, tree: ast.AST, source: str) -> list[Finding]:
    findings: list[Finding] = []
    scripts = _module_strings(tree)
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        if not (fn.name.startswith("test") and _claims_bitwise(fn)):
            continue
        exact_seen = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and node.id in scripts:
                text, line = scripts[node.id]
                if EXACT_TEXT.search(text):
                    exact_seen = True
                if re.search(r"(?<!_)allclose\(", text) \
                        and "rtol=0" not in text:
                    findings.append(Finding(
                        code="BP1", path=path, line=line, symbol=fn.name,
                        message=(f"allclose inside the {node.id} subprocess "
                                 "script of a test claiming 'bitwise' — "
                                 "pin with array_equal")))
            if isinstance(node, ast.Call):
                cn = (call_name(node) or "").split(".")[-1]
                if cn in EXACT_CALLS:
                    exact_seen = True
                elif cn in CLOSE_CALLS:
                    if _zero_tolerances(node):
                        exact_seen = True
                    else:
                        findings.append(Finding(
                            code="BP1", path=path, line=node.lineno,
                            symbol=fn.name,
                            message=(f"{cn} with nonzero tolerances in a "
                                     "test claiming 'bitwise' — pin with "
                                     "array_equal (or rtol=0, atol=0)")))
            elif isinstance(node, ast.Compare) \
                    and any(isinstance(op, ast.Eq) for op in node.ops):
                exact_seen = True
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str) and len(node.value) > 40:
                # forced-device tests embed their body as a subprocess
                # script string — scan its text for the same signals
                if EXACT_TEXT.search(node.value):
                    exact_seen = True
                if re.search(r"(?<!_)allclose\(", node.value) \
                        and "rtol=0" not in node.value:
                    findings.append(Finding(
                        code="BP1", path=path, line=node.lineno,
                        symbol=fn.name,
                        message=("allclose inside the embedded subprocess "
                                 "script of a test claiming 'bitwise' — "
                                 "pin with array_equal")))
        if not exact_seen:
            findings.append(Finding(
                code="BP2", path=path, line=fn.lineno, symbol=fn.name,
                message=("test claims 'bitwise' but performs no exact "
                         "comparison (array_equal / == / zero-tolerance "
                         "allclose)")))
    return findings
