"""Checker DX — strategy-table exhaustiveness and single-source capability
sets.

The distributed engine dispatches on ``(action, format, sync)`` through
``_DISTRIBUTED_STRATEGIES`` and gates optional features through the
capability frozensets (``_FUSED_STRATEGIES`` / ``_OVERLAP_STRATEGIES`` /
``_COMPRESS_STRATEGIES``).  The PR-3 EllOp hole was exactly a missing
table row; this checker makes that class of drift mechanical:

* DX1 — a capability-set member that is not a strategy kind produced by
  the table (a stale or misspelled entry gates nothing);
* DX2 — a dispatch hole: an ``(action, format)`` pair where both the
  action and the format appear elsewhere in the table but the pair has
  no row under any sync (how the EllOp hole looked);
* DX3 — a capability set with no fallback guard: no
  ``if ... kind not in <SET>:`` whose body warns (``_warn_*`` helper or
  ``warnings.warn``) — requests for the feature would be silently
  ignored or crash instead of downgrading loudly;
* DX4 — a duplicated capability literal: a tuple/set/list of string
  constants somewhere in ``src/repro`` equal (as a set) to one of the
  named capability constants, instead of referencing the constant — the
  hand-maintained copies drift;
* DX5 — the dispatch-miss error path does not enumerate the table
  programmatically (no ``sorted(_DISTRIBUTED_STRATEGIES)`` in the
  function that performs the ``.get``);
* DX6 — a hardcoded variant choice at a dispatch seam: a function that
  references two or more members of a *tuned variant family*
  (``VARIANT_FAMILIES`` — the CSR matvec kernel quartet, the
  fused-vs-scan sweep-engine pair) is choosing between measured
  alternatives, and must consult the tuning table (a ``repro.tune``
  lookup: ``resolve_fused`` / ``matvec_variant`` /
  ``tuned_rows_per_panel`` / ``lookup``) or carry a baseline entry
  justifying the bypass.  ``repro/tune`` (the table's own machinery)
  and ``repro/kernels`` (where the variants are *defined*, not chosen
  between) are exempt.

This is a repo-level checker (``check_repo``): the table lives in one
module but DX4 and DX6 scan every file.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.common import (
    Finding, call_name, const_str_tuple, dotted_name)

NAME = "dispatch"

TABLE_NAME = "_DISTRIBUTED_STRATEGIES"
CONST_NAME = re.compile(r"^_?[A-Z][A-Z0-9_]*$")

#: DX6 — the tuned variant families (family name -> member symbols).
#: Referencing >=2 members of one family in a single function means the
#: function chooses between measured alternatives at runtime.
VARIANT_FAMILIES = {
    "csr_matvec": frozenset({
        "spmv_csr", "spmv_csr_prefetch",
        "spmv_csr_sliced", "spmv_csr_sliced_prefetch"}),
    "sweep_engine": frozenset({
        "_sequential_fused_impl", "_sequential_scan_impl"}),
}

#: DX6 — the ``repro.tune`` lookup entry points that make a variant
#: choice table-driven (matched on the called name's last segment).
TUNE_LOOKUPS = frozenset({
    "resolve_fused", "matvec_variant", "tuned_rows_per_panel", "lookup"})

#: DX6 exemptions: the tuning machinery itself and the kernel modules
#: where the family members are defined.
DX6_EXEMPT = ("repro/tune/", "repro/kernels/")


def _module_constants(tree: ast.AST
                      ) -> dict[str, tuple[tuple[str, ...], int, ast.AST]]:
    """ALL_CAPS module-level string-tuple constants:
    name -> (values, line, value-AST)."""
    out = {}
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and CONST_NAME.match(node.targets[0].id):
            vals = const_str_tuple(node.value)
            if vals:
                out[node.targets[0].id] = (vals, node.lineno, node.value)
    return out


def _parse_table(tree: ast.AST) -> dict[tuple[str, str, str], str] | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == TABLE_NAME \
                and isinstance(node.value, ast.Dict):
            table = {}
            for k, v in zip(node.value.keys, node.value.values):
                kt = const_str_tuple(k)
                if kt and len(kt) == 3 and isinstance(v, ast.Constant):
                    table[kt] = v.value
            return table
    return None


def _has_fallback_guard(tree: ast.AST, set_name: str) -> bool:
    for node in ast.walk(tree):
        if not isinstance(node, ast.If):
            continue
        hit = any(
            isinstance(sub, ast.Compare)
            and any(isinstance(op, ast.NotIn) for op in sub.ops)
            and any(dotted_name(c) == set_name for c in sub.comparators)
            for sub in ast.walk(node.test))
        if not hit:
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                cn = call_name(sub) or ""
                if cn == "warnings.warn" or cn.split(".")[-1].startswith("_warn_"):
                    return True
    return False


def _get_functions(tree: ast.AST) -> list[ast.FunctionDef]:
    """Functions that perform the ``_DISTRIBUTED_STRATEGIES.get`` lookup."""
    out = []
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and any(
                isinstance(n, ast.Call)
                and call_name(n) == f"{TABLE_NAME}.get"
                for n in ast.walk(fn)):
            out.append(fn)
    return out


def check_repo(root: str, parsed: dict[str, tuple[ast.AST, str]]
               ) -> list[Finding]:
    findings: list[Finding] = []
    table_path, table, table_tree = None, None, None
    for path, (tree, _src) in parsed.items():
        t = _parse_table(tree)
        if t is not None:
            table_path, table, table_tree = path, t, tree
            break
    if table is None:
        return findings  # no distributed engine in this tree

    constants = _module_constants(table_tree)
    kinds = set(table.values())

    # DX1 — stale capability members
    cap_sets = {n: v for n, (v, _ln, _node) in constants.items()
                if n.endswith("_STRATEGIES")}
    for set_name, members in sorted(cap_sets.items()):
        line = constants[set_name][1]
        for m in members:
            if m not in kinds:
                findings.append(Finding(
                    code="DX1", path=table_path, line=line, symbol=set_name,
                    message=(f"{m!r} is not a strategy kind produced by "
                             f"{TABLE_NAME} (kinds: {sorted(kinds)}) — "
                             "stale capability entry gates nothing")))

    # DX2 — (action, format) holes
    actions = {a for (a, _f, _s) in table}
    formats = {f for (_a, f, _s) in table}
    covered = {(a, f) for (a, f, _s) in table}
    for a in sorted(actions):
        for f in sorted(formats):
            if (a, f) not in covered:
                findings.append(Finding(
                    code="DX2", path=table_path, line=0,
                    symbol=f"{TABLE_NAME}[{a},{f}]",
                    message=(f"dispatch hole: action={a!r} and format={f!r} "
                             "both appear in the table but the pair has no "
                             "row under any sync — add the row or an "
                             "explicit NotImplementedError with rationale")))

    # DX3 — capability sets without a warn-and-downgrade guard
    for set_name in sorted(cap_sets):
        if not _has_fallback_guard(table_tree, set_name):
            findings.append(Finding(
                code="DX3", path=table_path, line=constants[set_name][1],
                symbol=set_name,
                message=(f"no `kind not in {set_name}` fallback guard that "
                         "warns — feature requests outside the set would be "
                         "silently ignored or crash")))

    # DX5 — dispatch-miss error must enumerate the table programmatically
    for fn in _get_functions(table_tree):
        enumerates = any(
            isinstance(n, ast.Call) and call_name(n) == "sorted"
            and n.args and dotted_name(n.args[0]) == TABLE_NAME
            for n in ast.walk(fn))
        if not enumerates:
            findings.append(Finding(
                code="DX5", path=table_path, line=fn.lineno, symbol=fn.name,
                message=(f"dispatches via {TABLE_NAME}.get but the miss "
                         f"path never enumerates sorted({TABLE_NAME}) — "
                         "error messages must list the real table, not a "
                         "hand-maintained string")))

    # DX4 — duplicated capability literals anywhere in the tree.  Two
    # triggers: (a) a literal equal to a named capability constant, and
    # (b) the same >=3-element string-tuple literal appearing at two or
    # more sites (the pre-constant form of the same drift).
    tracked = {n: frozenset(v) for n, (v, _ln, _node) in constants.items()
               if len(v) >= 2}
    defining_nodes = {id(sub) for _n, (_v, _ln, node) in constants.items()
                      for sub in ast.walk(node)}
    occurrences: dict[frozenset, list[tuple[str, int]]] = {}
    for path, (tree, _src) in sorted(parsed.items()):
        consumed: set[int] = set()   # inner displays of frozenset(...) calls
        for node in ast.walk(tree):
            if id(node) in consumed:
                continue
            vals = const_str_tuple(node)
            if not vals or len(vals) < 2:
                continue
            if isinstance(node, ast.Call):
                # one literal, two AST nodes: don't count the wrapped
                # tuple/set display again when the walk reaches it
                consumed.add(id(node.args[0]))
            vset = frozenset(vals)
            hit_constant = False
            for cname, cvals in sorted(tracked.items()):
                if vset != cvals:
                    continue
                hit_constant = True
                if path == table_path and id(node) in defining_nodes:
                    continue  # the defining assignment itself
                findings.append(Finding(
                    code="DX4", path=path, line=node.lineno,
                    symbol=f"literal=={cname}",
                    message=(f"string literal duplicating {cname} "
                             f"({sorted(vset)}) — import the constant from "
                             "the table module so the copies cannot drift")))
            if not hit_constant and len(vset) >= 3:
                occurrences.setdefault(vset, []).append((path, node.lineno))
    for vset, sites in sorted(occurrences.items(),
                              key=lambda kv: sorted(kv[0])):
        if len(sites) < 2:
            continue
        for path, line in sites:
            findings.append(Finding(
                code="DX4", path=path, line=line,
                symbol=f"literal={'|'.join(sorted(vset))}",
                message=(f"string-tuple literal {sorted(vset)} repeated at "
                         f"{len(sites)} sites — hoist it to one named "
                         "constant so the copies cannot drift")))

    # DX6 — hardcoded variant selection bypassing the tuning table
    for path, (tree, _src) in sorted(parsed.items()):
        if any(seg in path for seg in DX6_EXEMPT):
            continue
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            referenced: set[str] = set()
            consults = False
            for node in ast.walk(fn):
                dn = dotted_name(node)
                if dn:
                    referenced.add(dn.split(".")[-1])
                if isinstance(node, ast.Call):
                    cn = (call_name(node) or "").split(".")[-1]
                    if cn in TUNE_LOOKUPS:
                        consults = True
            if consults:
                continue
            for fam, members in sorted(VARIANT_FAMILIES.items()):
                hit = sorted(referenced & members)
                if len(hit) >= 2:
                    findings.append(Finding(
                        code="DX6", path=path, line=fn.lineno,
                        symbol=fn.name,
                        message=(f"references {len(hit)} members of the "
                                 f"tuned {fam!r} variant family ({hit}) "
                                 "without a repro.tune lookup "
                                 f"({'/'.join(sorted(TUNE_LOOKUPS))}) — "
                                 "route the choice through the tuning "
                                 "table or baseline the bypass with a "
                                 "justification")))
    return findings
