"""Paper Sec. 7/8 analogue: multi-RHS linear regression via randomized
Kaczmarz — directly on the design matrix, no normal equations.

The paper's regression workload (120k x 120k normal equations, 51 targets,
~10 sweeps of accuracy) previously forced us to hand-build ridge normal
equations and solve the SPD system.  The Kaczmarz subsystem removes that
detour: iterate on the rows of X itself, so the contraction is governed by
kappa(X) instead of kappa(X)^2, and each update touches one row — the
per-iteration cost profile the paper's asynchronous analysis assumes.

    PYTHONPATH=src python examples/solve_regression.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LSQProblem, Schedule, cg_solve, solve, theory,
                        to_unit_diagonal)


def build_problem(n_samples=4096, n_features=1024, n_targets=51, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_samples, n_features)).astype(np.float32)
    X *= rng.exponential(1.0, n_features).astype(np.float32)  # skewed scales
    W_true = rng.standard_normal((n_features, n_targets)).astype(np.float32)
    Y = X @ W_true + 0.1 * rng.standard_normal(
        (n_samples, n_targets)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(Y), jnp.asarray(W_true)


def main():
    X, Y, W_true = build_problem()
    m, n = X.shape
    k = Y.shape[1]
    W_star = jnp.linalg.lstsq(X, Y)[0]
    W0 = jnp.zeros_like(W_star)
    yn = float(jnp.linalg.norm(Y))
    floor = float(jnp.linalg.norm(Y - X @ W_star)) / yn
    s = jnp.linalg.svd(X, compute_uv=False)
    # package as an LSQProblem so the unified solve() front door applies
    prob = LSQProblem(A=X, b=Y, x_star=W_star, x_true=W_true,
                      sigma_min=s[-1], sigma_max=s[0])
    print(f"least squares: m={m}, n={n}, targets={k}, "
          f"kappa(X)={float(s[0]/s[-1]):.1f}, optimum relresid={floor:.3e}")

    sweeps = 10
    t0 = time.time()
    res = solve(prob, key=jax.random.key(0),
                schedule=Schedule(num_iters=sweeps * m, record_every=m))
    t_rk = time.time() - t0

    # Async RK with the Thm-analogous step size beta~ = 1/(1 + 2 rho_rk tau).
    rho_rk = float(theory.rk_rho(X))
    tau = 64
    beta = theory.beta_opt_rk(rho_rk, tau)
    ares = solve(prob, key=jax.random.key(0), delay_key=jax.random.key(1),
                 beta=beta,
                 schedule=Schedule(num_iters=sweeps * m, tau=tau,
                                   record_every=m))

    # Baseline: CG on the Jacobi-rescaled normal equations (Sec. 2.3), as
    # the old hand-rolled path did — kappa is still squared relative to X
    # and every iteration costs two global reductions.
    B, d = to_unit_diagonal(X.T @ X)
    z = d[:, None] * (X.T @ Y)
    cg = cg_solve(B, z, jnp.zeros_like(W0), W_star / d[:, None],
                  num_iters=sweeps)
    W_cg = d[:, None] * cg.x

    print(f"after {sweeps} sweeps / NE iterations "
          f"(equal O(mn) work per sweep/iteration):")
    print(f"  sync RK    relresid {float(jnp.linalg.norm(res.resid[-1]))/yn:.3e} "
          f"({t_rk:.1f}s)")
    print(f"  async RK   relresid {float(jnp.linalg.norm(ares.resid[-1]))/yn:.3e} "
          f"(tau={tau}, beta~={beta:.2f})")
    print(f"  CG (X^T X) relresid {float(jnp.linalg.norm(Y - X @ W_cg))/yn:.3e}")

    # the downstream metric the paper cares about: regression quality
    rel_w = float(jnp.linalg.norm(ares.x - W_true) / jnp.linalg.norm(W_true))
    print(f"  downstream: ||W_hat - W_true||/||W_true|| = {rel_w:.3f} "
          f"(low-accuracy regime is enough, as in the paper)")


if __name__ == "__main__":
    main()
