"""Paper Sec. 8 analogue: multi-RHS linear regression from data analysis.

The paper solves (rescaled) normal equations from a social-media regression
— 120k x 120k, 51 right-hand sides, needing only ~10 sweeps of accuracy.
This example builds the same *shape* of problem at laptop scale: a ridge
normal-equation system  (X^T X + lambda I) W = X^T Y  with 51 targets,
solves all 51 columns simultaneously with randomized GS (synchronous and
asynchronous), and reports the low-accuracy regime where RGS beats CG.

    PYTHONPATH=src python examples/solve_regression.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (async_rgs_solve, cg_solve, rgs_solve, theory,
                        to_unit_diagonal)


def build_problem(n_samples=4096, n_features=1024, n_targets=51, lam=1e-2,
                  seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_samples, n_features)).astype(np.float32)
    X *= rng.exponential(1.0, n_features).astype(np.float32)  # skewed scales
    W_true = rng.standard_normal((n_features, n_targets)).astype(np.float32)
    Y = X @ W_true + 0.1 * rng.standard_normal((n_samples, n_targets)).astype(np.float32)
    B = jnp.asarray(X.T @ X / n_samples + lam * np.eye(n_features))
    z = jnp.asarray(X.T @ Y / n_samples)
    return B, z, jnp.asarray(W_true)


def main():
    B, z, W_true = build_problem()
    # Sec. 2.3: rescale to unit diagonal, solve A x = D z, map back y = D x.
    A, d = to_unit_diagonal(B)
    b = d[:, None] * z
    x_star = jnp.linalg.solve(A, b)
    n, k = b.shape
    x0 = jnp.zeros_like(b)
    bn = float(jnp.linalg.norm(b))
    evals = jnp.linalg.eigvalsh(A)
    print(f"normal equations: n={n}, targets={k}, "
          f"kappa={float(evals[-1]/evals[0]):.1f}")

    sweeps = 10
    t0 = time.time()
    res = rgs_solve(A, b, x0, x_star, key=jax.random.key(0),
                    num_iters=sweeps * n, record_every=n)
    t_rgs = time.time() - t0
    cg = cg_solve(A, b, x0, x_star, num_iters=sweeps)

    rho = float(theory.rho(A))
    tau = 64
    beta = theory.beta_opt(rho, tau)
    ares = async_rgs_solve(A, b, x0, x_star, key=jax.random.key(0),
                           delay_key=jax.random.key(1),
                           num_iters=sweeps * n, tau=tau, beta=beta,
                           record_every=n)

    print(f"after {sweeps} sweeps / iterations "
          f"(equal O(nnz) work per sweep/iteration):")
    print(f"  sync RGS   relresid {float(jnp.linalg.norm(res.resid[-1]))/bn:.3e} "
          f"({t_rgs:.1f}s)")
    print(f"  async RGS  relresid {float(jnp.linalg.norm(ares.resid[-1]))/bn:.3e} "
          f"(tau={tau}, beta~={beta:.2f})")
    print(f"  CG         relresid {float(jnp.linalg.norm(cg.resid[-1]))/bn:.3e}")

    # the downstream metric the paper cares about: regression quality
    W_hat = d[:, None] * ares.x
    rel_w = float(jnp.linalg.norm(W_hat - W_true) / jnp.linalg.norm(W_true))
    print(f"  downstream: ||W_hat - W_true||/||W_true|| = {rel_w:.3f} "
          f"(low-accuracy regime is enough, as in the paper)")


if __name__ == "__main__":
    main()
