"""Batched serving example: prefill a batch of prompts, decode greedily with
ring-buffer KV caches — the code path the decode_32k / long_500k dry-run
cells compile at pod scale.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-1b --new 24
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len), "--new", str(args.new)])


if __name__ == "__main__":
    main()
