"""End-to-end training driver (deliverable b): train an LM for a few hundred
steps with the full substrate — data pipeline, optimizer, checkpointing,
restart — on CPU with a reduced config by default.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 200

``--preset 100m`` uses a ~100M-parameter config (slow on this single-core
container; the default ~3M config shows the same loss curve in minutes).
The checkpoint/restart path is exercised mid-run: the trainer saves at
half-time and a fresh Trainer object resumes from disk.
"""
import argparse
import tempfile

import jax

from repro.configs import get_run_config, get_smoke_config
from repro.train import steps as ST
from repro.train.trainer import Trainer, make_data


def preset_100m(arch: str):
    cfg = get_smoke_config(arch)
    return cfg.with_(num_layers=12, d_model=768, num_heads=12,
                     num_kv_heads=4, head_dim=64, d_ff=2048,
                     vocab_size=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = (preset_100m(args.arch) if args.preset == "100m"
           else get_smoke_config(args.arch))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    rcfg = get_run_config(args.arch).with_(
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        learning_rate=1e-3, loss_chunk=min(128, args.seq_len),
        q_chunk=min(512, args.seq_len),
        checkpoint_dir=ckpt_dir, checkpoint_every=max(1, args.steps // 2))
    part = ST.make_partitioner(None, args.batch)
    data = make_data(cfg, args.seq_len, args.batch)

    n_params = sum(x.size for x in jax.tree.leaves(
        ST.init_train_state(cfg, rcfg, part, jax.random.key(0))[0].params))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq_len} tokens; "
          f"checkpoints -> {ckpt_dir}")

    trainer = Trainer(cfg=cfg, rcfg=rcfg, part=part, data=data,
                      log_every=max(1, args.steps // 10))
    half = args.steps // 2
    trainer.run(half)

    # kill the trainer, resume from disk — the restart path, exercised live
    print("[train_lm] simulating preemption: new Trainer resumes from disk")
    resumed = Trainer(cfg=cfg, rcfg=rcfg, part=part, data=data,
                      log_every=max(1, args.steps // 10))
    assert int(resumed.state.step) == half, "resume failed"
    hist = resumed.run(args.steps - half)
    first, last = trainer.history[0]["loss"], hist[-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
