"""Quickstart: solve an SPD system with the paper's solvers.

    PYTHONPATH=src python examples/quickstart.py

Builds a reference-scenario sparse SPD system (unit diagonal after the
Sec. 2.3 rescaling), then solves it three ways:
  1. synchronous randomized Gauss-Seidel (Leventhal-Lewis),
  2. asynchronous randomized GS under bounded delay, with the Sec. 5
     optimal step size beta~ = 1/(1 + 2 rho tau),
  3. CG (the paper's baseline).
"""
import jax
import jax.numpy as jnp

from repro.core import Schedule, cg_solve, random_sparse_spd, solve, theory


def main():
    n, sweeps = 1024, 10
    prob = random_sparse_spd(n, row_nnz=16, offdiag=0.95, n_rhs=4, seed=0)
    x0 = jnp.zeros_like(prob.x_star)
    bn = float(jnp.linalg.norm(prob.b))
    print(f"n={n}, nnz/row~32, kappa={float(prob.kappa):.1f}, 4 right-hand sides")

    # the unified front door: solve(problem, format=..., schedule=...)
    res = solve(prob, key=jax.random.key(1),
                schedule=Schedule(num_iters=sweeps * n, record_every=n))
    for s in (1, 5, 10):
        print(f"  sync RGS  sweep {s:2d}: relative residual "
              f"{float(jnp.linalg.norm(res.resid[s-1]))/bn:.3e}")

    tau = 32
    rho = float(theory.rho(prob.A))
    beta = theory.beta_opt(rho, tau)
    # tau > 0 routes to the bounded-delay simulator of the paper's Sec. 4
    ares = solve(prob, key=jax.random.key(1), delay_key=jax.random.key(2),
                 beta=beta, delay_mode="uniform",
                 schedule=Schedule(num_iters=sweeps * n, tau=tau,
                                   record_every=n))
    print(f"  async RGS (tau={tau}, beta~={beta:.3f}) sweep {sweeps}: "
          f"relative residual {float(jnp.linalg.norm(ares.resid[-1]))/bn:.3e}")

    cg = cg_solve(prob.A, prob.b, x0, prob.x_star, num_iters=sweeps)
    print(f"  CG        iter  {sweeps}: relative residual "
          f"{float(jnp.linalg.norm(cg.resid[-1]))/bn:.3e}")
    print("note: RGS sweeps and CG iterations cost the same O(nnz) work")


if __name__ == "__main__":
    main()
