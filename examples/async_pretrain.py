"""The paper's technique at trainer level, demonstrated: bounded-staleness
asynchronous data parallelism with the Sec. 5 step-size damping.

Three runs on the same data/seed:
  A. synchronous baseline,
  B. async tau=4 WITH beta~ damping (the paper's recipe),
  C. async tau=4 WITHOUT damping (what naive Hogwild-style delay does).

Expected outcome (mirrors Thm 4.1/Sec 5): B tracks A closely; C is noisier /
can lag — the damping is what makes scheduled staleness safe.

    PYTHONPATH=src python examples/async_pretrain.py --steps 120
"""
import argparse

from repro.configs import get_run_config, get_smoke_config
from repro.optim import staleness_beta
from repro.train import steps as ST
from repro.train.trainer import Trainer, make_data


def run_one(tag, tau, damping, steps, lr=3e-3):
    cfg = get_smoke_config("qwen2-1.5b")
    rcfg = get_run_config("qwen2-1.5b").with_(
        total_steps=steps, warmup_steps=5, learning_rate=lr,
        loss_chunk=32, q_chunk=32, async_tau=tau, staleness_damping=damping)
    part = ST.make_partitioner(None, 8)
    data = make_data(cfg, seq_len=64, global_batch=8)
    tr = Trainer(cfg=cfg, rcfg=rcfg, part=part, data=data,
                 log_every=max(1, steps // 6), log_fn=lambda *_: None)
    hist = tr.run(steps)
    losses = [h["loss"] for h in hist]
    print(f"  {tag:34s} loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--tau", type=int, default=4)
    args = ap.parse_args()
    print(f"[async_pretrain] tau={args.tau}, "
          f"beta~ = 1/(1+tau) = {staleness_beta(args.tau):.3f}")
    a = run_one("A sync", 0, True, args.steps)
    b = run_one(f"B async tau={args.tau} + beta~ damping", args.tau, True,
                args.steps)
    c = run_one(f"C async tau={args.tau} no damping", args.tau, False,
                args.steps)
    gap_b = b[-1] - a[-1]
    gap_c = c[-1] - a[-1]
    print(f"[async_pretrain] final-loss gap vs sync: damped {gap_b:+.3f}, "
          f"undamped {gap_c:+.3f}")
    print("the damped run should track the synchronous baseline closely "
          "(paper Sec. 5: the step size buys convergence at any tau)")


if __name__ == "__main__":
    main()
